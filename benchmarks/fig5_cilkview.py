"""Paper Fig 5 — Cilkview-style scalability profile for GSCPM.

Analytic work/span speedup lower bounds as a function of nTasks, for 61 and
244 "cores" (the Phi's core/thread counts) plus this harness's lane widths.
Reproduces the paper's qualitative claim: fine-grained task counts
(nTasks >> nCores) are required for near-perfect intrinsic parallelism;
16384 tasks ~ perfect speedup on 61 cores.
"""

from __future__ import annotations

from repro.configs.hex_paper import PAPER, TASK_SWEEP
from repro.core.cilkview import DagModel, profile


def run(n_playouts: int | None = None) -> dict:
    n = n_playouts or PAPER.n_playouts
    cores = [16, 61, 244]
    curves = profile(n, TASK_SWEEP, cores, DagModel())
    return {
        "n_playouts": n,
        "core_counts": cores,
        "task_sweep": TASK_SWEEP,
        "speedup_bounds": {str(t): v for t, v in curves.items()},
        "note": "bound(61 cores, 16384 tasks) ~ 61 == paper's near-perfect "
                "profile at fine grain",
    }


if __name__ == "__main__":
    import json

    from benchmarks.common import save_result
    r = run()
    print(json.dumps(r, indent=1))
    save_result("fig5_cilkview", r)
