"""Fill EXPERIMENTS.md placeholders from artifacts (dryrun + perf)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline_table import fmt_s, load_cells, render

ROOT = os.path.join(os.path.dirname(__file__), "..")


def dryrun_summary(cells) -> str:
    ok = [c for c in cells if "error" not in c]
    single = [c for c in ok if c["mesh"] == "single"]
    multi = [c for c in ok if c["mesh"] == "multipod"]
    fits = sum(1 for c in single if c["memory"]["fits_16GiB"])
    worst = sorted(single, key=lambda c: -c["memory"]["peak_bytes_per_chip"])[:3]
    lines = [
        f"- **{len(single)}/32 single-pod cells** lower + compile on the "
        f"16×16 mesh; **{len(multi)}/32 multi-pod cells** on 2×16×16 "
        "(the pod axis shards; gradient all-reduce crosses pods).",
        f"- {fits}/{len(single)} single-pod cells fit 16 GiB/chip at the "
        "baseline configuration; the exceptions are hillclimbed in §Perf:",
    ]
    for c in worst:
        lines.append(
            f"  - {c['arch']} {c['shape']}: "
            f"{c['memory']['peak_bytes_per_chip']/2**30:.2f} GiB"
            + (" (fits)" if c["memory"]["fits_16GiB"] else " (over budget)"))
    lines.append(
        "- per-cell JSON (memory breakdown, collective-by-op wire bytes, "
        "while-loop trip counts, compile times) in `artifacts/dryrun/`.")
    return "\n".join(lines)


def roofline_notes(cells) -> str:
    single = [c for c in cells if c.get("mesh") == "single" and "error" not in c]
    n_mem = sum(1 for c in single if c["roofline"]["bottleneck"] == "memory")
    n_coll = sum(1 for c in single if c["roofline"]["bottleneck"] == "collective")
    n_comp = len(single) - n_mem - n_coll
    return f"""Reading the table ({n_mem} memory-bound, {n_coll} collective-bound,
{n_comp} compute-bound cells):

- **decode cells are memory-bound everywhere** — intrinsic: one token per
  step reads all (active) weights + the KV cache; MFU is the wrong lens
  for decode, step-time (the memory term) is the score.
- **big-model train cells are collective-bound** at baseline: the wire
  breakdown (JSON `collectives.by_op`) shows the Megatron-SP block-edge
  activation all-gathers and the MoE combine all-reduces dominating, NOT
  the FSDP weight gathers (measured; see §Perf, hypothesis A1 refuted).
- the `useful` column (6·N·D / parsed HLO FLOPs) sits at 45-75% for train
  cells — the gap is attention FLOPs (reported separately in the JSON),
  remat recompute (~1.33x), and replicated compute on unshardable head
  counts (smollm's 9 heads, paligemma's 8 over a 16-way model axis).
- each cell's JSON carries a one-line improvement note candidate: the
  dominant term's biggest contributor from the per-op breakdown."""


def perf_log() -> str:
    runs = {}
    for path in glob.glob(os.path.join(ROOT, "artifacts", "perf", "*.json")):
        with open(path) as f:
            runs[os.path.basename(path)[:-5]] = json.load(f)

    def row(tag):
        r = runs[tag]["roofline"]
        m = runs[tag]["memory"]
        return (f"| {tag} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | "
                f"{m['peak_bytes_per_chip']/2**30:.2f} | "
                f"{r['mfu']*100:.2f}% |")

    hdr = ("| experiment | compute | memory | collective | peak GiB | MFU |\n"
           "|---|---|---|---|---|---|")
    out = [hdr]
    for tag in sorted(runs):
        out.append(row(tag))
    return "\n".join(out)


def main():
    cells = load_cells()
    exp_path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(exp_path) as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_SUMMARY -->", dryrun_summary(cells))
    text = text.replace("<!-- ROOFLINE_TABLE -->", render(cells, "single"))
    text = text.replace("<!-- ROOFLINE_NOTES -->", roofline_notes(cells))
    with open(exp_path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md placeholders filled "
          f"({len([c for c in cells if 'error' not in c])} cells, "
          f"{len(glob.glob(os.path.join(ROOT, 'artifacts/perf/*.json')))} "
          "perf runs)")


if __name__ == "__main__":
    main()
