"""Root-parallel scaling — aggregate playouts/s vs ensemble size E.

The §3 claim measured: E independent trees advanced by ONE jitted program
per round (no per-tree Python loop) amortize dispatch and fill idle vector
lanes, so aggregate throughput grows far faster than the cost of batching.
The acceptance bar for this repo: E=8 aggregate playouts/s >= 3x the
single-tree rate at an identical per-tree configuration.

The default per-tree config is the classic root-parallel regime — each
member is a narrow (W=1) searcher, the setting of the paper's companion
study (arXiv:1409.4297) where an ensemble of sequential searchers is merged
at the root. Wide per-tree configs (W >= 8) shift the parallelism budget to
the shared-tree axis of §2 and saturate a small host by themselves; the
ensemble dial and the lane dial trade against each other on fixed hardware.

    PYTHONPATH=src python benchmarks/root_parallel.py
"""

from __future__ import annotations

import os
import sys

import jax

if __package__ in (None, ""):   # `python benchmarks/root_parallel.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro.core.gscpm import GSCPMConfig, gscpm_search
from repro.core.root_parallel import gscpm_search_batch


def run(n_playouts: int = 4096, n_workers: int = 1, board_size: int = 5,
        n_tasks: int = 8, ensemble_sweep=(1, 2, 4, 8),
        merge_every: int = 0, seed: int = 0,
        tree_cap: int | None = None, repeats: int = 5) -> dict:
    cfg = GSCPMConfig(board_size=board_size, n_playouts=n_playouts,
                      n_tasks=n_tasks, n_workers=n_workers,
                      tree_cap=tree_cap or max(512, n_playouts // 8))
    board = cfg.game_obj.init_board()
    key = jax.random.key(seed)

    def one_single():
        _, st = gscpm_search(board, 1, cfg, key)
        return st

    def one_batch(e):
        _, st = gscpm_search_batch(board, 1, cfg, key, n_trees=e,
                                   merge_every=merge_every)
        return st

    # warm-up/compile every program before any timing
    one_single()
    for e in ensemble_sweep:
        one_batch(e)

    # paired repeats: shared hosts drift (contention, frequency scaling), so
    # each rep measures the single baseline and every ensemble size back to
    # back and the speedup is the median of PAIRED ratios — drift then hits
    # both sides of each ratio equally instead of whichever ran first
    single_rates = []
    batch_stats = {e: [] for e in ensemble_sweep}
    ratios = {e: [] for e in ensemble_sweep}
    for _ in range(repeats):
        s = one_single()
        single_rates.append(s["playouts_per_s"])
        for e in ensemble_sweep:
            st = one_batch(e)
            batch_stats[e].append(st)
            ratios[e].append(st["playouts_per_s"] / s["playouts_per_s"])

    def median(xs):
        xs = sorted(xs)
        return xs[len(xs) // 2]

    base_rate = median(single_rates)
    points = {}
    for e in ensemble_sweep:
        st = batch_stats[e][-1]
        speedup = median(ratios[e])
        points[str(e)] = {
            "playouts_per_s": median(
                [b["playouts_per_s"] for b in batch_stats[e]]),
            "aggregate_speedup": speedup,
            "batching_efficiency": speedup / e,
            "best_move_sum": st["best_move_sum"],
            "best_move_vote": st["best_move_vote"],
            "sharded": st["sharded"],
        }
    out = {
        "config": {"n_playouts": n_playouts, "n_workers": n_workers,
                   "board_size": board_size, "n_tasks": n_tasks,
                   "merge_every": merge_every, "repeats": repeats,
                   "n_devices": len(jax.devices())},
        "single_tree_playouts_per_s": base_rate,
        "single_tree_rates": single_rates,
        "ensemble": points,
    }
    try:
        out["sharded_forest"] = sharded_forest(
            n_playouts=min(n_playouts, 1024), repeats=2)
    except Exception as e:   # noqa: BLE001 — the scale-out point is an
        # extra on hosts where spawning workers is restricted; the in-process
        # sweep above stays the benchmark's headline either way
        out["sharded_forest"] = {"error": f"{type(e).__name__}: {e}"}
    return out


def sharded_forest(n_playouts: int = 1024, n_trees: int = 8,
                   board_size: int = 5, n_tasks: int = 8,
                   n_workers: int = 1, tree_cap: int | None = None,
                   seed: int = 0, repeats: int = 3,
                   n_devices: int = 8) -> dict:
    """shard_map forest scale-out vs the single-device vmap path.

    The device count is fixed when jax initializes, so each point runs in
    a SUBPROCESS with ``XLA_FLAGS=--xla_force_host_platform_device_count``
    set before import: one worker on 1 device (``shard="off"``), one on
    ``n_devices`` virtual host devices (``shard="require"``). The worker
    reports ``stats["sharded"]`` so the caller can assert the sharded
    point actually ran sharded, and the merged best move must agree across
    the two — the bit-identity contract of tests/test_forest_sharding.py,
    smoked here on every benchmark run.
    """
    import json
    import subprocess

    kw = dict(n_playouts=n_playouts, n_trees=n_trees, board_size=board_size,
              n_tasks=n_tasks, n_workers=n_workers,
              tree_cap=tree_cap or max(512, n_playouts // 8), seed=seed,
              repeats=repeats)

    def point(devices: int, shard: str) -> dict:
        env = dict(os.environ)
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--sharded-worker",
             json.dumps(dict(kw, shard=shard))],
            env=env, capture_output=True, text=True, timeout=600)
        if proc.returncode != 0:
            raise RuntimeError(f"sharded worker failed:\n{proc.stderr}")
        return json.loads(proc.stdout.strip().splitlines()[-1])

    single = point(1, "off")
    sharded = point(n_devices, "require")
    assert sharded["sharded"] is True, "sharded point ran unsharded"
    assert single["sharded"] is False
    assert sharded["best_move_sum"] == single["best_move_sum"]
    assert sharded["playouts"] == single["playouts"]
    return {
        "config": dict(kw, n_devices=n_devices),
        "single_device": single,
        "sharded": sharded,
        "speedup_vs_single_device": (sharded["playouts_per_s"]
                                     / max(single["playouts_per_s"], 1e-9)),
    }


def _sharded_worker(payload: str) -> None:
    """Subprocess entry: time gscpm_search_batch under this process's
    device count and print one JSON line."""
    import json

    kw = json.loads(payload)
    cfg = GSCPMConfig(board_size=kw["board_size"],
                      n_playouts=kw["n_playouts"], n_tasks=kw["n_tasks"],
                      n_workers=kw["n_workers"], tree_cap=kw["tree_cap"])
    board = cfg.game_obj.init_board()
    key = jax.random.key(kw["seed"])

    def one():
        _, st = gscpm_search_batch(board, 1, cfg, key,
                                   n_trees=kw["n_trees"],
                                   shard=kw["shard"])
        return st

    one()                                    # compile off the clock
    stats = [one() for _ in range(kw["repeats"])]
    rates = sorted(s["playouts_per_s"] for s in stats)
    st = stats[-1]
    print(json.dumps({
        "n_devices": len(jax.devices()),
        "sharded": st["sharded"],
        "mesh_shape": st["mesh_shape"],
        "padded_members": st["padded_members"],
        "playouts": st["playouts"],
        "playouts_per_s": rates[len(rates) // 2],
        "best_move_sum": st["best_move_sum"],
        "best_move_vote": st["best_move_vote"],
    }))


def main():
    import argparse

    from benchmarks.common import save_result

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny budgets (CI rot-guard, <1 min)")
    p.add_argument("--sharded-worker", default=None, metavar="JSON",
                   help=argparse.SUPPRESS)   # internal subprocess entry
    args = p.parse_args()
    if args.sharded_worker:
        _sharded_worker(args.sharded_worker)
        return

    out = run(n_playouts=512, repeats=2) if args.smoke else run()
    base = out["single_tree_playouts_per_s"]
    print(f"single tree: {base:9.0f} playouts/s   (baseline)")
    for e, pt in out["ensemble"].items():
        print(f"E={e:>2} trees:  {pt['playouts_per_s']:9.0f} playouts/s   "
              f"aggregate {pt['aggregate_speedup']:5.2f}x   "
              f"batching efficiency {pt['batching_efficiency']:5.1%}")
    sf = out["sharded_forest"]
    if "error" in sf:
        print(f"sharded forest: SKIPPED ({sf['error']})")
    else:
        print(f"sharded forest: E={sf['config']['n_trees']} over "
              f"{sf['sharded']['n_devices']} devices   "
              f"{sf['sharded']['playouts_per_s']:9.0f} playouts/s   "
              f"{sf['speedup_vs_single_device']:5.2f}x vs 1 device   "
              f"mesh {sf['sharded']['mesh_shape']}")
    path = save_result("root_parallel", out)
    print("->", path)
    e8 = out["ensemble"].get("8")
    if e8 is not None:
        ok = e8["aggregate_speedup"] >= 3.0
        print(f"acceptance (E=8 aggregate >= 3x single tree): "
              f"{'PASS' if ok else 'FAIL'} ({e8['aggregate_speedup']:.2f}x)")


if __name__ == "__main__":
    main()
