"""Kernel microbenches: correctness sweeps + timing of the real dispatch path.

Interpret-mode Pallas timings are meaningless (Python-interpreted kernel
bodies), so interpret runs are reported as validation only — never timed.
For ``uct_select`` the timed path is the ``ops.uct_select`` dispatch users
actually hit on this backend (compiled Pallas on TPU, the jitted jnp
reference elsewhere); attention/rmsnorm have no jnp fallback in ``ops``, so
off-TPU their interpret run is validation-only and the jitted oracle is
timed as the reference throughput. Each entry records which path ran
(``dispatch``) so TPU and CPU artifacts are not comparable by accident; TPU
wall-clock numbers belong to the §Perf iteration on real hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hex as hx
from repro.kernels import ops, ref

from benchmarks.common import timed

ON_TPU = jax.default_backend() == "tpu"


def run(seed: int = 0) -> dict:
    key = jax.random.key(seed)
    out: dict[str, dict] = {}

    # flash attention
    fa = {}
    for (B, H, Hkv, S, d) in [(1, 4, 2, 256, 64), (1, 8, 8, 512, 64)]:
        ks = jax.random.split(jax.random.fold_in(key, S), 3)
        q = jax.random.normal(ks[0], (B, H, S, d), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, S, d), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, S, d), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, layout="bhsd")
        want = ref.flash_attention(q, k, v, causal=True)
        err = float(jnp.max(jnp.abs(got - want)))
        oracle = jax.jit(lambda q, k, v: ref.flash_attention(q, k, v, True))
        jax.block_until_ready(oracle(q, k, v))
        t, _ = timed(lambda: jax.block_until_ready(oracle(q, k, v)),
                     repeats=3)
        flops = 4 * B * H * S * S * d
        fa[f"B{B}H{H}S{S}d{d}"] = {
            "max_err_vs_oracle": err,
            "checked_path": ("pallas_compiled" if ON_TPU
                             else "pallas_interpret_validation_only"),
            "timed_path": "jnp_oracle",
            "oracle_s": t, "oracle_gflops": flops / t / 1e9}
    out["flash_attention"] = fa

    # uct_select — validate the Pallas kernel in interpret mode (never
    # timed), then time the backend dispatch path the search actually hits
    us = {}
    for (W, C) in [(128, 128), (1024, 128)]:
        ks = jax.random.split(jax.random.fold_in(key, W + C), 4)
        visits = jnp.round(jax.random.uniform(ks[0], (W, C)) * 50)
        wins = jnp.round(jax.random.uniform(ks[1], (W, C)) * visits)
        vloss = jnp.zeros((W, C))
        valid = jax.random.uniform(ks[2], (W, C)) > 0.2
        ptot = jnp.maximum(visits.sum(-1), 1.0)
        cp = jnp.float32(1.0)
        got = ops.uct_select(wins, visits, vloss, ptot, valid, cp,
                             interpret=True)
        want = ref.uct_select(wins, visits, vloss, ptot, valid, cp)
        agree = float((got == want).mean())
        jax.block_until_ready(
            ops.uct_select(wins, visits, vloss, ptot, valid, cp))
        t, _ = timed(lambda: jax.block_until_ready(
            ops.uct_select(wins, visits, vloss, ptot, valid, cp)), repeats=3)
        us[f"W{W}C{C}"] = {
            "interpret_agreement_validation_only": agree,
            "dispatch": "pallas_compiled" if ON_TPU else "jnp_ref",
            "dispatch_s": t,
            "selections_per_s": W / t,
        }
    out["uct_select"] = us

    # hex winner / playout — the playout phase's two formulations (O(diam)
    # flood fill vs O(log n) pointer doubling), scalar-vmap vs batched, and
    # the fused playout stage. The interpret-mode Pallas kernel run is
    # validation-only; the timed paths are the real dispatch
    # (pointer-doubling Pallas on TPU, batched flood fill elsewhere) and
    # the jitted alternatives it was chosen against.
    hw = {}
    for (size, W) in [(9, 16), (11, 16), (11, 128)]:
        spec = hx.HexSpec(size)
        ks = jax.random.split(jax.random.fold_in(key, 7000 + size * W), W)
        empty = jnp.tile(hx.empty_board(spec)[None], (W, 1))
        fill_j = jax.jit(lambda b, k: hx.random_fill_batch(b, 1, k, spec))
        filled = jax.block_until_ready(fill_j(empty, ks))

        entry = {"dispatch": "pallas_compiled" if ON_TPU
                 else "jnp_flood_batch"}
        if W <= 16:  # interpret-mode Pallas is pure Python — keep it small
            kern = ops.hex_winner(filled, size, interpret=True)
            pj = ref.hex_winner(filled, size)
            entry["kernel_interpret_agreement_validation_only"] = float(
                (np.asarray(kern) == np.asarray(pj)).mean())

        disp = lambda b: ops.hex_winner(b, size)
        pj_j = jax.jit(lambda b: ref.hex_winner(b, size))
        flood_v = jax.jit(jax.vmap(lambda b: hx.winner(b, spec)))
        po_b = jax.jit(lambda b, k: hx.playout_batch(b, 1, k, spec))
        # explicit per-lane formulation (`hx.playout` itself is now a
        # width-1 wrapper over the batched path): fill + scalar flood winner
        po_v = jax.jit(jax.vmap(lambda b, k: hx.winner(
            hx.random_fill(b, jnp.int32(1), k, spec), spec)))
        for f, args in ((disp, (filled,)), (pj_j, (filled,)),
                        (flood_v, (filled,)), (po_b, (empty, ks)),
                        (po_v, (empty, ks))):
            jax.block_until_ready(f(*args))
        t_disp, _ = timed(lambda: jax.block_until_ready(disp(filled)),
                          repeats=5)
        t_pj, _ = timed(lambda: jax.block_until_ready(pj_j(filled)),
                        repeats=5)
        t_flood, _ = timed(lambda: jax.block_until_ready(flood_v(filled)),
                           repeats=5)
        t_pob, _ = timed(lambda: jax.block_until_ready(po_b(empty, ks)),
                         repeats=5)
        t_pov, _ = timed(lambda: jax.block_until_ready(po_v(empty, ks)),
                         repeats=5)
        entry.update({
            "winner_dispatch_s": t_disp,
            "winner_pointer_doubling_jnp_s": t_pj,
            "winner_floodfill_vmap_s": t_flood,
            "winner_eval_per_s": W / t_disp,
            "playout_batched_s": t_pob,
            "playout_vmap_s": t_pov,
            "playout_eval_per_s": W / t_pob,
            "playout_batched_speedup_vs_vmap": t_pov / t_pob,
        })
        hw[f"{size}x{size}W{W}"] = entry
    out["hex_winner"] = hw

    # gomoku eval — the second Game workload's fused playout stage
    # (completion-time resolution over a random fill) vs the sequential
    # per-lane move-loop oracle. One jitted jnp path on every backend
    # (no Pallas body yet — ROADMAP), so `dispatch` is backend-invariant.
    from repro.core import game as game_mod

    gk = {}
    for (size, W) in [(9, 16), (11, 64)]:
        g = game_mod.make_game("gomoku", size)
        ks = jax.random.split(jax.random.fold_in(key, 9000 + size * W), W)
        empty = jnp.tile(g.init_board()[None], (W, 1))
        po_b = jax.jit(lambda b, k, g=g: g.playout_batch(b, 1, k))
        po_v = jax.jit(jax.vmap(
            lambda b, k, g=g: g.playout_scalar(b, jnp.int32(1), k)))
        vals_b = jax.block_until_ready(po_b(empty, ks))
        vals_v = jax.block_until_ready(po_v(empty, ks))
        t_b, _ = timed(lambda: jax.block_until_ready(po_b(empty, ks)),
                       repeats=5)
        t_v, _ = timed(lambda: jax.block_until_ready(po_v(empty, ks)),
                       repeats=5)
        gk[f"{size}x{size}W{W}"] = {
            "dispatch": "jnp_completion_scan",
            "batched_vs_scalar_agreement": float(
                (np.asarray(vals_b) == np.asarray(vals_v)).mean()),
            "draw_fraction": float((np.asarray(vals_b) == 0).mean()),
            "playout_batched_s": t_b,
            "playout_scalar_vmap_s": t_v,
            "playout_eval_per_s": W / t_b,
            "playout_batched_speedup_vs_scalar": t_v / t_b,
        }
    out["gomoku_eval"] = gk

    # rmsnorm
    rn = {}
    for shape in [(4096, 1024), (256, 8192)]:
        x = jax.random.normal(jax.random.fold_in(key, shape[1]), shape,
                              jnp.float32)
        w = jnp.ones((shape[-1],), jnp.float32)
        got = ops.rmsnorm(x, w)
        want = ref.rmsnorm(x, w)
        err = float(jnp.max(jnp.abs(got - want)))
        oracle = jax.jit(lambda x, w: ref.rmsnorm(x, w))
        jax.block_until_ready(oracle(x, w))
        t, _ = timed(lambda: jax.block_until_ready(oracle(x, w)), repeats=3)
        gb = 2 * x.size * 4 / 1e9
        rn[f"{shape[0]}x{shape[1]}"] = {
            "max_err_vs_oracle": err,
            "checked_path": ("pallas_compiled" if ON_TPU
                             else "pallas_interpret_validation_only"),
            "timed_path": "jnp_oracle",
            "oracle_s": t, "oracle_gbps": gb / t}
    out["rmsnorm"] = rn
    return out


if __name__ == "__main__":
    import json

    from benchmarks.common import save_result
    r = run()
    print(json.dumps(r, indent=1))
    save_result("kernels_micro", r)
