"""Paper Fig 9 — measured GSCPM speedup overlaid on the Cilkview bound.

Runs the Fig 7 measurement for the FIFO discipline and compares each point
against the analytic work/span bound with a dispatch burden fitted from the
measured per-round overhead — reproducing the paper's observation that
measured speedup tracks the bound up to ~256 tasks and then departs due to
scheduling overheads.

Two burden fits produce two bound curves per point:

- ``bound`` — the original single-point calibration: solve ``t_round`` so
  the bound meets the measured speedup at the finest grain;
- ``bound_measured`` — the observability-plane fit (DESIGN.md §15): traced
  searches across the grain sweep record per-round ``gscpm_round`` spans,
  ``repro.obsv.profile.fit_dispatch_profile`` least-squares the per-round
  dispatch cost and per-iteration device cost out of the span durations,
  and the resulting ``DagModel`` carries MEASURED ``t_spawn``/``t_round``
  instead of guessed constants.
"""

from __future__ import annotations

import jax

from repro.core import game as game_mod
from repro.core.cilkview import DagModel, speedup_bound
from repro.core.gscpm import GSCPMConfig, gscpm_search

from benchmarks import fig7_speedup


def measure_dispatch_profile(n_playouts: int, n_workers: int,
                             board_size: int, task_counts,
                             seed: int = 0) -> dict:
    """Traced searches across the grain sweep -> fitted burden terms.

    One warm-up search per grain compiles the program (compile-tainted
    spans are additionally excluded by the fitter); the traced pass blocks
    per round, so span durations include the device work they dispatched.
    """
    from repro.obsv import TraceRecorder
    from repro.obsv.profile import fit_dispatch_profile

    tracer = TraceRecorder(process_name="fig9-profile")
    board = game_mod.make_game("hex", board_size).init_board()
    key = jax.random.key(seed)
    tree_cap = max(1 << 14, 4 * n_playouts)
    for n_tasks in task_counts:
        cfg = GSCPMConfig(game="hex", board_size=board_size,
                          n_playouts=n_playouts, n_tasks=n_tasks,
                          n_workers=n_workers, tree_cap=tree_cap)
        gscpm_search(board, 1, cfg, key)              # warm-up/compile
        gscpm_search(board, 1, cfg, key, tracer=tracer)
    return fit_dispatch_profile(tracer, n_workers=n_workers)


def run(n_playouts: int = 2048, n_workers: int = 16,
        board_size: int = 9) -> dict:
    measured = fig7_speedup.run(
        n_playouts=n_playouts, n_workers=n_workers, board_size=board_size,
        schedulers=("fifo",))
    seq_rate = measured["sequential_playouts_per_s"]
    t_iter = 1.0
    # fit the per-round dispatch burden from the finest-grain point
    pts = measured["curves"]["fifo"]
    finest = max(int(t) for t in pts)
    meas_fine = pts[str(finest)]["speedup"]
    grain = max(1, n_playouts // finest)
    # solve burden so bound(finest) == measured(finest)
    import math
    rounds = math.ceil(finest / n_workers)
    t1 = finest * grain
    tinf = grain + finest * 0.002
    tp_needed = t1 / max(meas_fine, 1e-9)
    t_round = max(0.0, (tp_needed - max(t1 / n_workers, tinf)) / rounds)

    # the observability-plane fit: measured spans -> measured burden terms
    from repro.obsv.profile import measured_dag_model
    profile = measure_dispatch_profile(
        n_playouts, n_workers, board_size,
        task_counts=sorted({int(t) for t in pts})[-3:])
    model_measured = measured_dag_model(profile)

    model = DagModel(t_iter=t_iter, t_spawn=0.002, t_round=t_round)
    overlay = {}
    for t_str, p in pts.items():
        t = int(t_str)
        g = max(1, n_playouts // t)
        overlay[t_str] = {
            "measured": p["speedup"],
            "bound": speedup_bound(t, g, n_workers, model),
            "bound_measured": speedup_bound(t, g, n_workers, model_measured),
        }
    return {
        "n_playouts": n_playouts,
        "n_workers": n_workers,
        "fitted_t_round": t_round,
        "measured_t_round": profile["t_round_units"],
        "measured_t_spawn": profile["t_spawn_units"],
        "dispatch_profile": profile,
        "sequential_playouts_per_s": seq_rate,
        "overlay": overlay,
    }


if __name__ == "__main__":
    import json

    from benchmarks.common import save_result
    r = run()
    print(json.dumps(r, indent=1))
    save_result("fig9_mapping", r)
