"""Paper Fig 9 — measured GSCPM speedup overlaid on the Cilkview bound.

Runs the Fig 7 measurement for the FIFO discipline and compares each point
against the analytic work/span bound with a dispatch burden fitted from the
measured per-round overhead — reproducing the paper's observation that
measured speedup tracks the bound up to ~256 tasks and then departs due to
scheduling overheads.
"""

from __future__ import annotations

from repro.core.cilkview import DagModel, speedup_bound

from benchmarks import fig7_speedup


def run(n_playouts: int = 2048, n_workers: int = 16,
        board_size: int = 9) -> dict:
    measured = fig7_speedup.run(
        n_playouts=n_playouts, n_workers=n_workers, board_size=board_size,
        schedulers=("fifo",))
    seq_rate = measured["sequential_playouts_per_s"]
    t_iter = 1.0
    # fit the per-round dispatch burden from the finest-grain point
    pts = measured["curves"]["fifo"]
    finest = max(int(t) for t in pts)
    meas_fine = pts[str(finest)]["speedup"]
    grain = max(1, n_playouts // finest)
    # solve burden so bound(finest) == measured(finest)
    import math
    rounds = math.ceil(finest / n_workers)
    t1 = finest * grain
    tinf = grain + finest * 0.002
    tp_needed = t1 / max(meas_fine, 1e-9)
    t_round = max(0.0, (tp_needed - max(t1 / n_workers, tinf)) / rounds)

    model = DagModel(t_iter=t_iter, t_spawn=0.002, t_round=t_round)
    overlay = {}
    for t_str, p in pts.items():
        t = int(t_str)
        g = max(1, n_playouts // t)
        overlay[t_str] = {
            "measured": p["speedup"],
            "bound": speedup_bound(t, g, n_workers, model),
        }
    return {
        "n_playouts": n_playouts,
        "n_workers": n_workers,
        "fitted_t_round": t_round,
        "sequential_playouts_per_s": seq_rate,
        "overlay": overlay,
    }


if __name__ == "__main__":
    import json

    from benchmarks.common import save_result
    r = run()
    print(json.dumps(r, indent=1))
    save_result("fig9_mapping", r)
