"""Benchmark aggregator: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper table/figure (CPU-scaled budgets), the kernel
microbenches, and the roofline-table render; writes JSON artifacts to
artifacts/bench/ and prints a summary. Pass --full for the larger budgets.

When the run includes fig7 (and optionally tpfifo / serve_games), it also
writes a root-level ``BENCH_mcts.json`` trajectory summary — search
playouts/s, best serving speedup, and mixed-game move-latency percentiles
for this host/backend — so the perf trajectory accumulates across PRs (CI
uploads it as an artifact per commit).
"""

from __future__ import annotations

import argparse
import json
import os
import time
import traceback


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="larger playout budgets (several minutes)")
    p.add_argument("--only", default=None,
                   help="comma-separated subset, e.g. table2,fig7")
    args = p.parse_args()

    from benchmarks import (ablate_vloss, fig5_cilkview, fig7_speedup,
                            fig9_mapping, kernels_micro, roofline_table,
                            root_parallel, selfplay, serve_chaos,
                            serve_games, table2_sequential, tpfifo)
    from benchmarks.common import save_result

    n_po = 8192 if args.full else 1024
    jobs = {
        "table2_sequential": lambda: table2_sequential.run(n_playouts=n_po),
        "fig5_cilkview": lambda: fig5_cilkview.run(),
        "fig7_speedup": lambda: fig7_speedup.run(
            n_playouts=n_po, n_workers=16,
            task_sweep=(4, 8, 16, 32, 64, 128, 256, 512) if args.full
            else (4, 16, 64, 256)),
        # the same sweep through the Game seam on the second workload
        # (smaller budget: the gomoku smoke guards the seam, the hex run
        # stays the perf headline with stable BENCH_mcts.json keys)
        "fig7_gomoku": lambda: fig7_speedup.run(
            n_playouts=n_po if args.full else n_po // 2, n_workers=16,
            game="gomoku", task_sweep=(4, 16, 64, 256) if args.full
            else (16, 64)),
        "fig9_mapping": lambda: fig9_mapping.run(n_playouts=n_po),
        "kernels_micro": lambda: kernels_micro.run(),
        "ablate_vloss": lambda: ablate_vloss.run(n_playouts=n_po),
        "roofline_table": lambda: roofline_table.run(),
        "root_parallel": lambda: root_parallel.run(n_playouts=n_po),
        "tpfifo": lambda: tpfifo.run(n_requests=48 if args.full else 24),
        "serve_games": lambda: serve_games.run(
            n_requests=32 if args.full else 16),
        # fault-rate sweep: goodput/latency under injected chaos with
        # bit-identical recovery + zero recompiles asserted inside
        "serve_chaos": lambda: serve_chaos.run(
            n_requests=32 if args.full else 16),
        "selfplay": lambda: selfplay.run(
            n_playouts=4096 if args.full else 1024,
            max_moves=20 if args.full else 12),
    }
    if args.only:
        keep = {k.strip() for k in args.only.split(",")}
        jobs = {k: v for k, v in jobs.items() if any(s in k for s in keep)}

    failures = []
    results: dict[str, dict] = {}
    for name, job in jobs.items():
        t0 = time.perf_counter()
        print(f"=== {name} ===", flush=True)
        try:
            res = job()
            results[name] = res
            path = save_result(name, res)
            print(json.dumps(_summ(name, res), indent=1))
            print(f"[{name}] ok in {time.perf_counter()-t0:.1f}s -> {path}\n",
                  flush=True)
        except Exception as e:
            failures.append(name)
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    traj = write_mcts_trajectory(results)
    if traj:
        print(f"perf trajectory -> {traj}")
    print("benchmarks complete;",
          f"{len(jobs) - len(failures)}/{len(jobs)} ok",
          ("FAILED: " + ", ".join(failures)) if failures else "")
    raise SystemExit(1 if failures else 0)


def write_mcts_trajectory(results: dict) -> str | None:
    """Write root-level BENCH_mcts.json from a run containing fig7.

    The accumulating perf headline of the repo: best search throughput
    (fig7's playouts/s sweep) plus the best TPFIFO serving speedup when
    that benchmark also ran. One file per host/backend snapshot — CI
    uploads it per commit so regressions are visible as a trajectory.
    """
    fig7 = results.get("fig7_speedup")
    if not fig7:
        return None
    import jax

    def best_of(res):
        rate, point = 0.0, {}
        for sched, pts in res["curves"].items():
            for n_tasks, p in pts.items():
                if p["playouts_per_s"] > rate:
                    rate = p["playouts_per_s"]
                    point = {"scheduler": sched, "n_tasks": int(n_tasks)}
        return rate, point

    best_rate, best_point = best_of(fig7)
    seq = fig7["sequential_playouts_per_s"]
    payload = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git_sha": _git_sha(),
        "backend": jax.default_backend(),
        # both axes of the host: OS cores (what the paper's thread scaling
        # is against) AND visible JAX devices (what shard_map scales over —
        # 1 unless XLA_FLAGS forces virtual host devices)
        "host_cores": os.cpu_count(),
        "n_devices": len(jax.devices()),
        "board": fig7["board"],
        "n_workers": fig7["n_workers"],
        "n_playouts": fig7["n_playouts"],
        "sequential_playouts_per_s": seq,
        "best_playouts_per_s": best_rate,
        "best_point": best_point,
        "best_speedup_vs_sequential": best_rate / max(seq, 1e-9),
    }
    # per-game search throughput (the existing top-level keys stay the Hex
    # headline so the perf trajectory remains comparable across PRs)
    games = {}
    for name, res in results.items():
        if name.startswith("fig7") and "curves" in res:
            rate, point = best_of(res)
            games[res.get("game", "hex")] = {
                "board": res["board"],
                "sequential_playouts_per_s": res[
                    "sequential_playouts_per_s"],
                "best_playouts_per_s": rate,
                "best_point": point,
            }
    if games:
        payload["games"] = games
    if "tpfifo" in results:
        payload["tpfifo_best_speedup"] = results["tpfifo"]["best_speedup"]
    if "serve_games" in results:
        # mixed hex+gomoku Poisson serving: move-latency percentiles,
        # playouts/s, and the zero-recompile ledger (see serve_games.py)
        payload["serving"] = results["serve_games"]["serving"]
        # async retirement pipelining vs blocking on the same trace, with
        # per-request bit-identity asserted in-run (DESIGN.md §18)
        payload["pipeline"] = results["serve_games"]["pipeline"]
    if "root_parallel" in results:
        # shard_map forest scale-out point (subprocess workers on 1 and 8
        # virtual host devices; see root_parallel.sharded_forest)
        payload["sharded_forest"] = results["root_parallel"].get(
            "sharded_forest")
    if "selfplay" in results:
        # cross-move tree reuse: warm vs cold move latency and the mean
        # visits-retained fraction over a self-play game (see selfplay.py)
        payload["selfplay"] = results["selfplay"]["selfplay"]
    if "serve_chaos" in results:
        # resilience: goodput/p50/p95 vs injected fault rate, with
        # bit-identical recovery and zero recompiles asserted in-run
        payload["chaos"] = results["serve_chaos"]["chaos"]
    km = results.get("kernels_micro")
    if km and "hex_winner" in km:
        # fused playout-evaluation throughput per (board, W) case + the
        # headline (best batched rate) — the playout-phase twin of
        # best_playouts_per_s
        cases = {k: v["playout_eval_per_s"]
                 for k, v in km["hex_winner"].items()}
        payload["playout_eval_per_s"] = max(cases.values())
        payload["playout_eval_per_s_by_case"] = cases
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_mcts.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return os.path.abspath(path)


def _git_sha() -> str | None:
    """Commit the trajectory point describes (None outside a git checkout —
    the artifact must never make the benchmark run fail)."""
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10,
            check=True).stdout.strip()
    except Exception:
        return None


def _summ(name: str, res: dict) -> dict:
    """Console-sized digest per benchmark."""
    if name == "table2_sequential":
        return {k: res[k] for k in ("n_playouts", "time_s", "per_playout_us",
                                    "extrapolated_paper_budget_s")}
    if name == "fig5_cilkview":
        b = res["speedup_bounds"]
        i61 = res["core_counts"].index(61)
        return {"bound_61c_16384t": b["16384"][i61],
                "bound_61c_64t": b["64"][i61]}
    if name.startswith("fig7"):
        return {s: {t: round(p["speedup"], 2) for t, p in pts.items()}
                for s, pts in res["curves"].items()}
    if name == "root_parallel":
        out = {f"E={e}": round(p["aggregate_speedup"], 2)
               for e, p in res["ensemble"].items()}
        sf = res.get("sharded_forest") or {}
        if "speedup_vs_single_device" in sf:
            out["sharded_vs_1dev"] = round(sf["speedup_vs_single_device"], 2)
        return out
    if name == "fig9_mapping":
        return {t: {k: round(v, 2) for k, v in o.items()}
                for t, o in res["overlay"].items()}
    if name == "kernels_micro":
        return {k: list(v) for k, v in res.items()}
    if name == "ablate_vloss":
        return {r: {"tree_nodes": v["tree_nodes"],
                    "playouts_per_s": round(v["playouts_per_s"])}
                for r, v in res["results"].items()}
    if name == "tpfifo":
        return {"lockstep_tok_s": round(res["lockstep"]["throughput_tok_s"]),
                "speedups": {m: round(r["speedup_vs_lockstep"], 2)
                             for m, r in res["tpfifo"].items()},
                "best": round(res["best_speedup"], 2),
                "pass": res["acceptance"]["pass"]}
    if name == "serve_games":
        s = res["serving"]
        return {"playouts_per_s": round(s["playouts_per_s"]),
                "move_latency_ms": {"p50": round(
                    s["move_latency_p50_s"] * 1e3),
                    "p95": round(s["move_latency_p95_s"] * 1e3)},
                "p50_vs_one_per_core": round(s["p50_vs_one_per_core"], 2),
                "p95_vs_one_per_core": round(s["p95_vs_one_per_core"], 2),
                "preemptions": s["preemptions"],
                "recompiles": s["recompiles"],
                "pipeline_speedup": round(res["pipeline"]["speedup"], 2)}
    if name == "serve_chaos":
        c = res["chaos"]
        return {"fault_rates": c["fault_rates"],
                "goodput_playouts_per_s": [round(g) for g in
                                           c["goodput_playouts_per_s"]],
                "latency_p95_ms": [round(v * 1e3) for v in
                                   c["latency_p95_s"]],
                "retries": c["retries"],
                "quarantined": c["quarantined"],
                "goodput_at_max_rate_vs_clean": round(
                    c["goodput_at_max_rate_vs_clean"], 2),
                "recompiles": c["recompiles"]}
    if name == "selfplay":
        s = res["selfplay"]
        return {"warm_p50_ms": round(s["warm_move_p50_s"] * 1e3),
                "cold_p50_ms": round(s["cold_move_p50_s"] * 1e3),
                "p50_speedup": round(s["p50_speedup_warm_vs_cold"], 2),
                "mean_retained_fraction": round(
                    s["mean_retained_fraction"], 3),
                "recompiles": s["recompiles"]}
    if name == "roofline_table":
        return {"n_ok": res["n_ok"], "n_cells": res["n_cells"]}
    return {}


if __name__ == "__main__":
    main()
