"""Ablation: virtual-loss rounds — the TPU analogue of the paper's locks.

With W lanes selecting against one tree snapshot, simultaneous selections
collide (search overhead — the phenomenon the paper handles with local
locks + atomic w/n; DESIGN.md §2 maps it to virtual-loss rounds R).
R=1 is maximally parallel (most collisions); R=W degenerates toward
sequential selection (none). The ablation measures search QUALITY at a
fixed playout budget: tree size (diversity) and root-child coverage vs R,
plus throughput cost per round.
"""

from __future__ import annotations

import jax

from repro.core import hex as hx
from repro.core.gscpm import GSCPMConfig, gscpm_search


def run(n_playouts: int = 1024, n_workers: int = 16, board_size: int = 9,
        rounds=(1, 2, 4, 8), seed: int = 0) -> dict:
    spec = hx.HexSpec(board_size)
    board = hx.empty_board(spec)
    key = jax.random.key(seed)
    out = {}
    for r in rounds:
        cfg = GSCPMConfig(board_size=board_size, n_playouts=n_playouts,
                          n_tasks=64, n_workers=n_workers, vl_rounds=r,
                          tree_cap=1 << 14, scheduler="fifo")
        gscpm_search(board, 1, cfg, key)            # warm-up
        tree, st = gscpm_search(board, 1, cfg, key)
        import numpy as np
        n_root = int(tree.n_children[0])
        out[str(r)] = {
            "tree_nodes": st["tree_nodes"],
            "root_children": n_root,
            "playouts_per_s": st["playouts_per_s"],
            "root_value": st["root_value"],
            "best_move": st["best_move"],
        }
    return {"n_playouts": n_playouts, "n_workers": n_workers,
            "rounds": list(rounds), "results": out}


if __name__ == "__main__":
    import json

    from benchmarks.common import save_result
    r = run()
    print(json.dumps(r, indent=1))
    save_result("ablate_vloss", r)
