"""TPFIFO vs lockstep serving under a Poisson arrival trace.

The serving analogue of the paper's Table I grain sweep: the same request
trace is replayed against the lockstep slot engine (one decode step per
tick, whole-prompt prefill per admission) and against the TPFIFO
work-sharing queue at several grain sizes (``m`` unified prefill/decode
micro-steps per jitted dispatch). On a dispatch-bound host, coarser grains
amortize the per-dispatch overhead across ``m`` micro-steps of every slot —
throughput rises with ``m`` until the quantum tail (dead lanes riding to
the quantum boundary) eats the gain, exactly the paper's fine-vs-coarse
grain tradeoff.

Acceptance: best TPFIFO throughput >= 1.3x lockstep on a mixed-length
Poisson trace (CPU host, smoke scale).

    PYTHONPATH=src python benchmarks/tpfifo.py [--smoke|--full]
"""

from __future__ import annotations

import os
import sys

import jax
import numpy as np

if __package__ in (None, ""):   # `python benchmarks/tpfifo.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro import configs
from repro.models import api
from repro.serve.engine import Request, SlotEngine
from repro.serve.tpfifo import TPFIFOEngine

ACCEPT_SPEEDUP = 1.3


def make_trace(n_requests: int, rate_rps: float, max_new: int,
               short_lens, long_lens, vocab: int, seed: int):
    """Poisson arrivals, bimodal prompt lengths (the irregular workload)."""
    rng = np.random.default_rng(seed)
    trace, t = [], 0.0
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        lens = long_lens if rid % 3 == 2 else short_lens
        plen = int(rng.integers(lens[0], lens[1] + 1))
        prompt = rng.integers(1, vocab, size=(plen,)).astype(np.int32)
        trace.append((t, dict(rid=rid, prompt=prompt, max_new=max_new)))
    return trace


def _requests(trace):
    return [(t, Request(rid=r["rid"], prompt=r["prompt"].copy(),
                        max_new=r["max_new"])) for t, r in trace]


def serve_trace(engine, trace) -> dict:
    done = engine.run_trace(_requests(trace))
    st = engine.stats()
    assert st.n_finished == len(trace), \
        f"only {st.n_finished}/{len(trace)} requests finished"
    out = st.as_dict()
    out["ticks"] = engine._ticks
    return out


def run(n_requests: int = 24, slots: int = 4, max_len: int = 96,
        max_new: int = 48, rate_rps: float = 200.0,
        grains=(1, 4, 8, 16, 32), policies=("fifo", "rebalance",
                                            "one_per_core"),
        short_lens=(4, 10), long_lens=(16, 40), seed: int = 0,
        smoke: bool = False) -> dict:
    # decode-heavy mixed-length trace: generation dominates the prompt (the
    # usual serving regime); TPFIFO replays prompts token-by-token through
    # the quantum (chunked prefill), so a prefill-heavy trace measures that
    # replay, not the grain amortization under test
    if smoke:
        n_requests, max_new, grains = 6, 24, (8,)
        short_lens, long_lens, max_len = (4, 8), (10, 16), 48
        policies = ("fifo",)

    cfg = configs.reduced_config("smollm-135m").replace(n_layers=2)
    params = api.init_params(cfg, jax.random.key(seed))
    trace = make_trace(n_requests, rate_rps, max_new, short_lens, long_lens,
                       cfg.vocab, seed)
    # warm-up covers every distinct prompt length in the trace: the lockstep
    # engine's per-admission whole-prompt prefill compiles once per length
    # (TPFIFO's chunked prefill is shape-stable and needs no such warming),
    # so without this the baseline measures compilation, not serving
    # max_new=2 so warming also reaches the decode step (a max_new=1
    # request completes at admission and never decodes)
    seen, warm = set(), []
    for t, r in trace:
        if len(r["prompt"]) not in seen:
            seen.add(len(r["prompt"]))
            warm.append((0.0, dict(r, max_new=2)))

    def lockstep():
        return SlotEngine(params, cfg, n_slots=slots, max_len=max_len,
                          eos_id=-1, seed=seed)

    def tpfifo(grain, policy="fifo"):
        return TPFIFOEngine(params, cfg, n_slots=slots, max_len=max_len,
                            grain=grain, policy=policy, eos_id=-1, seed=seed)

    # compile everything off the clock
    serve_trace(lockstep(), warm)
    serve_trace(tpfifo(grains[0]), warm)

    lock = serve_trace(lockstep(), trace)
    sweep = {}
    for g in grains:
        r = serve_trace(tpfifo(g), trace)
        r["speedup_vs_lockstep"] = (r["throughput_tok_s"]
                                    / lock["throughput_tok_s"])
        sweep[str(g)] = r
    best_g = max(sweep, key=lambda g: sweep[g]["throughput_tok_s"])
    pol = {}
    for p in policies:
        if p == "fifo":
            continue       # already measured in the grain sweep
        r = serve_trace(tpfifo(int(best_g), policy=p), trace)
        r["speedup_vs_lockstep"] = (r["throughput_tok_s"]
                                    / lock["throughput_tok_s"])
        pol[p] = r
    best = sweep[best_g]["speedup_vs_lockstep"]
    return {
        "config": {"n_requests": n_requests, "slots": slots,
                   "max_len": max_len, "max_new": max_new,
                   "rate_rps": rate_rps, "short_lens": list(short_lens),
                   "long_lens": list(long_lens), "seed": seed,
                   "smoke": smoke},
        "lockstep": lock,
        "tpfifo": sweep,
        "policies_at_best_grain": pol,
        "best_grain": int(best_g),
        "best_speedup": best,
        "acceptance": {"threshold": ACCEPT_SPEEDUP, "pass": best >= ACCEPT_SPEEDUP},
    }


def main():
    import argparse

    from benchmarks.common import save_result

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny trace (CI rot-guard, <1 min)")
    p.add_argument("--full", action="store_true")
    args = p.parse_args()

    out = run(smoke=args.smoke,
              n_requests=48 if args.full else 24)
    lk = out["lockstep"]
    print(f"lockstep : {lk['throughput_tok_s']:8.1f} tok/s   "
          f"p50/p95 latency {lk['latency_p50']*1e3:6.0f}/"
          f"{lk['latency_p95']*1e3:6.0f} ms")
    for g, r in out["tpfifo"].items():
        print(f"tpfifo m={g:>2}: {r['throughput_tok_s']:8.1f} tok/s   "
              f"p50/p95 latency {r['latency_p50']*1e3:6.0f}/"
              f"{r['latency_p95']*1e3:6.0f} ms   "
              f"{r['speedup_vs_lockstep']:5.2f}x")
    for pname, r in out["policies_at_best_grain"].items():
        print(f"policy {pname:>12} @m={out['best_grain']}: "
              f"{r['throughput_tok_s']:8.1f} tok/s   "
              f"{r['speedup_vs_lockstep']:5.2f}x")
    path = save_result("tpfifo", out)
    print("->", path)
    acc = out["acceptance"]
    print(f"acceptance (best tpfifo >= {acc['threshold']}x lockstep): "
          f"{'PASS' if acc['pass'] else 'FAIL'} ({out['best_speedup']:.2f}x "
          f"at grain {out['best_grain']})")


if __name__ == "__main__":
    main()
