"""Shared benchmark harness utilities."""

from __future__ import annotations

import json
import os
import time

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def save_result(name: str, payload: dict) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return os.path.abspath(path)


def timed(fn, *args, repeats: int = 1, **kw):
    """(min wall seconds, last result) over `repeats` calls."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out
