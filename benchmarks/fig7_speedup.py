"""Paper Fig 7/8 — GSCPM speedup vs nTasks, per scheduling discipline.

The paper's axes: x = nTasks (grain), y = speedup over sequential, one
curve per threading library. Our TPU-native mapping (DESIGN.md §2): one
curve per scheduler discipline x lane width; "speedup" is playout
throughput relative to the sequential searcher on the same host. The
qualitative reproduction targets:

  (1) speedup rises with nTasks until tasks ~ saturate the lanes
      (coarse-grain starvation, Table I top row),
  (2) too-fine grains pay per-round dispatch overhead (Table I bottom row),
  (3) plain FIFO work-sharing is equal-or-better than the rebalancing
      (stealing-analogue) discipline — the paper's headline surprise,
  (4) one-task-per-core underperforms grain-size control (the paper's 31x
      vs 47x on the Phi).
"""

from __future__ import annotations

import jax

from repro.core import game as game_mod
from repro.core.gscpm import GSCPMConfig, gscpm_search
from repro.core.mcts import uct_search


def run(n_playouts: int = 2048, n_workers: int = 16, board_size: int = 9,
        task_sweep=(4, 8, 16, 32, 64, 128, 256, 512),
        schedulers=("fifo", "rebalance", "one_per_core"),
        seed: int = 0, repeats: int = 3, game: str = "hex") -> dict:
    """Each point reports the best of ``repeats`` timed searches (min-time,
    the same convention as ``benchmarks.common.timed``): the harness hosts
    are shared and noisy, and a single timed search per point made the
    recorded curves swing ~2x run-to-run. ``game`` picks any registered
    Game (the sweep itself is game-agnostic — DESIGN.md §13)."""
    g = game_mod.make_game(game, board_size)
    board = g.init_board()
    key = jax.random.key(seed)
    tree_cap = max(1 << 14, 4 * n_playouts)

    # sequential baseline (warm-up excluded, as in the paper)
    uct_search(board, 1, 64, key, board_size=board_size, tree_cap=tree_cap,
               game=game)
    seq_rate = 0.0
    for _ in range(repeats):
        _, seq = uct_search(board, 1, n_playouts, key, board_size=board_size,
                            tree_cap=tree_cap, game=game)
        seq_rate = max(seq_rate, seq["playouts_per_s"])

    curves: dict[str, dict] = {}
    for sched in schedulers:
        pts = {}
        sweep = [n_workers] if sched == "one_per_core" else task_sweep
        for n_tasks in sweep:
            cfg = GSCPMConfig(
                game=game, board_size=board_size, n_playouts=n_playouts,
                n_tasks=n_tasks, n_workers=n_workers, tree_cap=tree_cap,
                scheduler=sched)
            gscpm_search(board, 1, cfg, key)          # warm-up/compile
            best = None
            for _ in range(repeats):
                _, st = gscpm_search(board, 1, cfg, key)
                if best is None or (st["playouts_per_s"]
                                    > best["playouts_per_s"]):
                    best = st
            pts[str(n_tasks)] = {
                "speedup": best["playouts_per_s"] / seq_rate,
                "playouts_per_s": best["playouts_per_s"],
                "masked_lane_fraction": best["masked_lane_fraction"],
                "tree_nodes": best["tree_nodes"],
            }
        curves[sched] = pts
    return {
        "game": game,
        "n_playouts": n_playouts,
        "n_workers": n_workers,
        "board": f"{board_size}x{board_size}",
        "repeats": repeats,
        "sequential_playouts_per_s": seq_rate,
        "curves": curves,
    }


if __name__ == "__main__":
    import argparse
    import json

    from benchmarks.common import save_result
    ap = argparse.ArgumentParser()
    ap.add_argument("--game", default="hex",
                    choices=list(game_mod.available_games()))
    ap.add_argument("--playouts", type=int, default=2048)
    args = ap.parse_args()
    r = run(n_playouts=args.playouts, game=args.game)
    print(json.dumps(r, indent=1))
    save_result("fig7_speedup" if args.game == "hex"
                else f"fig7_{args.game}", r)
