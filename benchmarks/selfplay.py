"""Cross-move tree reuse: warm vs cold move latency over a self-play game.

The serving loop this measures is DESIGN.md §16's: a ``GameSession`` plays
a whole game through the TPFIFO engine, re-rooting its device-resident
tree after every move so each search starts from the retained subtree and
only runs the REMAINDER of its evidence budget (``serve.games.warm_budget``
— ``n_playouts`` means total root evidence, warm or cold). At every
position along the trajectory a paired COLD request (same position, same
budget, fresh tree, stateless) is served through the same engine, so the
two arms see identical scheduler overhead and an identical position
sequence; the trajectory itself always advances with the warm arm's move.

Reported per game: warm vs cold p50/p95 move latency, mean visits-retained
fraction, and the compile ledger — the whole game (re-roots included) must
add ZERO ``run_chunk`` entries beyond the per-class warm-up (asserted).
Feeds BENCH_mcts.json under the ``selfplay`` key.

    PYTHONPATH=src python benchmarks/selfplay.py [--smoke|--full]
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/selfplay.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro.core.gscpm import run_chunk
from repro.serve.games import GameRequest, GameSession, TPFIFOGameEngine

GAMES = ("hex", "gomoku")


def _serve_one(eng, req) -> float:
    """Submit one request, run it to completion, return wall seconds."""
    t0 = time.perf_counter()
    eng.submit(req)
    eng.run()
    return time.perf_counter() - t0


def play_paired_game(eng, game: str, board_size: int, *, n_playouts: int,
                     n_tasks: int, max_moves: int, seed: int,
                     cp: float = 0.25) -> dict:
    """One self-play trajectory with a paired cold search at every position.

    The session (one tenant playing both sides — the strongest-retention
    arm: exactly one re-root per move) produces the warm measurements and
    the moves; each cold measurement is a stateless request for the same
    position and the same total budget, served by the same engine.

    ``cp`` defaults LOW (0.25): self-play move selection exploits, and an
    exploration-heavy root (cp=1.0 spreads 1024 visits nearly uniformly
    over 49 children on 7x7) leaves the played child only ~1/49 of the
    evidence — retention, and therefore the warm arm's whole advantage,
    is a property of how concentrated the root visits are. Both arms use
    the same cp, so the pairing stays fair.
    """
    sess = GameSession(eng, game, board_size, base_seed=seed,
                       name=f"bench-{game}")
    warm_s, cold_s, retained = [], [], []
    for mvno in range(max_moves):
        # cold arm first (its tree is dropped at retirement; ordering
        # cannot leak state into the warm arm)
        cold = GameRequest(
            rid=f"cold-{game}#{mvno}", game=game, board_size=board_size,
            to_move=sess.to_move, n_playouts=n_playouts, n_tasks=n_tasks,
            cp=cp, seed=seed + mvno, board=np.asarray(sess.board))
        cold_s.append(_serve_one(eng, cold))

        req = sess.make_request(n_playouts=n_playouts, n_tasks=n_tasks,
                                cp=cp)
        warm_s.append(_serve_one(eng, req))
        res = req.result
        retained.append(res["reused_visits"] / n_playouts)

        mv = res["best_move"]
        if mv < 0:
            break
        sess.play(mv)
        if sess.winner() >= 0:
            break
    return {
        "game": game,
        "n_moves": len(warm_s),
        "warm_latency_s": warm_s,
        "cold_latency_s": cold_s,
        "retained_fractions": retained,
        "warm_p50_s": float(np.percentile(warm_s, 50)),
        "warm_p95_s": float(np.percentile(warm_s, 95)),
        "cold_p50_s": float(np.percentile(cold_s, 50)),
        "cold_p95_s": float(np.percentile(cold_s, 95)),
        "mean_retained_fraction": float(np.mean(retained)),
        "p50_speedup": float(np.percentile(cold_s, 50)
                             / max(np.percentile(warm_s, 50), 1e-9)),
    }


def run(n_playouts: int = 1024, n_tasks: int = 128, board_size: int = 7,
        max_moves: int = 12, n_workers: int = 8, grain: int = 4,
        tree_cap: int | None = None, seed: int = 0,
        smoke: bool = False) -> dict:
    # n_tasks defaults HIGH (m = 1024/128 = 8): warm time savings are
    # quantized to whole schedule rounds (masked lanes still compute), so
    # fine task grain is what converts retained visits into latency —
    # at m=32 a warm search must retain n_workers*32 visits to drop one
    # round; at m=8 the savings track the retained fraction near-linearly
    if smoke:
        n_playouts, n_tasks, board_size, max_moves = 64, 8, 5, 3
    cap = tree_cap or max(2048, 4 * n_playouts)

    eng = TPFIFOGameEngine(n_slots=2, grain=grain, n_workers=n_workers,
                           tree_cap=cap)

    # compile off the clock: one tiny search per game class warms the one
    # quantum program each class ever gets; the whole benchmark (warm and
    # cold arms, re-roots, every budget size) must then add nothing
    for g in GAMES:
        _serve_one(eng, GameRequest(rid=f"warm-{g}", game=g,
                                    board_size=board_size, n_playouts=8,
                                    n_tasks=2, seed=0))
    cache_before = run_chunk._cache_size()

    games = {}
    for g in GAMES:
        games[g] = play_paired_game(eng, g, board_size,
                                    n_playouts=n_playouts, n_tasks=n_tasks,
                                    max_moves=max_moves, seed=seed)
    recompiles = run_chunk._cache_size() - cache_before
    assert recompiles == 0, \
        f"self-play (with re-rooting) grew the jit cache by {recompiles}"

    best = max(games.values(), key=lambda s: s["p50_speedup"])
    return {
        "config": {"n_playouts": n_playouts, "n_tasks": n_tasks,
                   "board_size": board_size, "max_moves": max_moves,
                   "n_workers": n_workers, "grain": grain, "tree_cap": cap,
                   "cp": 0.25, "seed": seed, "smoke": smoke},
        "games": games,
        "selfplay": {
            "board": f"{board_size}x{board_size}",
            "n_playouts": n_playouts,
            "warm_move_p50_s": best["warm_p50_s"],
            "warm_move_p95_s": best["warm_p95_s"],
            "cold_move_p50_s": best["cold_p50_s"],
            "cold_move_p95_s": best["cold_p95_s"],
            "mean_retained_fraction": float(np.mean(
                [s["mean_retained_fraction"] for s in games.values()])),
            "p50_speedup_warm_vs_cold": best["p50_speedup"],
            "best_game": best["game"],
            "recompiles": recompiles,
            "per_game": {g: {
                "warm_p50_s": s["warm_p50_s"],
                "cold_p50_s": s["cold_p50_s"],
                "p50_speedup": s["p50_speedup"],
                "mean_retained_fraction": s["mean_retained_fraction"],
            } for g, s in games.items()},
        },
    }


def main():
    import argparse

    from benchmarks.common import save_result

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny game (CI rot-guard, <1 min)")
    p.add_argument("--full", action="store_true")
    args = p.parse_args()

    out = run(smoke=args.smoke,
              n_playouts=4096 if args.full else 1024,
              max_moves=20 if args.full else 12)
    for g, s in out["games"].items():
        print(f"{g:>8}: warm p50/p95 {s['warm_p50_s']*1e3:6.0f}/"
              f"{s['warm_p95_s']*1e3:6.0f} ms   cold p50/p95 "
              f"{s['cold_p50_s']*1e3:6.0f}/{s['cold_p95_s']*1e3:6.0f} ms   "
              f"retained {s['mean_retained_fraction']:.2f}   "
              f"p50 speedup {s['p50_speedup']:.2f}x")
    s = out["selfplay"]
    print(f"best ({s['best_game']}): warm beats cold "
          f"{s['p50_speedup_warm_vs_cold']:.2f}x at p50; "
          f"recompiles during self-play: {s['recompiles']}")
    path = save_result("selfplay", out)
    print("->", path)


if __name__ == "__main__":
    main()
