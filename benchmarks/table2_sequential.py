"""Paper Table II — sequential baseline time.

The paper: 1,048,576 playouts of 11x11 Hex, sequential, on Xeon CPU
(21.47 s) and Xeon Phi (185.37 s). Here: the same sequential UCT search on
this host at a scaled playout budget; we report per-playout time and the
extrapolated full-budget time. The absolute numbers are hardware-specific
(documented in EXPERIMENTS.md); the deliverable is the baseline every
speedup in Fig 7/8 is measured against.
"""

from __future__ import annotations

import jax

from repro.configs.hex_paper import PAPER
from repro.core import hex as hx
from repro.core.mcts import uct_search


def run(n_playouts: int = 2048, board_size: int = 11, seed: int = 0) -> dict:
    spec = hx.HexSpec(board_size)
    board = hx.empty_board(spec)
    # warm-up game (paper: first game excluded — jit warm-up here)
    uct_search(board, 1, 64, jax.random.key(seed + 1), cp=PAPER.cp,
               tree_cap=1 << 14)
    tree, stats = uct_search(board, 1, n_playouts, jax.random.key(seed),
                             cp=PAPER.cp, tree_cap=max(1 << 14, n_playouts * 2))
    per_playout = stats["time_s"] / n_playouts
    return {
        "board": f"{board_size}x{board_size}",
        "n_playouts": n_playouts,
        "time_s": stats["time_s"],
        "per_playout_us": per_playout * 1e6,
        "extrapolated_paper_budget_s": per_playout * PAPER.n_playouts,
        "paper_xeon_s": 21.47,
        "paper_phi_s": 185.37,
        "tree_nodes": stats["tree_nodes"],
    }


if __name__ == "__main__":
    from benchmarks.common import save_result
    r = run()
    print(json.dumps(r, indent=1) if (json := __import__("json")) else r)
    save_result("table2_sequential", r)
