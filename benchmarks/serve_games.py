"""Mixed-game Poisson serving through the TPFIFO quantum engine.

The multi-tenant twin of the paper's irregular-workload story: hex and
gomoku search requests with heterogeneous playout budgets arrive Poisson
and are served in m-round GSC-PM quanta from per-game-class slot pools
(`repro.serve.games`). Measured against the one_per_core run-to-completion
baseline (the paper's one-task-per-lane discipline): preemptive grain
sharing lets small requests slip between a big request's quanta instead of
waiting out its whole search — median move latency drops (roughly the big
search's service time) while the few big tenants pay at the p95 tail for
the quanta they yielded. Both ratios are reported; the discipline is a
latency-fairness dial, not a free lunch.

Reported: p50/p95 move latency, aggregate playouts/s, preemption counts,
and the compile ledger — serving an entire mixed trace must add ZERO
`run_chunk` entries beyond the one-per-game-class warm-up (asserted).
Feeds BENCH_mcts.json under the ``serving`` key.

    PYTHONPATH=src python benchmarks/serve_games.py [--smoke|--full]
"""

from __future__ import annotations

import os
import sys

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/serve_games.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro.core.gscpm import run_chunk
from repro.serve.games import GameRequest, TPFIFOGameEngine

GAMES = ("hex", "gomoku")


def make_trace(n_requests: int, rate_rps: float, board_size: int,
               playout_choices, seed: int):
    """Poisson arrivals, alternating game classes, mixed budgets/Cp/seeds."""
    rng = np.random.default_rng(seed)
    trace, t = [], 0.0
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        npo = int(rng.choice(playout_choices))
        trace.append((t, dict(
            rid=rid, game=GAMES[rid % len(GAMES)], board_size=board_size,
            n_playouts=npo, n_tasks=max(1, npo // 8),
            cp=float(rng.uniform(0.8, 1.4)), seed=rid)))
    return trace


def _requests(trace):
    return [(t, GameRequest(**kw)) for t, kw in trace]


def serve_trace(engine, trace) -> dict:
    done = engine.run_trace(_requests(trace))
    st = engine.stats()
    assert st.n_finished == len(trace), \
        f"only {st.n_finished}/{len(trace)} requests finished"
    out = st.as_dict()
    playouts = sum(r.result["playouts"] for r in done)
    out["playouts"] = playouts
    out["playouts_per_s"] = playouts / max(out["wall_s"], 1e-9)
    out["ticks"] = engine._ticks
    return out


def run(n_requests: int = 16, slots: int = 2, grain: int = 2,
        n_workers: int = 8, board_size: int = 7, rate_rps: float = 64.0,
        preempt_quanta: int | None = 2, tree_cap: int = 1 << 11,
        playout_choices=(128, 128, 256, 256, 512, 2048), seed: int = 0,
        smoke: bool = False) -> dict:
    if smoke:
        n_requests, board_size, tree_cap = 6, 5, 512
        playout_choices, rate_rps = (32, 64, 128), 50.0

    trace = make_trace(n_requests, rate_rps, board_size, playout_choices,
                       seed)

    def engine(policy="fifo", preempt=preempt_quanta, pipeline=None):
        return TPFIFOGameEngine(n_slots=slots, grain=grain, policy=policy,
                                preempt_quanta=preempt, n_workers=n_workers,
                                tree_cap=tree_cap, pipeline=pipeline)

    # compile off the clock: one tiny request per game class warms the one
    # quantum program each class ever gets
    warm = [(0.0, dict(rid=f"warm-{g}", game=g, board_size=board_size,
                       n_playouts=8, n_tasks=2, seed=0)) for g in GAMES]
    serve_trace(engine(), warm)
    cache_before = run_chunk._cache_size()

    tpfifo = serve_trace(engine(), trace)
    one_per_core = serve_trace(engine(policy="one_per_core", preempt=None),
                               trace)
    recompiles = run_chunk._cache_size() - cache_before
    assert recompiles == 0, \
        f"mixed-budget serving grew the jit cache by {recompiles}"

    p50_ratio = one_per_core["latency_p50"] / max(tpfifo["latency_p50"],
                                                  1e-9)
    p95_ratio = one_per_core["latency_p95"] / max(tpfifo["latency_p95"],
                                                  1e-9)

    # pipelined vs blocking retirement (DESIGN.md §18): same trace, same
    # answers — asserted bitwise per request — with throughput and
    # host-blocked-on-device time compared side by side
    eng_on, eng_off = engine(pipeline=True), engine(pipeline=False)
    pipe_on = serve_trace(eng_on, trace)
    pipe_off = serve_trace(eng_off, trace)
    res_on = {r.rid: r.result for r in eng_on.finished}
    res_off = {r.rid: r.result for r in eng_off.finished}
    for rid, r in res_on.items():
        assert (r["root_visits"] == res_off[rid]["root_visits"]).all()
        assert r["best_move"] == res_off[rid]["best_move"]
    pipeline = {
        "pipelined_playouts_per_s": pipe_on["playouts_per_s"],
        "blocking_playouts_per_s": pipe_off["playouts_per_s"],
        "speedup": (pipe_on["playouts_per_s"]
                    / max(pipe_off["playouts_per_s"], 1e-9)),
        "pipelined_device_wait_s": pipe_on["device_wait_s"],
        "blocking_device_wait_s": pipe_off["device_wait_s"],
        "bit_identical": True,
    }
    return {
        "config": {"n_requests": n_requests, "slots": slots, "grain": grain,
                   "n_workers": n_workers, "board_size": board_size,
                   "rate_rps": rate_rps, "preempt_quanta": preempt_quanta,
                   "tree_cap": tree_cap,
                   "playout_choices": list(playout_choices), "seed": seed,
                   "smoke": smoke},
        "tpfifo": tpfifo,
        "one_per_core": one_per_core,
        "pipeline": pipeline,
        "serving": {
            "games": list(GAMES),
            "board": f"{board_size}x{board_size}",
            "n_requests": n_requests,
            "playouts_per_s": tpfifo["playouts_per_s"],
            "move_latency_p50_s": tpfifo["latency_p50"],
            "move_latency_p95_s": tpfifo["latency_p95"],
            "p50_vs_one_per_core": p50_ratio,
            "p95_vs_one_per_core": p95_ratio,
            "preemptions": tpfifo["n_preemptions"],
            "recompiles": recompiles,
        },
    }


def main():
    import argparse

    from benchmarks.common import save_result

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny trace (CI rot-guard, <1 min)")
    p.add_argument("--full", action="store_true")
    args = p.parse_args()

    out = run(smoke=args.smoke, n_requests=32 if args.full else 16)
    for name in ("tpfifo", "one_per_core"):
        r = out[name]
        print(f"{name:>12}: {r['playouts_per_s']:10.0f} playouts/s   "
              f"p50/p95 move latency {r['latency_p50']*1e3:6.0f}/"
              f"{r['latency_p95']*1e3:6.0f} ms   "
              f"preempts {r['n_preemptions']}")
    s = out["serving"]
    print(f"one_per_core / tpfifo latency: p50 {s['p50_vs_one_per_core']:.2f}x"
          f"  p95 {s['p95_vs_one_per_core']:.2f}x   "
          f"recompiles during serving: {s['recompiles']}")
    pl = out["pipeline"]
    print(f"pipelined vs blocking: {pl['speedup']:.2f}x playouts/s   "
          f"device wait {pl['pipelined_device_wait_s']*1e3:.1f} / "
          f"{pl['blocking_device_wait_s']*1e3:.1f} ms   bit-identical: "
          f"{pl['bit_identical']}")
    path = save_result("serve_games", out)
    print("->", path)


if __name__ == "__main__":
    main()
