"""Chaos serving: fault-rate sweep against the TPFIFO game engine.

The robustness twin of ``serve_games``: the same mixed hex+gomoku Poisson
trace is replayed at increasing injected-fault rates (seeded
``FaultPlan``s: dispatch failures, NaN root-stat poisoning, clock stalls,
duplicate submissions — ``repro.serve.resilience``) and the engine must
absorb them all: every non-shed request completes, answered results are
**bit-identical** to the rate-0 run of the same seeds (recovery replays
from committed snapshots, and round RNG depends only on the schedule),
and the whole sweep adds ZERO ``run_chunk`` jit entries (asserted).

Reported per fault rate: goodput (answered playouts/s), p50/p95 move
latency, retries / quarantined slots / fired-fault counts — the cost of
resilience as a measured curve, not a vibe. Feeds BENCH_mcts.json under
the ``chaos`` key.

    PYTHONPATH=src python benchmarks/serve_chaos.py [--smoke|--full]
"""

from __future__ import annotations

import os
import sys

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/serve_chaos.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro.core.gscpm import run_chunk
from repro.serve.games import GameRequest, TPFIFOGameEngine
from repro.serve.resilience import FaultInjector, FaultPlan

GAMES = ("hex", "gomoku")


def make_trace(n_requests: int, rate_rps: float, board_size: int,
               playout_choices, seed: int):
    rng = np.random.default_rng(seed)
    trace, t = [], 0.0
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        npo = int(rng.choice(playout_choices))
        trace.append((t, dict(
            rid=rid, game=GAMES[rid % len(GAMES)], board_size=board_size,
            n_playouts=npo, n_tasks=max(1, npo // 8),
            cp=float(rng.uniform(0.8, 1.4)), seed=rid)))
    return trace


def _requests(trace):
    return [(t, GameRequest(**kw)) for t, kw in trace]


def serve_chaos(trace, fault_rate: float, *, slots, grain, n_workers,
                tree_cap, quarantine_after, chaos_seed,
                fault_horizon: int = 4096) -> tuple[dict, list]:
    """One serve of the trace at ``fault_rate``; returns (stats, requests)."""
    injector = None
    if fault_rate > 0:
        plan = FaultPlan.generate(seed=chaos_seed, n_ticks=fault_horizon,
                                  n_slots=slots * len(GAMES),
                                  rate=fault_rate)
        injector = FaultInjector(plan)
    eng = TPFIFOGameEngine(
        n_slots=slots, grain=grain, n_workers=n_workers, tree_cap=tree_cap,
        injector=injector, quarantine_after=quarantine_after,
        retry_backoff=(1, 8))
    reqs = _requests(trace)
    eng.run_trace(list(reqs), max_ticks=200_000)
    st = eng.stats().as_dict()
    answered = [r for _, r in reqs if r.result["status"] == "answered"]
    playouts = sum(r.result["playouts"] for r in answered)
    st.update(
        fault_rate=fault_rate,
        n_answered=len(answered),
        goodput_playouts_per_s=playouts / max(st["wall_s"], 1e-9),
        faults=(injector.summary() if injector else
                {"planned": 0, "fired": {}, "fired_total": 0}),
    )
    return st, [r for _, r in reqs]


def run(n_requests: int = 16, slots: int = 2, grain: int = 2,
        n_workers: int = 8, board_size: int = 7, rate_rps: float = 64.0,
        tree_cap: int = 1 << 11, quarantine_after: int = 3,
        playout_choices=(128, 128, 256, 256, 512), seed: int = 0,
        chaos_seed: int = 1234, fault_rates=(0.0, 0.05, 0.1, 0.2),
        smoke: bool = False) -> dict:
    if smoke:
        n_requests, board_size, tree_cap = 6, 5, 512
        playout_choices, rate_rps = (32, 64, 128), 50.0
        fault_rates = (0.0, 0.1, 0.3)

    trace = make_trace(n_requests, rate_rps, board_size, playout_choices,
                       seed)

    # compile off the clock: one tiny request per game class
    warm_eng = TPFIFOGameEngine(n_slots=slots, grain=grain,
                                n_workers=n_workers, tree_cap=tree_cap)
    for g in GAMES:
        warm_eng.submit(GameRequest(rid=f"warm-{g}", game=g,
                                    board_size=board_size, n_playouts=8,
                                    n_tasks=2, seed=0))
    warm_eng.run()
    cache_before = run_chunk._cache_size()

    sweep, reference = [], None
    for rate in fault_rates:
        st, reqs = serve_chaos(
            trace, rate, slots=slots, grain=grain, n_workers=n_workers,
            tree_cap=tree_cap, quarantine_after=quarantine_after,
            chaos_seed=chaos_seed)
        # every non-shed request completed (the never-crash pin)
        unresolved = [r.rid for r in reqs if not r.done]
        assert not unresolved, f"rate {rate}: unresolved rids {unresolved}"
        if rate == 0.0:
            reference = {r.rid: r.result for r in reqs}
        elif reference is not None:
            # bit-identical recovery: every fully-run answered search
            # matches the fault-free serve of the same seeds
            for r in reqs:
                res = r.result
                if (res["status"] != "answered"
                        or res["rounds"] != res["rounds_total"]):
                    continue
                ref = reference[r.rid]
                np.testing.assert_array_equal(res["root_visits"],
                                              ref["root_visits"])
                np.testing.assert_array_equal(res["root_wins"],
                                              ref["root_wins"])
        sweep.append(st)

    recompiles = run_chunk._cache_size() - cache_before
    assert recompiles == 0, \
        f"chaos churn grew the jit cache by {recompiles}"

    base = sweep[0]
    return {
        "config": {"n_requests": n_requests, "slots": slots, "grain": grain,
                   "n_workers": n_workers, "board_size": board_size,
                   "rate_rps": rate_rps, "tree_cap": tree_cap,
                   "quarantine_after": quarantine_after,
                   "playout_choices": list(playout_choices), "seed": seed,
                   "chaos_seed": chaos_seed,
                   "fault_rates": list(fault_rates), "smoke": smoke},
        "sweep": sweep,
        "chaos": {
            "games": list(GAMES),
            "board": f"{board_size}x{board_size}",
            "n_requests": n_requests,
            "fault_rates": list(fault_rates),
            "goodput_playouts_per_s": [s["goodput_playouts_per_s"]
                                       for s in sweep],
            "latency_p50_s": [s["latency_p50"] for s in sweep],
            "latency_p95_s": [s["latency_p95"] for s in sweep],
            "retries": [s["n_retries"] for s in sweep],
            "quarantined": [s["n_quarantined"] for s in sweep],
            "faults_fired": [s["faults"]["fired_total"] for s in sweep],
            "goodput_at_max_rate_vs_clean": (
                sweep[-1]["goodput_playouts_per_s"]
                / max(base["goodput_playouts_per_s"], 1e-9)),
            "recompiles": recompiles,
        },
    }


def main():
    import argparse

    from benchmarks.common import save_result

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny trace + 3 rates (CI rot-guard, <1 min)")
    p.add_argument("--full", action="store_true")
    args = p.parse_args()

    out = run(smoke=args.smoke, n_requests=32 if args.full else 16)
    for s in out["sweep"]:
        print(f"rate {s['fault_rate']:.2f}: "
              f"{s['goodput_playouts_per_s']:10.0f} playouts/s goodput   "
              f"p50/p95 {s['latency_p50']*1e3:6.0f}/"
              f"{s['latency_p95']*1e3:6.0f} ms   "
              f"retries {s['n_retries']}  quarantined {s['n_quarantined']}  "
              f"faults fired {s['faults']['fired_total']}")
    c = out["chaos"]
    print(f"goodput at max fault rate vs clean: "
          f"{c['goodput_at_max_rate_vs_clean']:.2f}x   "
          f"recompiles across sweep: {c['recompiles']}")
    path = save_result("serve_chaos", out)
    print("->", path)


if __name__ == "__main__":
    main()
