"""Render the §Roofline table from the dry-run artifacts.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits
the per-(arch x shape x mesh) markdown table EXPERIMENTS.md embeds: the
three terms in seconds, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs,
peak bytes/chip.
"""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "dryrun")


def load_cells(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def render(cells: list[dict], mesh: str = "single") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "MFU | useful (6ND/HLO) | peak GiB/chip |",
        "|------|-------|---------|--------|------------|------------|"
        "-----|------------------|---------------|",
    ]
    for c in cells:
        if c.get("mesh") != mesh or "error" in c:
            continue
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['bottleneck']} | {r['mfu']*100:.1f}% | "
            f"{r['useful_ratio']*100:.1f}% | "
            f"{c['memory']['peak_bytes_per_chip']/2**30:.2f} |")
    return "\n".join(rows)


def run() -> dict:
    cells = load_cells()
    ok = [c for c in cells if "error" not in c]
    return {
        "n_cells": len(cells),
        "n_ok": len(ok),
        "table_single": render(cells, "single"),
        "table_multipod": render(cells, "multipod"),
    }


if __name__ == "__main__":
    r = run()
    print(f"{r['n_ok']}/{r['n_cells']} cells\n")
    print(r["table_single"])
