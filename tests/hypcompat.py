"""`hypothesis` compatibility shim: property tests degrade to plain pytest.

When `hypothesis` is installed, this module re-exports the real
`given`/`settings`/`strategies` unchanged. When it is missing (it is an
optional dependency — see pyproject.toml), lightweight stand-ins run each
property test over a small deterministic example grid instead of a searched
one: strategy endpoints first, then seeded-random draws. Coverage is thinner
than real hypothesis, but the suite collects and the properties still get
exercised — the tier-1 command must never fail on an optional import.
"""

from __future__ import annotations

import functools
import inspect
import zlib

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # ---------------------------------------- fallback ----
    import numpy as _np

    HAVE_HYPOTHESIS = False
    _FALLBACK_MAX_EXAMPLES = 5

    class _Strategy:
        """A deterministic example generator: draw(rng, i) -> value."""

        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            def draw(rng, i):
                if i == 0:
                    return min_value
                if i == 1:
                    return max_value
                return int(rng.integers(min_value, max_value + 1))

            return _Strategy(draw)

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            seq = list(elements)
            return _Strategy(lambda rng, i: seq[i % len(seq)])

        @staticmethod
        def floats(min_value: float, max_value: float, **_) -> _Strategy:
            def draw(rng, i):
                if i == 0:
                    return float(min_value)
                if i == 1:
                    return float(max_value)
                return float(rng.uniform(min_value, max_value))

            return _Strategy(draw)

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng, i: bool(i % 2))

    st = _Strategies()

    def given(**strategies):
        def deco(f):
            @functools.wraps(f)
            def runner(*args, **kwargs):
                n = min(getattr(runner, "_max_examples",
                                _FALLBACK_MAX_EXAMPLES),
                        _FALLBACK_MAX_EXAMPLES)
                rng = _np.random.default_rng(
                    zlib.crc32(f.__qualname__.encode()))
                for i in range(n):
                    drawn = {k: s.draw(rng, i) for k, s in strategies.items()}
                    f(*args, **kwargs, **drawn)

            # hide the strategy-filled parameters from pytest's fixture
            # resolution (real hypothesis does the same); non-strategy
            # parameters (pytest fixtures) stay visible
            sig = inspect.signature(f)
            runner.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return runner

        return deco

    def settings(max_examples: int | None = None, **_):
        def deco(f):
            if max_examples is not None:
                f._max_examples = max_examples
            return f

        return deco

strategies = st

__all__ = ["given", "settings", "st", "strategies", "HAVE_HYPOTHESIS"]
