"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.key(7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,S,d", [
    (2, 4, 2, 256, 64),    # GQA
    (1, 8, 1, 128, 128),   # MQA
    (2, 2, 2, 256, 80),    # odd head dim (pad path)
    (1, 4, 4, 512, 64),    # MHA longer seq
])
def test_flash_attention(B, H, Hkv, S, d, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, S + d + H), 3)
    q = jax.random.normal(ks[0], (B, H, S, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), dtype)
    got = ops.flash_attention(q, k, v, causal=True, layout="bhsd")
    want = ref.flash_attention(q, k, v, causal=True)
    tol = 2.5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    got = ops.flash_attention(q, k, v, causal=False, layout="bhsd")
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_matches_model_sdpa():
    """Kernel agrees with the model's attention path (bshd layout)."""
    from repro.models.attention import causal_mask, sdpa
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    got = ops.flash_attention(q, k, v, causal=True)        # bshd
    want = sdpa(q, k, v, causal_mask(128))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("W,C", [(7, 11), (64, 121), (200, 121), (16, 300),
                                 (128, 128), (1, 5)])
@pytest.mark.parametrize("noise", [False, True])
def test_uct_select_kernel_vs_oracle(W, C, noise):
    """Interpret-mode Pallas kernel (validation-only path) == jnp oracle."""
    ks = jax.random.split(jax.random.fold_in(KEY, W * C + noise), 5)
    visits = jnp.round(jax.random.uniform(ks[0], (W, C)) * 10)
    wins = jnp.round(jax.random.uniform(ks[1], (W, C)) * visits)
    vloss = jnp.round(jax.random.uniform(ks[2], (W, C)) * 2)
    valid = jax.random.uniform(ks[3], (W, C)) > 0.3
    ptot = jnp.maximum(visits.sum(-1), 1.0)
    nz = 1e-3 * jax.random.uniform(ks[4], (W, C)) if noise else None
    got = ops.uct_select(wins, visits, vloss, ptot, valid, 1.0, noise=nz,
                         interpret=True)
    want = ref.uct_select(wins, visits, vloss, ptot, valid, 1.0, noise=nz)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_uct_select_dispatch_agrees_with_kernel():
    """The auto dispatch the search hot path hits (compiled Pallas on TPU,
    jitted jnp reference elsewhere) selects the same children as the
    interpret-mode Pallas kernel — an independent implementation on every
    backend, so this is non-vacuous on the CPU CI host too — with cp
    traced and a lane mask applied."""
    ks = jax.random.split(KEY, 4)
    W, C = 32, 24
    visits = jnp.round(jax.random.uniform(ks[0], (W, C)) * 10)
    wins = jnp.round(jax.random.uniform(ks[1], (W, C)) * visits)
    valid = jax.random.uniform(ks[2], (W, C)) > 0.3
    ptot = jnp.maximum(visits.sum(-1), 1.0)
    mask = jax.random.uniform(ks[3], (W,)) > 0.25
    for cp in (jnp.float32(0.5), jnp.float32(1.7)):
        got = ops.uct_select(wins, visits, jnp.zeros((W, C)), ptot, valid,
                             cp, lane_mask=mask)
        kernel = ops.uct_select(wins, visits, jnp.zeros((W, C)), ptot, valid,
                                cp, lane_mask=mask, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(kernel))


@pytest.mark.parametrize("size,W", [(5, 1), (5, 7), (9, 16), (11, 16)])
def test_hex_winner_kernel_vs_oracle(size, W):
    """Interpret-mode pointer-doubling Pallas kernel (validation-only path)
    == the jnp pointer-doubling reference == the scalar flood-fill winner,
    on filled boards (the kernel's contract domain)."""
    from repro.core import hex as hx
    spec = hx.HexSpec(size)
    keys = jax.random.split(jax.random.fold_in(KEY, size * W), W)
    boards = jnp.tile(hx.empty_board(spec)[None], (W, 1))
    filled = hx.random_fill_batch(boards, 1, keys, spec)
    got = ops.hex_winner(filled, size, interpret=True)
    want = ref.hex_winner(filled, size)
    flood = jax.vmap(lambda b: hx.winner(b, spec))(filled)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(flood))
    assert got.dtype == jnp.int8


def test_hex_winner_dispatch_agrees_with_kernel():
    """The auto dispatch the playout phase hits (compiled Pallas on TPU,
    jitted batched flood fill elsewhere) returns the same winners as the
    interpret-mode pointer-doubling kernel — independent implementations
    on every backend, so non-vacuous on the CPU CI host too."""
    from repro.core import hex as hx
    size, W = 9, 12
    spec = hx.HexSpec(size)
    keys = jax.random.split(jax.random.fold_in(KEY, 99), W)
    filled = hx.random_fill_batch(
        jnp.tile(hx.empty_board(spec)[None], (W, 1)), 2, keys, spec)
    got = ops.hex_winner(filled, size)
    kernel = ops.hex_winner(filled, size, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(kernel))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 64), d=st.integers(1, 300),
       dt=st.sampled_from(["float32", "bfloat16"]))
def test_rmsnorm_property(n, d, dt):
    dtype = jnp.dtype(dt)
    x = jax.random.normal(jax.random.fold_in(KEY, n * d), (n, d), dtype)
    w = 1 + 0.1 * jax.random.normal(jax.random.fold_in(KEY, d), (d,),
                                    jnp.float32)
    got = ops.rmsnorm(x, w, 1e-5)
    want = ref.rmsnorm(x, w, 1e-5)
    tol = 3e-2 if dt == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)
    assert got.dtype == x.dtype


def test_rmsnorm_matches_model_layer():
    from repro.models.layers import rmsnorm as model_rmsnorm
    x = jax.random.normal(KEY, (4, 32, 256), jnp.float32)
    w = jnp.ones((256,))
    np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, w, 1e-5)),
                               np.asarray(model_rmsnorm(x, w, 1e-5)),
                               atol=1e-5, rtol=1e-5)
