"""Ensemble-axis sharding: padding math, pad-member inertness, and
sharded-vs-unsharded bit-identity.

The multi-device cases skip unless the process sees >= 2 JAX devices;
CI runs this file a second time under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the shard_map
path is exercised for real (see .github/workflows/ci.yml)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hex as hx
from repro.core.gscpm import GSCPMConfig, fold_task_keys
from repro.core.root_parallel import (
    check_forest_invariants,
    ensemble_mesh,
    ensemble_sharding,
    forest_summary,
    gscpm_search_batch,
    merged_root_stats,
    pad_forest_members,
)
from repro.core.tree import forest_size, init_forest, reroot_forest

SIZE = 5
N_MOVES = SIZE * SIZE
N_DEV = len(jax.devices())

multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def cfg(**kw):
    base = dict(board_size=SIZE, n_playouts=96, n_tasks=8, n_workers=4,
                tree_cap=768, select_noise=1e-3)
    base.update(kw)
    return GSCPMConfig(**base)


# ------------------------------------------------------------- padding math ----
def test_ensemble_sharding_defaults_to_visible_devices():
    sharding, padded = ensemble_sharding(5, mesh=None)
    if N_DEV == 1:
        assert sharding is None and padded == 5     # no mesh -> identity
    else:
        assert sharding is not None
        assert padded % N_DEV == 0 and padded >= 5


def test_single_device_has_no_mesh():
    if N_DEV == 1:
        assert ensemble_mesh() is None
    else:
        assert ensemble_mesh() is not None


@multi_device
def test_ensemble_sharding_pads_to_next_device_multiple():
    mesh = ensemble_mesh()
    for n in range(1, 2 * N_DEV + 1):
        sharding, padded = ensemble_sharding(n, mesh)
        assert sharding is not None
        assert padded % N_DEV == 0 and padded >= n
        assert padded - n < N_DEV          # NEXT multiple, not a later one


def test_pad_forest_members_appends_inert_init_trees():
    c = cfg()
    forest = init_forest(3, c.tree_cap, N_MOVES, 1)
    boards = jnp.tile(hx.empty_board(hx.HexSpec(SIZE))[None, :], (3, 1))
    pf, pb = pad_forest_members(forest, boards, 5, c, 1)
    assert forest_size(pf) == 5 and pb.shape[0] == 5
    # pad members are freshly initialized trees: a single root, no stats
    assert np.asarray(pf.n_nodes[3:]).tolist() == [1, 1]
    assert float(np.asarray(pf.visits[3:]).sum()) == 0.0
    # real members are untouched
    np.testing.assert_array_equal(np.asarray(pf.visits[:3]),
                                  np.asarray(forest.visits))


# ---------------------------------------------------------- bit-identity ----
@multi_device
def test_sharded_batch_bit_identical_to_unsharded():
    """The whole tentpole contract: shard_map over the ensemble mesh (with
    padding when E % n_devices != 0) changes NOTHING about the answer —
    merged stats, per-member stats, and the forest summary are bitwise
    equal to the single-device vmap path."""
    board = hx.empty_board(hx.HexSpec(SIZE))
    e = N_DEV - 1              # forces padding
    c = cfg(n_playouts=128)
    f_off, s_off = gscpm_search_batch(board, 1, c, jax.random.PRNGKey(7),
                                      n_trees=e, shard="off")
    f_on, s_on = gscpm_search_batch(board, 1, c, jax.random.PRNGKey(7),
                                    n_trees=e, shard="require")
    assert s_off["sharded"] is False and s_on["sharded"] is True
    assert s_on["n_devices"] == N_DEV
    assert s_on["mesh_shape"] == {"ens": N_DEV}
    assert s_on["padded_members"] == N_DEV - e
    assert forest_size(f_on) == e           # pads sliced off before return
    for a, b in zip(jax.tree.leaves(f_off), jax.tree.leaves(f_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in ("best_move_sum", "best_move_vote", "member_best_moves",
              "tree_nodes", "playouts"):
        assert s_off[k] == s_on[k], k
    check_forest_invariants(f_on)


@multi_device
def test_sharded_periodic_sync_bit_identical():
    """sync_root_stats is the ONLY cross-shard exchange; its delta-tracked
    merge must stay exact when the forest lives on a mesh."""
    board = hx.empty_board(hx.HexSpec(SIZE))
    c = cfg(n_playouts=128, n_tasks=16)
    f_off, s_off = gscpm_search_batch(board, 1, c, jax.random.PRNGKey(8),
                                      n_trees=3, merge_every=1, shard="off")
    f_on, s_on = gscpm_search_batch(board, 1, c, jax.random.PRNGKey(8),
                                    n_trees=3, merge_every=1,
                                    shard="require")
    assert s_on["n_syncs"] == s_off["n_syncs"] >= 2
    mv_off, mw_off = merged_root_stats(f_off, N_MOVES)
    mv_on, mw_on = merged_root_stats(f_on, N_MOVES)
    np.testing.assert_array_equal(np.asarray(mv_off), np.asarray(mv_on))
    np.testing.assert_array_equal(np.asarray(mw_off), np.asarray(mw_on))
    # after the final sync every member's root carries the ensemble total
    np.testing.assert_allclose(np.asarray(f_on.visits[:, 0]),
                               float(s_on["playouts"]))
    summ_off = jax.device_get(forest_summary(f_off, N_MOVES))
    summ_on = jax.device_get(forest_summary(f_on, N_MOVES))
    for k in summ_off:
        np.testing.assert_array_equal(np.asarray(summ_off[k]),
                                      np.asarray(summ_on[k]), err_msg=k)


@multi_device
def test_sharded_metrics_bit_identical():
    board = hx.empty_board(hx.HexSpec(SIZE))
    c = cfg(metrics=True)
    _, s_off = gscpm_search_batch(board, 1, c, jax.random.PRNGKey(9),
                                  n_trees=2, shard="off")
    _, s_on = gscpm_search_batch(board, 1, c, jax.random.PRNGKey(9),
                                 n_trees=2, shard="require")
    assert s_off["metrics"] == s_on["metrics"]


def test_shard_require_raises_on_single_device():
    if N_DEV > 1:
        pytest.skip("only meaningful with one device")
    board = hx.empty_board(hx.HexSpec(SIZE))
    with pytest.raises(RuntimeError, match="require"):
        gscpm_search_batch(board, 1, cfg(), jax.random.PRNGKey(0),
                           n_trees=2, shard="require")


# ------------------------------------------------------------------ reroot ----
@multi_device
def test_reroot_forest_round_trip_under_sharding():
    """Search sharded -> re-root every member -> warm-continue sharded:
    the whole cross-move loop survives device placement, bit-identical to
    the unsharded loop."""
    board = hx.empty_board(hx.HexSpec(SIZE))
    c = cfg(n_playouts=96)

    def loop(shard):
        forest, stats = gscpm_search_batch(
            board, 1, c, jax.random.PRNGKey(11), n_trees=2, shard=shard)
        mv, _ = merged_root_stats(forest, N_MOVES)
        move = int(jnp.argmax(mv))
        warm = reroot_forest(forest, move)
        nb = jnp.tile(board[None, :].at[:, move].set(1), (2, 1))
        forest2, stats2 = gscpm_search_batch(
            nb, 2, c, jax.random.PRNGKey(12), forest=warm, shard=shard)
        return forest2, move

    f_off, m_off = loop("off")
    f_on, m_on = loop("require")
    assert m_off == m_on
    for a, b in zip(jax.tree.leaves(f_off), jax.tree.leaves(f_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- key streams ----
def test_member_key_streams_ignore_padding():
    """Real members' RNG streams must not depend on how far the ensemble
    was padded — fold_task_keys(key, arange(Ep))[:E] == fold over arange(E),
    which is what makes padded and unpadded runs bit-identical."""
    key = jax.random.key(5)
    a = fold_task_keys(key, jnp.arange(3, dtype=jnp.int32))
    b = fold_task_keys(key, jnp.arange(8, dtype=jnp.int32))[:3]
    np.testing.assert_array_equal(jax.random.key_data(a),
                                  jax.random.key_data(b))
