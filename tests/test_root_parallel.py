"""Root parallelism: merge correctness, sync exactness, member invariants."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hex as hx
from repro.core import scheduler
from repro.core.gscpm import GSCPMConfig
from repro.core.root_parallel import (
    check_forest_invariants,
    ensemble_best_move,
    gscpm_search_batch,
    majority_vote_move,
    merged_root_stats,
)
from repro.core.tree import (
    best_child,
    forest_member,
    forest_size,
    init_forest,
    root_move_stats,
)

SIZE = 5
N_MOVES = SIZE * SIZE


def cfg(**kw):
    base = dict(board_size=SIZE, n_playouts=192, n_tasks=8, n_workers=4,
                tree_cap=4096, select_noise=1e-3)
    base.update(kw)
    return GSCPMConfig(**base)


def crossing_position():
    """Black column c=2 and white row r=2, both missing only (2,2): whoever
    takes cell 12 wins instantly (same forced position as tests/test_gscpm)."""
    b = hx.empty_board(hx.HexSpec(SIZE))
    for r in (0, 1, 3, 4):
        b = b.at[r * SIZE + 2].set(1)
    for c in (0, 1, 3, 4):
        b = b.at[2 * SIZE + c].set(2)
    return b, 2 * SIZE + 2


@pytest.fixture(scope="module")
def searched_forest():
    board = hx.empty_board(hx.HexSpec(SIZE))
    forest, stats = gscpm_search_batch(board, 1, cfg(), jax.random.PRNGKey(0),
                                       n_trees=3)
    return forest, stats


# --------------------------------------------------------------- merging ----
def test_merged_visits_equal_member_sum(searched_forest):
    """Merged per-move root visits == Σ over ensemble members."""
    forest, stats = searched_forest
    merged_v, merged_w = merged_root_stats(forest, N_MOVES)
    acc_v = np.zeros(N_MOVES, np.float64)
    acc_w = np.zeros(N_MOVES, np.float64)
    for e in range(forest_size(forest)):
        v, w = root_move_stats(forest_member(forest, e), N_MOVES)
        acc_v += np.asarray(v)
        acc_w += np.asarray(w)
    np.testing.assert_allclose(np.asarray(merged_v), acc_v, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(merged_w), acc_w, rtol=1e-6)
    # every playout passes through exactly one root child
    assert float(np.asarray(merged_v).sum()) == stats["playouts"]


def test_member_invariants_and_independence(searched_forest):
    """check_invariants holds per member; members explore differently."""
    forest, _ = searched_forest
    check_forest_invariants(forest)
    v0 = np.asarray(forest_member(forest, 0).visits[:256])
    v1 = np.asarray(forest_member(forest, 1).visits[:256])
    assert not np.array_equal(v0, v1)  # per-member RNG streams decorrelate


def test_majority_vote_matches_visit_sum_on_forced_win():
    """On a sharply forced position every member finds the winning move, so
    the vote mode and the argmax of summed visits must agree on it."""
    b, win_move = crossing_position()
    forest, stats = gscpm_search_batch(
        b, 1, cfg(n_playouts=512, n_workers=8, n_tasks=16),
        jax.random.PRNGKey(1), n_trees=4)
    assert stats["best_move_sum"] == win_move
    assert stats["best_move_vote"] == win_move
    assert int(ensemble_best_move(forest, N_MOVES)) == \
        int(majority_vote_move(forest, N_MOVES))


def test_multi_position_batch():
    """One tree per DISTINCT position: each member searches its own board."""
    spec = hx.HexSpec(SIZE)
    b_forced, win_move = crossing_position()
    boards = jnp.stack([hx.empty_board(spec), b_forced])
    forest, stats = gscpm_search_batch(
        boards, 1, cfg(n_playouts=384, n_workers=8), jax.random.PRNGKey(2))
    check_forest_invariants(forest)
    assert stats["member_best_moves"][1] == win_move
    # forced-board member: winning child's value estimate is exactly 1.0
    t1 = forest_member(forest, 1)
    assert int(best_child(t1)) == win_move


# ---------------------------------------------------------- periodic sync ----
def test_periodic_sync_exact_no_double_count():
    """Delta-tracked sync: after the final sync, every member's root visits
    equal the TOTAL ensemble playouts — repeated merges never double-count."""
    board = hx.empty_board(hx.HexSpec(SIZE))
    c = cfg(n_playouts=256, n_tasks=16, n_workers=4)
    forest, stats = gscpm_search_batch(board, 1, c, jax.random.PRNGKey(3),
                                       n_trees=3, merge_every=1)
    assert stats["n_syncs"] >= 2  # merged repeatedly, not just once at the end
    root_visits = np.asarray(forest.visits[:, 0])
    np.testing.assert_allclose(root_visits, float(stats["playouts"]))
    check_forest_invariants(forest)


def test_periodic_sync_keeps_forced_win():
    b, win_move = crossing_position()
    _, stats = gscpm_search_batch(
        b, 1, cfg(n_playouts=512, n_workers=8, n_tasks=16),
        jax.random.PRNGKey(4), n_trees=3, merge_every=2)
    assert stats["best_move_sum"] == win_move


# ----------------------------------------------------------------- forest ----
def test_init_forest_shapes_and_cap():
    forest = init_forest(4, 64, N_MOVES, jnp.asarray([1, 2, 1, 2]))
    assert forest.cap == 64                       # per-member, not ensemble
    assert forest.max_children == N_MOVES
    assert forest_size(forest) == 4
    assert np.asarray(forest.to_move[:, 0]).tolist() == [1, 2, 1, 2]
    t2 = forest_member(forest, 1)
    assert t2.cap == 64 and int(t2.n_nodes) == 1


def test_single_vs_batch_same_playout_budget():
    board = hx.empty_board(hx.HexSpec(SIZE))
    c = cfg(n_playouts=128)
    forest, stats = gscpm_search_batch(board, 1, c, jax.random.PRNGKey(5),
                                       n_trees=2)
    assert stats["playouts_per_tree"] == 128
    assert stats["playouts"] == 256
    np.testing.assert_allclose(np.asarray(forest.visits[:, 0]), 128.0)


# -------------------------------------------------- scheduler utilization ----
def test_rebalance_utilization_beats_fifo():
    """Regression: the stealing analogue must keep lanes busier than static
    FIFO whenever W does not divide nTasks (the paper's Table I effect)."""
    fifo = scheduler.schedule_stats(
        scheduler.make_schedule(640, n_tasks=10, n_workers=4, policy="fifo"))
    reb = scheduler.schedule_stats(
        scheduler.make_schedule(640, n_tasks=10, n_workers=4,
                                policy="rebalance"))
    assert fifo["lane_iterations"] == reb["lane_iterations"] == 640
    assert reb["utilization"] > fifo["utilization"]
    assert reb["utilization"] == 1.0
    assert fifo["utilization"] == pytest.approx(640 / 768)
