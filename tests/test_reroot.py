"""Cross-move tree reuse suite (DESIGN.md §16).

Pins the re-root retention contract — every retained node's statistics,
topology, and depth survive ``reroot_tree``/``reroot_forest`` bit-for-bit
(``check_reroot_retention``), an unexpanded move compacts to a tree
bit-identical to a fresh ``init_tree`` with the side to move flipped, and
shrinking capacities fail loudly at trace time. Warm starts are pinned as
a DATA change, never a program change: warm searches are deterministic,
``warm_tree_check`` rejects mismatched trees eagerly, a session-served
warm search equals the direct warm reference bit-for-bit, gomoku's 0.5
draw credits ride through a re-root unchanged, and a whole session game
(re-roots, warm budgets and all) adds ZERO entries to the ``run_chunk``
jit cache beyond the per-class warm-up.

NOTE: engines/configs here use tree_cap=1024 so their class keys never
collide with the exact-compile-count suites (test_serve_games pins
tree_cap=512 at sizes 5/6, test_obsv size 4).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from test_game_protocol import drawn_gomoku_position

from repro.core.gscpm import (GSCPMConfig, gscpm_search, run_chunk,
                              warm_tree_check)
from repro.core.root_parallel import gscpm_search_batch
from repro.core.tree import (check_invariants, check_reroot_retention,
                             forest_member, forest_size, init_tree,
                             node_depths, reroot_forest, reroot_tree,
                             root_summary)
from repro.serve.games import (GameRequest, GameSession, TPFIFOGameEngine,
                               warm_budget)

SIZE = 5
CAP = 1024   # reserved for this suite (see module docstring)


def cfg(**kw):
    kw.setdefault("game", "hex")
    kw.setdefault("board_size", SIZE)
    kw.setdefault("n_playouts", 64)
    kw.setdefault("n_tasks", 8)
    kw.setdefault("n_workers", 4)
    kw.setdefault("tree_cap", CAP)
    return GSCPMConfig(**kw)


def engine(**kw):
    kw.setdefault("n_slots", 1)
    kw.setdefault("grain", 2)
    kw.setdefault("n_workers", 4)
    kw.setdefault("tree_cap", CAP)
    return TPFIFOGameEngine(**kw)


def searched_tree(game="hex", seed=0, **kw):
    c = cfg(game=game, **kw)
    tree, stats = gscpm_search(c.game_obj.init_board(), 1, c,
                               jax.random.key(seed))
    return tree, stats, c


def expanded_root_move(tree) -> int:
    kids = np.asarray(tree.children[0][: int(tree.n_children[0])])
    return int(np.asarray(tree.move)[kids[0]])


# ------------------------------------------------------- retention contract ----
@pytest.mark.parametrize("game", ["hex", "gomoku"])
def test_reroot_retention_bit_identical(game):
    """The played child's whole subtree survives the compaction node-for-
    node: stats bit-identical, topology remapped, depths shifted by one,
    and the result passes every tree invariant."""
    tree, stats, c = searched_tree(game)
    mv = stats["best_move"]
    dst = reroot_tree(tree, mv)
    n_sub = check_reroot_retention(tree, dst, mv)
    assert n_sub == int(dst.n_nodes) > 0
    check_invariants(dst)
    dep = node_depths(dst)
    assert dep[0] == 0
    assert (dep[1: int(dst.n_nodes)] > 0).all()
    # the new root IS the played child: same stats, flipped ownership
    kids = np.asarray(tree.children[0][: int(tree.n_children[0])])
    child = int(kids[list(np.asarray(tree.move)[kids]).index(mv)])
    assert float(dst.visits[0]) == float(tree.visits[child]) > 0
    assert float(dst.wins[0]) == float(tree.wins[child])
    assert int(dst.to_move[0]) == 3 - int(tree.to_move[0])
    # virtual loss is transient per-search state: always cleared
    assert not np.asarray(dst.vloss).any()


def test_reroot_forest_retention_per_member():
    """Every ensemble member keeps ITS OWN subtree under one vmapped
    re-root; members that never expanded the move come back as 1-node
    trees (checked per member by the same host-side contract walk)."""
    c = cfg(n_playouts=32, n_tasks=4)
    forest, _ = gscpm_search_batch(c.game_obj.init_board(), 1, c,
                                   jax.random.key(3), n_trees=3)
    mv = expanded_root_move(forest_member(forest, 0))
    dst = reroot_forest(forest, mv)
    assert forest_size(dst) == 3
    retained = 0
    for e in range(3):
        src_e, dst_e = forest_member(forest, e), forest_member(dst, e)
        retained += check_reroot_retention(src_e, dst_e, mv)
        check_invariants(dst_e)
    assert retained > 0


def test_reroot_unexpanded_move_is_fresh_init_tree():
    """Re-rooting onto a move the root never expanded must yield a tree
    BIT-IDENTICAL to ``init_tree`` with the side to move flipped — the
    'cold start in warm clothing' that makes ``play(any legal move)``
    unconditionally safe."""
    # a tiny budget cannot expand all 25 root moves
    tree, _, c = searched_tree(n_playouts=8, n_tasks=2, n_workers=2)
    kids = np.asarray(tree.children[0][: int(tree.n_children[0])])
    seen = set(np.asarray(tree.move)[kids].tolist())
    missing = next(m for m in range(c.game_obj.n_actions) if m not in seen)
    dst = reroot_tree(tree, missing)
    assert check_reroot_retention(tree, dst, missing) == 0
    fresh = init_tree(CAP, c.game_obj.n_actions, 2)   # to_move flipped
    for f, a, b in zip(tree._fields, dst, fresh):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)


def test_reroot_capacity_shrink_raises_at_trace_time():
    """new_cap < cap cannot be proven to fit from traced shapes alone —
    it must refuse eagerly, never silently truncate retained statistics."""
    tree, stats, c = searched_tree(n_playouts=16, n_tasks=2)
    with pytest.raises(ValueError, match="capacity overflow"):
        reroot_tree(tree, stats["best_move"], new_cap=CAP // 2)
    forest, _ = gscpm_search_batch(c.game_obj.init_board(), 1, c,
                                   jax.random.key(0), n_trees=2)
    with pytest.raises(ValueError, match="capacity overflow"):
        reroot_forest(forest, 0, new_cap=CAP - 1)
    # growing is fine and keeps the whole contract
    mv = stats["best_move"]
    big = reroot_tree(tree, mv, new_cap=2 * CAP)
    assert big.cap == 2 * CAP
    check_reroot_retention(tree, big, mv)
    check_invariants(big)


# ------------------------------------------------------------- warm starts ----
def test_warm_search_deterministic_bit_identical():
    """Search -> re-root -> warm search is a pure function: running the
    pipeline twice from the same seeds yields bit-identical trees and
    stats (the foundation of replayable self-play games)."""
    outs = []
    for _ in range(2):
        tree, stats, c = searched_tree(seed=7)
        mv = stats["best_move"]
        warm = reroot_tree(tree, mv)
        board = c.game_obj.place(c.game_obj.init_board(), jnp.int32(mv),
                                 jnp.int8(1))
        t2, s2 = gscpm_search(board, 2, c, jax.random.key(8), tree=warm)
        outs.append((jax.tree.map(np.asarray, t2), s2))
    (ta, sa), (tb, sb) = outs
    for f, a, b in zip(ta._fields, ta, tb):
        np.testing.assert_array_equal(a, b, err_msg=f)
    assert sa["reused_nodes"] == sb["reused_nodes"] > 0
    assert sa["reused_visits"] == sb["reused_visits"] > 0
    assert sa["best_move"] == sb["best_move"]
    check_invariants(ta)


def test_warm_tree_check_rejects_mismatched_trees():
    tree, _, c = searched_tree(n_playouts=16, n_tasks=2)
    warm_tree_check(tree, 1, c)                      # the matching case
    with pytest.raises(ValueError, match="cap"):
        warm_tree_check(init_tree(CAP // 2, 25, 1), 1, c)
    with pytest.raises(ValueError, match="different game"):
        warm_tree_check(tree, 1, cfg(game="gomoku", board_size=7))
    with pytest.raises(ValueError, match="to_move"):
        warm_tree_check(tree, 2, c)


def test_warm_budget_preserves_grain():
    """n_playouts is TOTAL evidence: the fresh remainder shrinks with the
    retained visits while the grain m (playouts per task) is preserved —
    same quantum program, fewer rounds."""
    po, tasks = warm_budget(512, 16, 8, 100.0)
    assert (po, tasks) == (412, 12)
    assert tasks == max(1, po // (512 // 16))         # m=32 sets the tasks
    # a fully warm position still refreshes one worker batch
    assert warm_budget(512, 16, 8, 512.0) == (8, 1)
    assert warm_budget(512, 16, 8, 10_000.0) == (8, 1)
    # a cold tree changes nothing
    assert warm_budget(512, 16, 8, 0.0) == (512, 16)


def test_gomoku_draw_credits_survive_reroot():
    """From the forced-draw position every node holds wins == visits/2;
    the re-rooted tree must retain the half-credits exactly and a warm
    continuation must keep root_value at exactly 0.5."""
    b = drawn_gomoku_position()
    c = cfg(game="gomoku", n_playouts=64, n_tasks=8)
    tree, stats = gscpm_search(b, 1, c, jax.random.key(5))
    assert stats["root_value"] == 0.5
    mv = stats["best_move"]
    dst = reroot_tree(tree, mv)
    check_reroot_retention(tree, dst, mv)
    nn = int(dst.n_nodes)
    np.testing.assert_allclose(np.asarray(dst.wins[:nn]),
                               np.asarray(dst.visits[:nn]) / 2.0)
    b2 = c.game_obj.place(b, jnp.int32(mv), jnp.int8(1))
    t2, s2 = gscpm_search(b2, 2, c, jax.random.key(6), tree=dst)
    check_invariants(t2)
    assert s2["root_value"] == 0.5
    nn = int(t2.n_nodes)
    np.testing.assert_allclose(np.asarray(t2.wins[:nn]),
                               np.asarray(t2.visits[:nn]) / 2.0)


def test_root_summary_reports_reused_visits():
    tree, _, c = searched_tree(n_playouts=16, n_tasks=2)
    cold = root_summary(tree, c.game_obj.n_actions)
    assert "reused_visits" not in cold    # cold snapshots stay comparable
    warm = root_summary(tree, c.game_obj.n_actions, reused_visits=5)
    assert warm["reused_visits"] == 5


# ---------------------------------------------------------------- sessions ----
def serve(eng, req):
    eng.submit(req)
    eng.run()
    return req.result


def test_session_served_warm_matches_direct_reference():
    """The full serving loop — session request, tree checkout, warm-budget
    replacement, quantum-served search, re-root — must equal the direct
    two-move reference (cold search, ``reroot_tree``, ``warm_budget``,
    warm ``gscpm_search``) bit-for-bit."""
    eng = engine()
    sess = GameSession(eng, "hex", SIZE, base_seed=11)
    r0 = serve(eng, sess.make_request(n_playouts=64, n_tasks=8))
    mv = r0["best_move"]
    sess.play(mv)
    r1 = serve(eng, sess.make_request(n_playouts=64, n_tasks=8))

    # the stateless twin pins the class cfg; the reference replays the
    # same two seeds through the library entry points
    c = eng.request_cfg(GameRequest(rid="ref", game="hex", board_size=SIZE,
                                    n_playouts=64, n_tasks=8, seed=11))
    t0, _ = gscpm_search(c.game_obj.init_board(), 1, c, jax.random.key(11))
    warm = reroot_tree(t0, mv)
    reused = float(warm.visits[0])
    eff_po, eff_tasks = warm_budget(64, 8, c.n_workers, reused)
    c1 = dataclasses.replace(c, n_playouts=eff_po, n_tasks=eff_tasks)
    board1 = c.game_obj.place(c.game_obj.init_board(), jnp.int32(mv),
                              jnp.int8(1))
    t1, s1 = gscpm_search(board1, 2, c1, jax.random.key(12), tree=warm)
    ref = root_summary(t1, c.game_obj.n_actions)

    np.testing.assert_array_equal(r1["root_visits"], ref["root_visits"])
    np.testing.assert_array_equal(r1["root_wins"], ref["root_wins"])
    assert r1["best_move"] == ref["best_move"]
    assert r1["tree_nodes"] == ref["tree_nodes"]
    assert r1["reused_visits"] == int(reused) > 0
    assert r1["reused_nodes"] == int(warm.n_nodes) - 1 > 0
    # equal-evidence accounting: the served search committed exactly the
    # reference's fresh-playout schedule (make_schedule may round eff_po)
    assert r1["playouts"] == s1["playouts"] < 64


def test_session_custody_and_legality_guards():
    """One request in flight per session (the tree has ONE owner), and
    ``play`` validates moves against the live board."""
    eng = engine()
    sess = GameSession(eng, "hex", SIZE)
    req = sess.make_request(n_playouts=16, n_tasks=2)
    with pytest.raises(RuntimeError, match="already in flight"):
        sess.make_request()
    with pytest.raises(RuntimeError, match="in flight"):
        sess.play(0)
    serve(eng, req)
    mv = req.result["best_move"]
    sess.play(mv)
    with pytest.raises(ValueError, match="illegal move"):
        sess.play(mv)                              # cell is now occupied
    assert sess.retained_visits > 0
    assert 0.0 < sess.retained_fraction <= 1.0


def test_cold_session_ablation_never_reuses():
    """reuse_tree=False keeps the session bookkeeping but drops the tree at
    every play — the benchmark's cold arm: same positions, zero reuse."""
    eng = engine()
    warm_s = GameSession(eng, "hex", SIZE, base_seed=3)
    cold_s = GameSession(eng, "hex", SIZE, base_seed=3, reuse_tree=False)
    for sess, want_reuse in ((warm_s, True), (cold_s, False)):
        r0 = serve(eng, sess.make_request(n_playouts=32, n_tasks=4))
        sess.play(r0["best_move"])
        assert (sess.tree is not None) == want_reuse
        r1 = serve(eng, sess.make_request(n_playouts=32, n_tasks=4))
        assert (r1["reused_visits"] > 0) == want_reuse
        if not want_reuse:   # a shallow warm tree may retain 0 descendants
            assert r1["reused_nodes"] == 0
    # both arms decided from the same total evidence
    assert cold_s.last_result["playouts"] == 32
    assert warm_s.last_result["playouts"] < 32


def test_whole_game_adds_zero_recompiles():
    """A whole session game — warm budgets, re-roots, every position —
    must add NOTHING to the run_chunk jit cache beyond the per-class
    warm-up: reuse is a data change, never a program change."""
    eng = engine()
    serve(eng, GameRequest(rid="warm", game="hex", board_size=SIZE,
                           n_playouts=8, n_tasks=2, seed=0))
    before = run_chunk._cache_size()
    sess = GameSession(eng, "hex", SIZE, base_seed=1)
    reused = []
    for _ in range(6):
        res = serve(eng, sess.make_request(n_playouts=48, n_tasks=6))
        reused.append(res["reused_visits"])
        if res["best_move"] < 0:
            break
        sess.play(res["best_move"])
        if sess.over():
            break
    assert run_chunk._cache_size() == before
    assert len(reused) >= 2 and max(reused) > 0   # reuse actually happened
