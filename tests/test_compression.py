"""int8 gradient compression: quantization bounds + the compressed pod-reduce."""

from __future__ import annotations

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.optim.compression import dequantize_int8, quantize_int8

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 5000),
       scale=st.floats(1e-6, 1e4))
def test_quantize_roundtrip_bound(seed, n, scale):
    """|x - dq(q(x))| <= blockmax/254 elementwise (half a quant step)."""
    x = scale * jax.random.normal(jax.random.key(seed), (n,))
    q, s = quantize_int8(x, block=256)
    back = dequantize_int8(q, s, x.shape, x.dtype)
    blocks = np.asarray(jnp.pad(x, (0, (-n) % 256)).reshape(-1, 256))
    bound = np.repeat(np.abs(blocks).max(1) / 254.0, 256)[:n] + 1e-7
    assert (np.abs(np.asarray(back) - np.asarray(x)) <= bound).all()


def test_quantize_preserves_zeros_and_extremes():
    x = jnp.asarray([0.0, 1.0, -1.0, 127.0, -127.0])
    q, s = quantize_int8(x, block=8)
    back = dequantize_int8(q, s, x.shape, x.dtype)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-2)
    assert float(back[0]) == 0.0


def test_compressed_psum_subprocess():
    """compressed_psum over a real 4-way 'pod' axis ~= exact psum; and the
    compressed train step lowers+compiles on a (pod, data, model) mesh."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.compat import make_auto_mesh
from repro.optim.compression import compressed_psum

mesh = make_auto_mesh((4, 2), ("pod", "data"))
x = jax.random.normal(jax.random.key(0), (4, 64))

def f(x):
    comp = compressed_psum(x, "pod")
    exact = jax.lax.psum(x, "pod")
    return comp, exact

g = compat.shard_map(f, mesh=mesh, in_specs=P("pod"),
                     out_specs=(P("pod"), P("pod")), check=False)
comp, exact = g(x)
err = float(jnp.max(jnp.abs(comp - exact)))
scale = float(jnp.max(jnp.abs(exact))) + 1e-9
assert err / scale < 0.05, (err, scale)

# compressed train step lowers + compiles on a pod mesh. Requires the
# modern partial-auto shard_map: jax 0.4.x's experimental `auto=` path
# trips an XLA CHECK (IsManualSubgroup) on this program, so only the
# numeric psum half runs there.
if hasattr(jax, "shard_map"):
    from repro import configs
    from repro.optim.adamw import OptConfig
    from repro.train import step as sm
    cfg = configs.reduced_config("smollm-135m").replace(n_layers=2)
    mesh3 = make_auto_mesh((2, 2, 2), ("pod", "data", "model"))
    step = sm.make_train_step_compressed(cfg, OptConfig(), mesh3)
    state = sm.abstract_state(cfg)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "mask": jax.ShapeDtypeStruct((8, 32), jnp.float32)}
    compiled = jax.jit(step).lower(state, batch).compile()
    txt = compiled.as_text()
    assert "all-gather" in txt  # the int8 wire path
    assert "s8[" in txt, "int8 payload missing from the compiled module"
print("OK", err / scale)
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
