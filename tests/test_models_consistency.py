"""Cross-implementation model consistency + hypothesis property tests."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.models import ssm, xlstm
from repro.models.common import ModelConfig, init_tree, spec_with_dtype


def test_mlstm_chunked_equals_quadratic():
    cfg = ModelConfig(family="xlstm", d_model=64, n_heads=4, vocab=64,
                      mlstm_chunk=16)
    p = init_tree(spec_with_dtype(xlstm.mlstm_specs(cfg), jnp.float32),
                  jax.random.key(0))
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 64, 64))
    y_c, cache_c = xlstm._mlstm_chunked(p, cfg, x)
    full = cfg.replace(mlstm_chunk=0)
    y_f = xlstm.mlstm_forward(p, full, x)
    _, cache_f = xlstm.mlstm_prefill(p, full, x)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_f), atol=3e-5,
                               rtol=3e-4)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(cache_c[k]),
                                   np.asarray(cache_f[k]), atol=5e-5,
                                   rtol=5e-4)


def test_mlstm_chunked_then_decode():
    """Chunked prefill state continues correctly through decode steps."""
    cfg = ModelConfig(family="xlstm", d_model=32, n_heads=2, vocab=64,
                      mlstm_chunk=8)
    p = init_tree(spec_with_dtype(xlstm.mlstm_specs(cfg), jnp.float32),
                  jax.random.key(2))
    x = 0.5 * jax.random.normal(jax.random.key(3), (1, 40, 32))
    full = cfg.replace(mlstm_chunk=0)
    # ground truth: full quadratic over 40 tokens
    y_full = xlstm.mlstm_forward(p, full, x)
    # chunked prefill over 32, decode the last 8 recurrently
    y_pre, cache = xlstm.mlstm_prefill(p, cfg, x[:, :32])
    outs = []
    for t in range(32, 40):
        yt, cache = xlstm.mlstm_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(yt)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 32:]),
                               atol=2e-4, rtol=2e-3)


def test_mamba2_forward_equals_decode():
    cfg = ModelConfig(family="ssm", d_model=64, n_heads=4, vocab=64,
                      ssm_state=16, ssm_headdim=16, ssm_chunk=8)
    p = init_tree(spec_with_dtype(ssm.mamba2_specs(cfg), jnp.float32),
                  jax.random.key(4))
    x = 0.5 * jax.random.normal(jax.random.key(5), (2, 32, 64))
    y, cache = ssm.mamba2_forward(p, cfg, x, return_cache=True)
    cache_d = ssm.mamba2_init_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(32):
        yt, cache_d = ssm.mamba2_decode(p, cfg, x[:, t:t + 1], cache_d)
        outs.append(yt)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq), atol=5e-4,
                               rtol=5e-3)
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(cache_d["state"]), atol=5e-4,
                               rtol=5e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([4, 8, 16]),
       L=st.sampled_from([16, 32, 48]))
def test_mamba2_chunk_invariance(seed, chunk, L):
    """SSD output independent of the chunking grain (property)."""
    cfg = ModelConfig(family="ssm", d_model=32, n_heads=2, vocab=64,
                      ssm_state=8, ssm_headdim=16, ssm_chunk=chunk)
    p = init_tree(spec_with_dtype(ssm.mamba2_specs(cfg), jnp.float32),
                  jax.random.key(7))
    x = 0.3 * jax.random.normal(jax.random.key(seed), (1, L, 32))
    y1 = ssm.mamba2_forward(p, cfg, x)
    y2 = ssm.mamba2_forward(p, cfg.replace(ssm_chunk=L), x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-4,
                               rtol=3e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_moe_fullcapacity_matches_dense(seed):
    """With ample capacity the grouped MoE equals the per-token dense mix."""
    from repro.models import moe
    cfg = ModelConfig(family="moe", d_model=16, n_experts=4,
                      n_experts_per_tok=2, moe_d_ff=8, capacity_factor=8.0,
                      vocab=32, norm_topk_prob=True, moe_group_size=8)
    p = init_tree(spec_with_dtype(moe.moe_specs(cfg), jnp.float32),
                  jax.random.key(11))
    x = jax.random.normal(jax.random.key(seed), (2, 8, 16))
    y = moe.moe_ffn(p, cfg, x)
    xf = x.reshape(-1, 16)
    topi, topw = moe.router_topk(xf @ p["router"], 2, True)
    yref = np.zeros((16, 16), np.float32)
    for t in range(16):
        for j in range(2):
            e, w = int(topi[t, j]), float(topw[t, j])
            h = jax.nn.silu(xf[t] @ p["wg"][e]) * (xf[t] @ p["wu"][e])
            yref[t] += w * np.asarray(h @ p["wd"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(16, 16)), yref,
                               atol=1e-4, rtol=1e-3)


def test_sdpa_chunked_matches_full():
    from repro.models.attention import causal_mask, sdpa, sdpa_chunked
    q = jax.random.normal(jax.random.key(0), (2, 64, 4, 16))
    k = jax.random.normal(jax.random.key(1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.key(2), (2, 64, 2, 16))
    full = sdpa(q, k, v, causal_mask(64))
    for chunk in (8, 16, 32):
        ch = sdpa_chunked(q, k, v, chunk)
        np.testing.assert_allclose(np.asarray(ch), np.asarray(full),
                                   atol=2e-5, rtol=2e-5)
    # prefix-LM variant
    from repro.models.attention import prefix_lm_mask
    pre = sdpa(q, k, v, prefix_lm_mask(64, 10))
    ch = sdpa_chunked(q, k, v, 16, prefix_len=10)
    np.testing.assert_allclose(np.asarray(ch), np.asarray(pre), atol=2e-5,
                               rtol=2e-5)
