"""Training loop: resume equivalence, bad-step skip, grain-size accumulation."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import DataConfig
from repro.models import api
from repro.optim.adamw import OptConfig, adamw_update, init_opt_state, lr_at
from repro.train import step as step_mod
from repro.train.loop import LoopConfig, train

CFG = configs.reduced_config("smollm-135m").replace(n_layers=2)
DC = DataConfig(seq_len=32, global_batch=8, seed=5)
OC = OptConfig(lr=1e-3, warmup_steps=4, total_steps=40)


def test_resume_equivalence(tmp_path):
    """5 steps + restart + 5 steps == 10 straight steps (same data/updates)."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    out_straight = train(CFG, OC, DC, LoopConfig(steps=10, ckpt_dir=d1,
                                                 ckpt_every=100, log_every=100))
    train(CFG, OC, DC, LoopConfig(steps=5, ckpt_dir=d2, ckpt_every=5,
                                  log_every=100))
    out_resumed = train(CFG, OC, DC, LoopConfig(steps=10, ckpt_dir=d2,
                                                ckpt_every=5, log_every=100))
    assert out_resumed["history"][0]["step"] == 5
    np.testing.assert_allclose(out_straight["final_loss"],
                               out_resumed["final_loss"], rtol=1e-4)


def test_nonfinite_grads_skipped():
    params = api.init_params(CFG, jax.random.key(0))
    opt = init_opt_state(params)
    bad = jax.tree.map(lambda p: jnp.full(p.shape, jnp.nan, jnp.float32),
                       params)
    p2, o2, m = adamw_update(params, bad, opt, OC)
    assert m["skipped"] == 1.0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert int(o2["step"]) == 1  # schedule still advances


def test_microbatch_grain_equivalence():
    """n_microbatches=1 vs 4 give the same gradients (the grain dial is
    numerically neutral, exactly like nTasks in the paper)."""
    params = api.init_params(CFG, jax.random.key(0))
    from repro.data.pipeline import make_batch_fn
    batch = {k: jnp.asarray(v) for k, v in make_batch_fn(DC, CFG)(0).items()}
    l1, g1 = step_mod._mean_grads(CFG, params, batch, 1)
    l4, g4 = step_mod._mean_grads(CFG, params, batch, 4)
    # microbatch CE averages over tokens per microbatch then over grains —
    # with uniform masks these agree
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-3)


def test_lr_schedule():
    assert float(lr_at(OC, jnp.int32(0))) == 0.0
    assert abs(float(lr_at(OC, jnp.int32(4))) - OC.lr) < 1e-9
    assert float(lr_at(OC, jnp.int32(40))) <= OC.lr * OC.min_lr_frac + 1e-9


def test_unroll_loops_equivalence():
    """unroll_loops (the dry-run mode) is numerically identical."""
    params = api.init_params(CFG, jax.random.key(1))
    from repro.data.pipeline import make_batch_fn
    batch = {k: jnp.asarray(v) for k, v in make_batch_fn(DC, CFG)(1).items()}
    cfg_u = CFG.replace(unroll_loops=True, scan_layers=False,
                        logits_chunk=16, attn_chunk=16)
    cfg_s = CFG.replace(logits_chunk=16, attn_chunk=16)
    l_u = api.loss(params, cfg_u, batch)
    l_s = api.loss(params, cfg_s, batch)
    np.testing.assert_allclose(float(l_u), float(l_s), rtol=1e-5)
