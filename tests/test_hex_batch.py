"""Batched (W, cells) Hex evaluation vs the per-lane scalar oracles.

The tentpole contract (DESIGN.md §12): `connected_batch` (pointer-doubling
CC labeling), `winner_batch` / `winner_flood_batch`, `random_fill_batch`,
and the fused `playout_batch` must be BIT-identical to the vmapped scalar
oracles (`connected` / `winner` / `random_fill` / `playout`) under the same
RNG schedule — across board sizes, batch widths, partial and filled boards,
and under a further vmap over the forest axis. Pointer doubling must also
converge within the fixed ceil(log2(n_cells)) + 2 round budget the Pallas
kernel hard-codes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import hex as hx

SIZES = (5, 9, 11)
WIDTHS = (1, 8, 16)


def random_boards(rng: np.random.Generator, size: int, W: int,
                  fill: float) -> jnp.ndarray:
    """(W, n) int8 boards with `fill` fraction of alternating stones."""
    n = size * size
    out = np.zeros((W, n), dtype=np.int8)
    for w in range(W):
        k = int(n * fill)
        idx = rng.permutation(n)[:k]
        for t, i in enumerate(idx):
            out[w, i] = 1 if t % 2 == 0 else 2
    return jnp.asarray(out)


# ------------------------------------------------------------ connectivity ----
@pytest.mark.parametrize("size", SIZES)
@pytest.mark.parametrize("W", WIDTHS)
def test_connected_batch_matches_vmapped_connected(size, W):
    spec = hx.HexSpec(size)
    rng = np.random.default_rng(size * 100 + W)
    for fill in (0.0, 0.3, 0.6, 1.0):
        boards = random_boards(rng, size, W, fill)
        for player in (1, 2):
            got = hx.connected_batch(boards, player, spec)
            want = jax.vmap(
                lambda b: hx.connected(b, jnp.int8(player), spec))(boards)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=f"{size=} {W=} {fill=} "
                                                  f"{player=}")


@pytest.mark.parametrize("size", SIZES)
def test_winner_batch_paths_agree_on_filled(size):
    """Dispatch (`winner_batch`), flood batch, and vmapped scalar winner are
    bit-identical on filled boards."""
    spec = hx.HexSpec(size)
    W = 16
    keys = jax.random.split(jax.random.key(size), W)
    boards = jnp.tile(hx.empty_board(spec)[None], (W, 1))
    filled = hx.random_fill_batch(boards, 1, keys, spec)
    assert (np.asarray(filled) != 0).all()
    want = jax.vmap(lambda b: hx.winner(b, spec))(filled)
    np.testing.assert_array_equal(
        np.asarray(hx.winner_batch(filled, spec)), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(hx.winner_flood_batch(filled, spec)), np.asarray(want))


def adversarial_stones(size: int) -> np.ndarray:
    """(3, n) stone masks with worst-case component shape: solid board,
    column comb, and a boustrophedon snake — the long-thin components that
    maximize pointer-doubling rounds."""
    n = size * size
    solid = np.ones(n, dtype=bool)
    comb = np.zeros(n, dtype=bool)
    for r in range(size):
        for c in range(size):
            if c % 2 == 0 or r == 0:
                comb[r * size + c] = True
    snake = np.zeros(n, dtype=bool)
    for r in range(size):
        cols = range(size) if r % 2 == 0 else [size - 1]
        for c in cols:
            snake[r * size + c] = True
    return np.stack([solid, comb, snake])


@pytest.mark.parametrize("size", [11, 17, 25])
def test_fixed_round_budget_adversarial_boards(size):
    """The kernel's fixed round budget has NO runtime convergence check, so
    it must reach the exact CC fixpoint on the worst component shapes too —
    snake/comb/solid boards at sizes beyond the play configs (empirically
    <= 7 rounds vs caps of 9-12; do not tighten the budget without this)."""
    spec = hx.HexSpec(size)
    cap = hx.doubling_rounds(size * size)
    stones = jnp.asarray(adversarial_stones(size))
    lab_fix = hx.cc_labels_batch(stones, spec)
    lab_cap = hx.cc_labels_batch(stones, spec, rounds=cap)
    np.testing.assert_array_equal(np.asarray(lab_fix), np.asarray(lab_cap))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), size=st.sampled_from(list(SIZES)),
       W=st.sampled_from(list(WIDTHS)))
def test_fixed_doubling_round_budget(seed, size, W):
    """Pointer doubling reaches the exact CC fixpoint within the kernel's
    fixed ceil(log2(n_cells)) + 2 rounds — on random partial boards AND the
    adversarial all-one-color board (worst-case component diameter)."""
    spec = hx.HexSpec(size)
    n = size * size
    cap = hx.doubling_rounds(n)
    rng = np.random.default_rng(seed)
    boards = random_boards(rng, size, W, float(rng.uniform(0.2, 1.0)))
    stones = jnp.concatenate(
        [boards == 1, jnp.ones((1, n), dtype=bool)], axis=0)
    lab_fix = hx.cc_labels_batch(stones, spec)
    lab_cap = hx.cc_labels_batch(stones, spec, rounds=cap)
    np.testing.assert_array_equal(np.asarray(lab_fix), np.asarray(lab_cap))


# ------------------------------------------------------------ fill/playout ----
@pytest.mark.parametrize("size", SIZES)
@pytest.mark.parametrize("W", WIDTHS)
def test_random_fill_batch_bit_identical(size, W):
    spec = hx.HexSpec(size)
    rng = np.random.default_rng(size + W)
    keys = jax.random.split(jax.random.key(size * 7 + W), W)
    for fill in (0.0, 0.4):
        boards = random_boards(rng, size, W, fill)
        got = hx.random_fill_batch(boards, 2, keys, spec)
        want = jax.vmap(
            lambda b, k: hx.random_fill(b, jnp.int32(2), k, spec))(boards, keys)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert (np.asarray(got) != 0).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), size=st.sampled_from(list(SIZES)),
       W=st.sampled_from(list(WIDTHS)))
def test_playout_batch_bit_identical(seed, size, W):
    """Fused playout (one argsort-free fill + one connectivity solve for the
    whole batch) returns exactly the winners of W scalar playouts."""
    spec = hx.HexSpec(size)
    rng = np.random.default_rng(seed)
    boards = random_boards(rng, size, W, float(rng.uniform(0.0, 0.7)))
    keys = jax.random.split(jax.random.key(seed), W)
    to_move = 1 + (seed % 2)
    got = hx.playout_batch(boards, to_move, keys, spec)
    # explicit scalar formulation (fill + per-lane flood-fill winner):
    # `hx.playout` itself is now a width-1 wrapper over the batched path,
    # so the oracle is spelled out to stay an independent implementation
    want = jax.vmap(lambda b, k: hx.winner(
        hx.random_fill(b, jnp.int32(to_move), k, spec), spec))(boards, keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_playout_batch_composes_with_forest_vmap():
    """A further vmap over the ensemble axis (the root-parallel forest path)
    keeps the batch bit-identical: (E, W, cells) playouts in one program."""
    E, W, size = 3, 8, 5
    spec = hx.HexSpec(size)
    keys = jax.random.split(jax.random.key(11), E * W).reshape(E, W)
    boards = jnp.tile(hx.empty_board(spec)[None, None], (E, W, 1))
    got = jax.jit(jax.vmap(
        lambda b, k: hx.playout_batch(b, 1, k, spec)))(boards, keys)
    want = jax.vmap(jax.vmap(lambda b, k: hx.winner(
        hx.random_fill(b, jnp.int32(1), k, spec), spec)))(boards, keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -------------------------------------------------------- winner contract ----
def test_winner_checked_rejects_partial_board():
    spec = hx.HexSpec(5)
    partial = hx.empty_board(spec).at[0].set(1)
    with pytest.raises(AssertionError, match="not completely filled"):
        hx.winner_checked(partial, spec)


def test_winner_checked_passes_filled_board():
    spec = hx.HexSpec(5)
    full = hx.random_fill(hx.empty_board(spec), jnp.int32(1),
                          jax.random.key(0), spec)
    assert int(hx.winner_checked(full, spec)) == int(hx.winner(full, spec))


# ------------------------------------------------------------ replay oracle ----
@pytest.mark.parametrize("n_moves", [0, 3, 7])
def test_replay_moves_matches_sequential_placement(n_moves):
    """The one-shot masked scatter equals move-by-move placement."""
    size = 5
    spec = hx.HexSpec(size)
    moves = jnp.asarray([4, 9, 0, 24, 13, 7, 19], dtype=jnp.int32)
    got = np.asarray(hx.replay_moves(moves, jnp.int32(n_moves),
                                     jnp.int32(2), spec))
    want = np.zeros(size * size, dtype=np.int8)
    for i in range(n_moves):
        want[int(moves[i])] = 2 if i % 2 == 0 else 1
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------- search integration ----
@pytest.mark.parametrize("W", [4, 8])
def test_full_search_playout_batched_equals_scalar(W):
    """Whole GSCPM searches with the fused playout stage produce bit-identical
    trees to the per-lane flood-fill playout oracle (same RNG schedule)."""
    from repro.core.gscpm import GSCPMConfig, gscpm_search

    board = hx.empty_board(hx.HexSpec(5))
    base = GSCPMConfig(board_size=5, n_playouts=128, n_tasks=8, n_workers=W,
                       tree_cap=2048, playout="batched")
    key = jax.random.PRNGKey(23)
    t_b, s_b = gscpm_search(board, 1, base, key)
    t_s, s_s = gscpm_search(board, 1,
                            dataclasses.replace(base, playout="scalar"), key)
    assert int(t_b.n_nodes) == int(t_s.n_nodes)
    nn = int(t_b.n_nodes)
    for f in ("parent", "move", "to_move", "n_children"):
        np.testing.assert_array_equal(
            np.asarray(getattr(t_b, f)[:nn]),
            np.asarray(getattr(t_s, f)[:nn]), err_msg=f)
    np.testing.assert_allclose(np.asarray(t_b.visits[:nn]),
                               np.asarray(t_s.visits[:nn]))
    np.testing.assert_allclose(np.asarray(t_b.wins[:nn]),
                               np.asarray(t_s.wins[:nn]))
    assert s_b["best_move"] == s_s["best_move"]
