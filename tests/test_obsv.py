"""Observability-layer suite (DESIGN.md §15).

The contracts that make the two planes trustworthy:

- **bit-identity**: a search with the device-plane ``SearchMetrics``
  accumulator threaded through its compiled chunks is bit-identical —
  every ``Tree`` leaf — to the same search with metrics off, for hex AND
  gomoku, single tree and forest;
- **two programs**: ``GSCPMConfig.metrics`` is a hashed static flag, so a
  Cp × grain × budget sweep with metrics on and off compiles exactly TWO
  quantum programs per game class (asserted via jit-cache deltas);
- **conservation**: the traced counters must agree with the tree the
  search actually built and with the schedule it actually ran;
- **trace structure**: the recorder emits valid Chrome trace-event JSON
  (``validate_trace`` accepts it and rejects malformed variants), serving
  traces carry the admission/quantum/preempt/retire/deadline vocabulary,
  and ``obsv.profile`` recovers known burden terms from synthetic spans;
- **QueueStats**: progress telemetry (preemptions, quanta, tokens) is
  reported even when NO request has finished (the regression this PR
  fixes).
"""

from __future__ import annotations

import dataclasses
import json
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scheduler
from repro.core.gscpm import GSCPMConfig, gscpm_search, run_chunk
from repro.core.root_parallel import gscpm_search_batch, run_chunk_forest
from repro.core.tree import init_tree, node_depths
from repro.obsv import (
    MetricsRegistry,
    TraceRecorder,
    init_search_metrics,
    init_search_metrics_forest,
    merge_metrics,
    summarize_metrics,
    validate_trace,
)
from repro.serve.games import GameRequest, TPFIFOGameEngine
from repro.serve.tpfifo import QueueStats, Ticket

SIZE = 5


def cfg_for(game, metrics=False, **kw):
    kw.setdefault("board_size", SIZE)
    kw.setdefault("n_workers", 4)
    kw.setdefault("tree_cap", 512)
    kw.setdefault("n_playouts", 64)
    kw.setdefault("n_tasks", 8)
    return GSCPMConfig(game=game, metrics=metrics, **kw)


def trees_equal(a, b) -> bool:
    return all(bool(jnp.array_equal(x, y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------------------ bit-identity ----
@pytest.mark.parametrize("game", ["hex", "gomoku"])
def test_metrics_whole_search_bit_identity(game):
    """Same key, same schedule: metrics on vs off must agree on EVERY tree
    leaf (visits, wins, structure, allocation counters)."""
    cfg = cfg_for(game)
    board = cfg.game_obj.init_board()
    key = jax.random.key(7)
    t_off, s_off = gscpm_search(board, 1, cfg, key)
    t_on, s_on = gscpm_search(board, 1,
                              dataclasses.replace(cfg, metrics=True), key)
    assert trees_equal(t_off, t_on)
    assert s_off["best_move"] == s_on["best_move"]
    assert "metrics" in s_on and "metrics" not in s_off


@pytest.mark.parametrize("game", ["hex", "gomoku"])
def test_metrics_forest_bit_identity(game):
    cfg = cfg_for(game, n_playouts=32, n_tasks=8)
    board = cfg.game_obj.init_board()
    key = jax.random.key(3)
    f_off, s_off = gscpm_search_batch(board, 1, cfg, key, n_trees=3)
    f_on, s_on = gscpm_search_batch(
        board, 1, dataclasses.replace(cfg, metrics=True), key, n_trees=3)
    assert trees_equal(f_off, f_on)
    assert s_on["metrics"]["lane_playouts"] == s_off["playouts"]


# ------------------------------------------------------------ two programs ----
def test_exactly_two_programs_per_game_class():
    """Cp × grain × budget sweeps with metrics on AND off compile exactly
    two quantum programs per game class — the metrics arm is one extra
    cache entry, budget knobs stay compare=False. The (n_workers, tree_cap,
    board_size) combination is unique to this test so the cache delta is
    exact even with other test modules warm in the same process."""
    for game in ("hex", "gomoku"):
        before = run_chunk._cache_size()
        board = None
        key = jax.random.key(0)
        for metrics in (False, True):
            for cp, (n_po, n_t) in [(0.5, (16, 4)), (1.7, (32, 8)),
                                    (0.9, (24, 12))]:
                cfg = GSCPMConfig(game=game, board_size=4, n_workers=6,
                                  tree_cap=384, n_playouts=n_po,
                                  n_tasks=n_t, cp=cp, metrics=metrics)
                board = cfg.game_obj.init_board()
                gscpm_search(board, 1, cfg, key)
        assert run_chunk._cache_size() == before + 2, game


def test_run_chunk_rejects_flag_accumulator_mismatch():
    cfg = cfg_for("hex")
    board = cfg.game_obj.init_board()
    tree = init_tree(cfg.tree_cap, cfg.game_obj.n_actions, 1)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.key(0), jnp.arange(cfg.n_workers))
    active = jnp.ones((cfg.n_workers,), bool)
    with pytest.raises(ValueError, match="metrics"):
        run_chunk(tree, board, cfg, keys, active, jnp.int32(1),
                  jnp.float32(1.0), init_search_metrics())


# ------------------------------------------------------------ conservation ----
@pytest.mark.parametrize("game", ["hex", "gomoku"])
def test_counter_conservation(game):
    """The device counters must agree with the tree and the schedule:
    every playout is a scheduled lane iteration, every expansion is a tree
    node, every proposal either allocates or collides, and no descent is
    deeper than the tree it walked."""
    cfg = cfg_for(game, metrics=True, n_playouts=72, n_tasks=12)
    board = cfg.game_obj.init_board()
    tree, st = gscpm_search(board, 1, cfg, jax.random.key(11))
    m = st["metrics"]
    sch = scheduler.make_schedule(cfg.n_playouts, cfg.n_tasks,
                                  cfg.n_workers, cfg.scheduler)
    sstats = scheduler.schedule_stats(sch)

    assert m["lane_playouts"] == st["playouts"] \
        == sstats["lane_iterations"]
    assert m["masked_lane_iterations"] == sum(
        int((~np.asarray(r.active)).sum()) * r.m for r in sch)
    assert m["sync_iterations"] == sum(r.m for r in sch)
    # every playout backs up through the root exactly once
    assert int(np.asarray(tree.visits)[0]) == m["lane_playouts"]

    depths = node_depths(tree)
    n_nodes = int(tree.n_nodes)
    assert m["expansions"] == n_nodes - 1          # root precedes the search
    assert m["tree_nodes_peak"] == n_nodes         # nodes are never freed
    assert m["expand_proposals"] == m["expansions"] + m["expand_collisions"]
    assert 0 <= m["depth_max"] <= depths[:n_nodes].max()
    assert 0 <= m["depth_sum"] <= m["depth_max"] * m["lane_playouts"]
    assert m["leaf_collisions"] <= m["lane_playouts"]
    n_cells = cfg.game_obj.n_cells
    assert 0 < m["playout_len_max"] <= n_cells
    assert m["playout_moves"] <= n_cells * m["lane_playouts"]
    assert m["held_levels"] >= 0


def test_forest_summary_merges_members():
    fm = init_search_metrics_forest(3)
    fm = fm._replace(
        lane_playouts=jnp.asarray([4, 5, 6], jnp.int32),
        depth_max=jnp.asarray([2, 7, 3], jnp.int32),
        depth_sum=jnp.asarray([1, 2, 3], jnp.int32))
    s = summarize_metrics(fm)
    assert s["lane_playouts"] == 15
    assert s["depth_max"] == 7                      # max-merged gauge
    assert s["depth_sum"] == 6                      # summed counter


def test_merge_metrics_sum_vs_max_fields():
    a = init_search_metrics()._replace(
        expansions=jnp.int32(3), tree_nodes_peak=jnp.int32(10))
    b = init_search_metrics()._replace(
        expansions=jnp.int32(4), tree_nodes_peak=jnp.int32(8))
    c = merge_metrics(a, b)
    assert int(c.expansions) == 7
    assert int(c.tree_nodes_peak) == 10


# ------------------------------------------------------------------ tracer ----
def test_trace_recorder_structure_and_validation(tmp_path):
    tr = TraceRecorder(process_name="t")
    tr.name_thread(1, "worker")
    tr.instant("evt", {"k": 1})
    tr.begin("outer", tid=1)
    tr.end(tid=1)
    with tr.span("quantum", {"rounds": 2}):
        pass
    tr.counter("queue", {"depth": 3})
    d = tr.to_dict()
    assert d["displayTimeUnit"] == "ms"
    n = validate_trace(d)
    assert n == len(d["traceEvents"]) >= 6
    path = tr.save(str(tmp_path / "t.json"))
    assert validate_trace(path) == n
    with open(path) as f:
        assert json.load(f)["traceEvents"]


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({"traceEvents": []})
    with pytest.raises(ValueError, match="missing"):
        validate_trace({"traceEvents": [{"ph": "i", "ts": 0}]})
    with pytest.raises(ValueError, match="dur"):
        validate_trace({"traceEvents": [{"name": "x", "ph": "X", "ts": 0}]})
    with pytest.raises(ValueError, match="unclosed"):
        validate_trace({"traceEvents": [{"name": "b", "ph": "B", "ts": 0}]})
    with pytest.raises(ValueError, match="unbalanced"):
        validate_trace({"traceEvents": [{"name": "e", "ph": "E", "ts": 0}]})


def test_compile_watch_counts_jit_cache_growth():
    @jax.jit
    def f(x):
        return x + 1

    tr = TraceRecorder()
    tr.watch_compiles("f", f)
    f(jnp.zeros((2,)))                 # compile 1
    f(jnp.zeros((3,)))                 # compile 2 (new shape)
    f(jnp.zeros((3,)))                 # cache hit
    tr.poll_compiles()
    assert tr.compile_counts() == {"f": 2}
    evs = [e for e in tr.events if e["name"] == "jit_compile"]
    assert len(evs) == 1 and evs[0]["args"]["new_programs"] == 2


# ---------------------------------------------------------------- registry ----
def test_metrics_registry_counters_gauges_exposition(tmp_path):
    reg = MetricsRegistry()
    reg.counter("requests_total", "all requests").inc()
    reg.counter("requests_total").inc(2)
    reg.gauge("depth").set(7)
    with pytest.raises(ValueError, match="registered"):
        reg.gauge("requests_total")
    snap = reg.snapshot()
    assert snap["metrics"]["requests_total"]["value"] == 3
    assert snap["metrics"]["depth"]["type"] == "gauge"
    text = reg.exposition()
    assert "# TYPE requests_total counter" in text
    assert "requests_total 3" in text
    assert "depth 7" in text
    path = reg.save(str(tmp_path / "m.json"))
    with open(path) as f:
        assert json.load(f)["metrics"]["depth"]["value"] == 7


# ------------------------------------------------------------ serving trace ----
def test_served_trace_carries_scheduling_vocabulary(tmp_path):
    """A preempting, deadline-bearing serve run records the full event
    vocabulary and the device-plane metrics land in every result —
    without perturbing the served answers (same engine config minus
    observers must produce identical root stats)."""
    def build(tracer=None, registry=None, metrics=False):
        eng = TPFIFOGameEngine(n_slots=1, grain=1, preempt_quanta=1,
                               n_workers=4, tree_cap=512, metrics=metrics,
                               tracer=tracer, registry=registry)
        for i, (g, n) in enumerate([("hex", 64), ("gomoku", 32),
                                    ("hex", 32)]):
            eng.submit(GameRequest(rid=i, game=g, board_size=SIZE,
                                   n_playouts=n, n_tasks=8, seed=i))
        eng.submit(GameRequest(rid=99, game="hex", board_size=SIZE,
                               n_playouts=64, n_tasks=8, seed=9,
                               deadline_s=0.0))      # expires immediately
        eng.run()
        return eng

    tr, reg = TraceRecorder(), MetricsRegistry()
    eng = build(tracer=tr, registry=reg, metrics=True)
    plain = build()

    names = {e["name"] for e in tr.events}
    assert {"admission", "quantum", "preempt", "retire", "deadline_expiry",
            "device_sync", "tick", "queue"} <= names
    assert validate_trace(tr.to_dict()) == len(tr.events)
    path = tr.save(str(tmp_path / "serve.json"))
    assert validate_trace(path) > 0

    for r_obs, r_plain in zip(eng.finished, plain.finished):
        assert r_obs.rid == r_plain.rid
        if not r_obs.result["deadline_expired"]:
            assert "metrics" in r_obs.result
            assert (r_obs.result["root_visits"]
                    == r_plain.result["root_visits"]).all()
        assert r_obs.result["best_move"] == r_plain.result["best_move"]
    m = reg.snapshot()["metrics"]
    assert m["serve_requests_finished_total"]["value"] == 4
    assert m["serve_preemptions_total"]["value"] == eng.stats().n_preemptions
    assert m["serve_deadline_expiries_total"]["value"] >= 1

    # every quantum span carries the work annotation profile.py consumes
    quanta = [e for e in tr.events if e["name"] == "quantum"]
    assert quanta and all(
        e["ph"] == "X" and "dur" in e and "rounds" in e["args"]
        and "iterations" in e["args"] for e in quanta)
    assert sum(e["args"]["rounds"] for e in quanta) == eng.stats().tokens


# -------------------------------------------------------- QueueStats fixes ----
def _ticket(out_len=0, preemptions=0, quanta=0, done_at=None):
    @dataclasses.dataclass
    class R:
        rid: int = 0
        out: list = dataclasses.field(default_factory=list)
        done: bool = False

    t = Ticket(req=R(out=list(range(out_len))), t_submit=0.0)
    t.preemptions = preemptions
    t.quanta = quanta
    if done_at is not None:
        t.t_admit = 0.1
        t.t_done = done_at
    return t


def test_queue_stats_reported_with_no_finished_requests():
    """Regression: a run that preempted requests but finished none used to
    report all-zero telemetry."""
    st = QueueStats.from_tickets([
        _ticket(out_len=3, preemptions=2, quanta=5),
        _ticket(out_len=1, preemptions=1, quanta=2)])
    assert st.n_finished == 0
    assert st.n_preemptions == 3
    assert st.quanta == 7
    assert st.tokens == 4
    assert st.wall_s == 0.0 and st.latency_p95 == 0.0


def test_queue_stats_mixed_finished_and_unfinished():
    st = QueueStats.from_tickets([
        _ticket(out_len=4, preemptions=1, quanta=3, done_at=1.0),
        _ticket(out_len=2, preemptions=2, quanta=2)])      # still queued
    assert st.n_finished == 1
    assert st.n_preemptions == 3                # unfinished work counted
    assert st.quanta == 5
    assert st.tokens == 6
    # percentiles/throughput stay defined over the finished set only
    assert st.latency_p50 == pytest.approx(1.0)
    assert st.throughput_tok_s == pytest.approx(4 / 1.0)


def test_engine_stats_cover_active_and_queued_tickets():
    """Mid-run stats() sees preemptions/quanta of requests that have not
    finished (search dispatch stubbed out for speed)."""
    with mock.patch("repro.serve.games.run_schedule_round",
                    lambda tree, board, cfg, key, rnd, cp: tree):
        eng = TPFIFOGameEngine(n_slots=1, grain=1, preempt_quanta=1,
                               n_workers=4, tree_cap=64)
        for i in range(3):
            eng.submit(GameRequest(rid=i, game="hex", board_size=SIZE,
                                   n_playouts=512, n_tasks=64, seed=i))
        eng.run(max_ticks=3, on_exhaust="ignore")   # deliberate early stop
    st = eng.stats()
    assert st.n_finished == 0
    assert st.n_unfinished == 3                # PR 9: leftovers are visible
    assert st.quanta > 0                       # progress before any finish
    assert st.tokens > 0
    assert st.n_preemptions > 0


# ----------------------------------------------------------------- profile ----
def _synthetic_trace(points, t_round_us, t_iter_us, workers=8):
    """X spans with dur = rounds*t_round + rounds*m*workers*t_iter."""
    evs, ts = [], 0.0
    for rounds, m in points:
        iters = rounds * m
        dur = rounds * t_round_us + iters * workers * t_iter_us
        evs.append({"name": "gscpm_round", "ph": "X", "pid": 0, "tid": 0,
                    "ts": ts, "dur": dur,
                    "args": {"rounds": rounds, "iterations": iters,
                             "workers": workers}})
        ts += dur + 10.0
    return {"traceEvents": evs}


def test_profile_fit_recovers_known_burden():
    from repro.obsv.profile import fit_dispatch_profile, measured_dag_model

    trace = _synthetic_trace(
        [(4, 2), (2, 16), (8, 1), (1, 64), (16, 4)],
        t_round_us=500.0, t_iter_us=2.0, workers=8)
    prof = fit_dispatch_profile(trace)
    assert prof["identifiable"]
    assert prof["n_workers"] == 8
    assert prof["t_round_s"] == pytest.approx(500e-6, rel=1e-6)
    assert prof["t_iter_s"] == pytest.approx(2e-6, rel=1e-6)
    # burden terms in t_iter units: t_round/t_iter, split over W lanes
    assert prof["t_round_units"] == pytest.approx(250.0, rel=1e-5)
    assert prof["t_spawn_units"] == pytest.approx(250.0 / 8, rel=1e-5)
    assert prof["fit_rms_rel"] < 1e-6
    model = measured_dag_model(prof)
    assert model.t_iter == 1.0
    assert model.t_round == pytest.approx(250.0, rel=1e-5)


def test_profile_fit_rank_deficient_fallback():
    from repro.obsv.profile import fit_dispatch_profile

    # all spans share one rounds:iterations ratio -> terms inseparable
    trace = _synthetic_trace([(2, 8), (4, 8), (8, 8)],
                             t_round_us=100.0, t_iter_us=1.0)
    prof = fit_dispatch_profile(trace)
    assert not prof["identifiable"]
    assert prof["t_iter_s"] > 0.0              # never a degenerate model


def test_profile_fit_excludes_compile_tainted_spans():
    from repro.obsv.profile import fit_dispatch_profile

    trace = _synthetic_trace(
        [(4, 2), (2, 16), (8, 1), (1, 64), (16, 4)],
        t_round_us=500.0, t_iter_us=2.0, workers=8)
    first = trace["traceEvents"][0]
    first["dur"] += 3_000_000.0                # a 3 s compile stall
    trace["traceEvents"].append(
        {"name": "jit_compile", "ph": "i", "s": "t", "pid": 0, "tid": 0,
         "ts": first["ts"] + 1.0, "args": {"fn": "run_chunk"}})
    prof = fit_dispatch_profile(trace)
    assert prof["n_excluded_compile"] == 1
    assert prof["t_round_s"] == pytest.approx(500e-6, rel=1e-4)


def test_profile_requires_dispatch_spans():
    from repro.obsv.profile import fit_dispatch_profile

    with pytest.raises(ValueError, match="dispatch spans"):
        fit_dispatch_profile({"traceEvents": [
            {"name": "tick", "ph": "X", "ts": 0, "dur": 1}]})


def test_measured_vs_analytic_table_renders():
    from repro.obsv.profile import (fit_dispatch_profile, format_table,
                                    measured_vs_analytic)

    trace = _synthetic_trace([(4, 2), (1, 64)], 500.0, 2.0)
    rows = measured_vs_analytic(fit_dispatch_profile(trace),
                                n_playouts=256, task_counts=(8, 64),
                                n_cores=61)
    assert [r["n_tasks"] for r in rows] == [8, 64]
    for r in rows:
        assert r["parallelism_measured"] <= r["parallelism_analytic"] * 1.01
        assert r["burdened_parallelism_measured"] > 0
    table = format_table(rows)
    assert "par(measured)" in table and len(table.splitlines()) == 4


# ------------------------------------------------------- traced search CLI ----
def test_gscpm_search_tracer_records_fittable_rounds():
    from repro.obsv.profile import fit_dispatch_profile

    tr = TraceRecorder()
    cfg = cfg_for("hex", n_playouts=32, n_tasks=8)
    board = cfg.game_obj.init_board()
    gscpm_search(board, 1, cfg, jax.random.key(0))          # warm
    for n_t in (4, 8, 16):
        c = dataclasses.replace(cfg, n_playouts=32, n_tasks=n_t)
        gscpm_search(board, 1, c, jax.random.key(0), tracer=tr)
    spans = [e for e in tr.events if e["name"] == "gscpm_round"]
    assert spans and all(e["args"]["rounds"] == 1 for e in spans)
    expect = sum(
        r.m
        for n_t in (4, 8, 16)
        for r in scheduler.make_schedule(32, n_t, cfg.n_workers,
                                         cfg.scheduler))
    assert sum(e["args"]["iterations"] for e in spans) == expect
    prof = fit_dispatch_profile(tr, n_workers=cfg.n_workers)
    assert prof["t_iter_s"] >= 0.0
    assert validate_trace(tr.to_dict()) == len(tr.events)
