"""Chaos suite for the TPFIFO serving stack (DESIGN.md §17).

The center-of-gravity pin: under a seeded ``FaultPlan`` (dispatch
failures, NaN poisoning, clock stalls, duplicate submissions) the engine
completes every non-shed request with results **bit-identical** to a
fault-free run of the same seeds, never crashes the driver loop,
quarantines failing slots while serving on the survivors, and does all
of it with ZERO new jit compilations.

Class-key discipline: jit caches are shared across the pytest process,
so this file owns the (board_size=5, tree_cap=256) game classes —
test_serve_games owns 512@5/6, test_obsv owns 384@4, test_reroot owns
1024@5. Compile-count assertions here stay meaningful as long as no
other file serves these classes.
"""

from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypcompat import given, settings, st

from repro.core import scheduler
from repro.core.gscpm import gscpm_search, run_chunk
from repro.core.tree import init_tree, root_summary
from repro.serve import resilience as rz
from repro.serve.games import GameRequest, TPFIFOGameEngine

SIZE = 5
CAP = 256
WORKERS = 4


def engine(**kw):
    kw.setdefault("n_slots", 1)
    kw.setdefault("grain", 1)
    kw.setdefault("n_workers", WORKERS)
    kw.setdefault("tree_cap", CAP)
    return TPFIFOGameEngine(**kw)


def req(rid, game="hex", **kw):
    kw.setdefault("board_size", SIZE)
    kw.setdefault("n_playouts", 64)
    kw.setdefault("n_tasks", 16)     # 4 schedule rounds at W=4
    kw.setdefault("seed", rid if isinstance(rid, int) else 0)
    return GameRequest(rid=rid, game=game, **kw)


def reference(eng, r):
    """The uninterrupted search a recovered request must match bit-for-bit."""
    cfg = eng.request_cfg(r)
    board = (cfg.game_obj.init_board() if r.board is None
             else jnp.asarray(r.board, jnp.int8))
    tree, _ = gscpm_search(board, r.to_move, cfg, jax.random.key(r.seed))
    return root_summary(tree, cfg.game_obj.n_actions)


def assert_same_search(r, ref):
    np.testing.assert_array_equal(r.result["root_visits"],
                                  ref["root_visits"])
    np.testing.assert_array_equal(r.result["root_wins"], ref["root_wins"])
    assert r.result["best_move"] == ref["best_move"]
    assert r.result["root_value"] == ref["root_value"]


@pytest.fixture(scope="module")
def warm():
    """Compile both game classes once so compile-count deltas isolate
    chaos churn from first-touch compilation."""
    eng = engine(n_slots=1)
    eng.submit(req("warm-hex", "hex", seed=0))
    eng.submit(req("warm-gom", "gomoku", seed=0))
    eng.run()
    return run_chunk._cache_size()


# -------------------------------------------------------------- fault plan ----
def test_fault_plan_deterministic_and_seeded():
    a = rz.FaultPlan.generate(seed=9, n_ticks=50, n_slots=4, rate=0.2)
    b = rz.FaultPlan.generate(seed=9, n_ticks=50, n_slots=4, rate=0.2)
    c = rz.FaultPlan.generate(seed=10, n_ticks=50, n_slots=4, rate=0.2)
    assert a.events == b.events
    assert a.events != c.events
    assert all(ev.kind in rz.FAULT_KINDS for ev in a.events)
    assert all(0 <= ev.tick < 50 and 0 <= ev.slot < 4 for ev in a.events)
    # rate sanity on the Bernoulli grid: 200 cells at p=.2 -> ~40
    assert 10 <= len(a.events) <= 80


def test_fault_plan_validates_inputs():
    with pytest.raises(ValueError):
        rz.FaultPlan.generate(seed=0, n_ticks=5, n_slots=1, rate=1.5)
    with pytest.raises(ValueError):
        rz.FaultPlan.generate(seed=0, n_ticks=5, n_slots=1, rate=0.1,
                              kinds=("segfault",))


def test_injector_arms_per_tick_and_counts_fired():
    plan = rz.FaultPlan(events=(
        rz.FaultEvent(tick=0, slot=0, kind="dispatch_error"),
        rz.FaultEvent(tick=0, slot=1, kind="poison_nan"),
        rz.FaultEvent(tick=1, slot=0, kind="clock_stall", stall_s=1.0),
    ))
    inj = rz.FaultInjector(plan)
    driver_evs = inj.begin_tick(0)
    assert driver_evs == []                       # both tick-0 kinds are slot-level
    assert inj.dispatch_fault(1) is None          # wrong slot
    assert inj.dispatch_fault(0).kind == "dispatch_error"
    assert inj.dispatch_fault(0) is None          # consumed
    assert inj.poison(1).kind == "poison_nan"
    driver_evs = inj.begin_tick(1)
    assert [ev.kind for ev in driver_evs] == ["clock_stall"]
    assert inj.dispatch_fault(0) is None          # tick 0 events disarmed
    inj.record_fired(plan.events[0])
    assert inj.summary()["fired"] == {"dispatch_error": 1}


# ------------------------------------------------------------ result guard ----
def _good_res(n=4, total=8.0):
    v = np.full(n, total / n)
    return {"root_visits": v, "root_wins": v * 0.5, "best_move": 0,
            "root_value": 0.5, "tree_nodes": n + 1}


def test_validate_result_accepts_clean_and_flags_each_violation():
    assert rz.validate_result(_good_res(), 8) == []
    assert rz.validate_result(_good_res(), None) == []   # warm: no conservation
    bad = _good_res()
    bad["root_wins"] = bad["root_wins"] + np.nan
    assert any("wins not finite" in v for v in rz.validate_result(bad, 8))
    bad = _good_res()
    bad["root_visits"][0] = -1.0
    out = rz.validate_result(bad, 8)
    assert any("non-negative" in v for v in out)
    bad = _good_res()
    bad["root_wins"][0] = bad["root_visits"][0] + 1     # wins > visits
    assert any("outside [0, visits]" in v for v in rz.validate_result(bad, 8))
    assert any("conservation" in v for v in rz.validate_result(_good_res(), 9))
    bad = _good_res()
    bad["root_value"] = float("nan")
    assert any("root value" in v for v in rz.validate_result(bad, 8))
    bad = _good_res()
    bad["best_move"] = 99
    assert any("best_move" in v for v in rz.validate_result(bad, 8))


# --------------------------------------------------------------- snapshots ----
def test_snapshot_restore_roundtrip_and_poison_detection():
    tree = init_tree(64, 8, 1)
    tree = tree._replace(visits=tree.visits.at[0].set(4.0),
                         wins=tree.wins.at[0].set(2.0))
    snap = rz.snapshot_search(tree, None, round_idx=2, playouts=16, out_len=2)
    assert rz.snapshot_is_clean(snap)
    back, metrics = rz.restore_search(snap)
    assert metrics is None
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype
    dirty = rz.snapshot_search(rz.poison_root_stats(tree), None, 2, 16, 2)
    assert not rz.snapshot_is_clean(dirty)


# ---------------------------------------------------- recovery bit-identity ----
def test_dispatch_fault_retries_bit_identical(warm):
    plan = rz.FaultPlan(events=(
        rz.FaultEvent(tick=1, slot=0, kind="dispatch_error"),
        rz.FaultEvent(tick=2, slot=0, kind="dispatch_error"),
    ))
    inj = rz.FaultInjector(plan)
    eng = engine(injector=inj, retry_backoff=(1, 2))
    r = req("df", seed=3)
    assert eng.submit(r)
    eng.run(max_ticks=500)
    assert inj.fired["dispatch_error"] >= 1
    assert r.result["status"] == "answered"
    assert r.result["retries"] >= 1
    assert eng.stats().n_retries >= 1
    assert_same_search(r, reference(eng, r))
    assert run_chunk._cache_size() == warm      # zero recompiles


def test_poison_guard_rejects_and_recovers_bit_identical(warm):
    plan = rz.FaultPlan(events=(
        rz.FaultEvent(tick=2, slot=0, kind="poison_nan"),))
    inj = rz.FaultInjector(plan)
    eng = engine(injector=inj)
    r = req("poison", seed=7)
    eng.submit(r)
    eng.run(max_ticks=500)
    assert inj.fired["poison_nan"] == 1
    # the corrupted answer never shipped: it became a retry that recovered
    assert r.result["status"] == "answered"
    assert r.result["retries"] >= 1
    assert np.isfinite(r.result["root_wins"]).all()
    assert_same_search(r, reference(eng, r))
    assert run_chunk._cache_size() == warm


def test_mixed_chaos_generated_plan_all_complete_bit_identical(warm):
    plan = rz.FaultPlan.generate(seed=13, n_ticks=60, n_slots=4, rate=0.3)
    inj = rz.FaultInjector(plan)
    eng = engine(n_slots=2, grain=2, injector=inj, quarantine_after=3,
                 retry_backoff=(1, 4))
    reqs = [req(i, ("hex", "gomoku")[i % 2], seed=i) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=5000)
    for r in reqs:
        assert r.result["status"] == "answered"
        assert_same_search(r, reference(eng, r))
    assert run_chunk._cache_size() == warm


# ---------------------------------------------------------------- quarantine ----
def test_slot_quarantined_after_consecutive_failures_serves_on_survivor(warm):
    # slot 0 fails its dispatch EVERY tick; slot 1 is healthy
    evs = tuple(rz.FaultEvent(tick=t, slot=0, kind="dispatch_error")
                for t in range(100))
    eng = engine(n_slots=2, injector=rz.FaultInjector(rz.FaultPlan(evs)),
                 quarantine_after=2)
    reqs = [req(i, seed=i) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=5000)
    st = eng.stats()
    assert st.n_quarantined == 1
    assert st.n_retries >= 2                    # the strikes that led there
    for r in reqs:
        assert r.result["status"] == "answered"
        assert_same_search(r, reference(eng, r))
    assert run_chunk._cache_size() == warm


def test_last_healthy_slot_never_quarantined():
    # every slot faulted every tick: at most n_slots-1 quarantines, and the
    # engine still drains on the last healthy slot once the plan runs dry
    evs = tuple(rz.FaultEvent(tick=t, slot=s, kind="dispatch_error")
                for t in range(8) for s in range(2))
    eng = engine(n_slots=2, injector=rz.FaultInjector(rz.FaultPlan(evs)),
                 quarantine_after=2, retry_backoff=(1, 2))
    reqs = [req(f"lh{i}", seed=i) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=5000)
    assert eng.stats().n_quarantined <= 1
    assert all(r.result["status"] == "answered" for r in reqs)


# ------------------------------------------------------- shedding / dedup ----
def test_bounded_admission_sheds_with_status(warm):
    eng = engine(max_queue=2)
    rs = [req(f"s{i}", seed=i) for i in range(4)]
    assert eng.submit(rs[0]) and eng.submit(rs[1])
    assert not eng.submit(rs[2])               # class queue full -> shed
    assert rs[2].done and rs[2].result["status"] == "shed"
    assert rs[2].result["reason"] == "queue_full"
    # shedding is PER CLASS: a gomoku request still gets in
    g = req("g0", "gomoku", seed=1)
    assert eng.submit(g)
    eng.run(max_ticks=2000)
    st = eng.stats()
    assert st.n_shed == 1
    assert {r.rid for r in eng.finished} == {"s0", "s1", "g0"}
    for r in (rs[0], rs[1], g):
        assert_same_search(r, reference(eng, r))


def test_duplicate_submission_dropped_not_double_served():
    eng = engine()
    r = req("dup", seed=2)
    assert eng.submit(r)
    assert not eng.submit(r)                   # same rid still pending
    eng.run(max_ticks=1000)
    assert len(eng.finished) == 1
    assert r.result["status"] == "answered"


def test_injected_duplicate_submit_is_deduped(warm):
    plan = rz.FaultPlan(events=(
        rz.FaultEvent(tick=1, slot=0, kind="duplicate_submit"),))
    inj = rz.FaultInjector(plan)
    eng = engine(injector=inj)
    rs = [req(f"q{i}", seed=i) for i in range(2)]
    for r in rs:
        eng.submit(r)
    eng.run(max_ticks=1000)
    assert inj.fired.get("duplicate_submit", 0) == 1
    assert len(eng.finished) == 2              # each original served once
    assert all(r.result["status"] == "answered" for r in rs)


# ------------------------------------------------------------- clock stall ----
def test_clock_stall_expires_deadline_cleanly(warm):
    plan = rz.FaultPlan(events=(
        rz.FaultEvent(tick=1, slot=0, kind="clock_stall", stall_s=60.0),))
    inj = rz.FaultInjector(plan)
    eng = engine(injector=inj)
    r = req("cs", seed=4, deadline_s=30.0)
    eng.submit(r)
    eng.run(max_ticks=500)
    assert inj.fired["clock_stall"] == 1
    assert r.result["status"] == "deadline_expired"
    assert r.result["deadline_expired"]
    assert 0 < r.result["rounds"] < r.result["rounds_total"]
    assert np.isfinite(r.result["root_wins"]).all()   # partial stats, clean
    assert run_chunk._cache_size() == warm


# --------------------------------------------------------- exhaust detection ----
def test_run_exhaust_raises_with_unfinished_rids():
    with mock.patch("repro.serve.games.run_schedule_round",
                    lambda tree, board, cfg, key, rnd, cp: tree):
        eng = engine(preempt_quanta=1, tree_cap=64, guard=False)
        for i in range(3):
            eng.submit(req(i, seed=i))
        with pytest.raises(RuntimeError, match="max_ticks=2 exhausted"):
            eng.run(max_ticks=2)
        with pytest.warns(RuntimeWarning, match="unfinished"):
            eng.run(max_ticks=1, on_exhaust="warn")
        assert eng.stats().n_unfinished == 3
        eng.run(on_exhaust="ignore", max_ticks=1)     # deliberate early stop


# --------------------------------------------------------- submit validation ----
def test_submit_validation_typed_errors():
    eng = engine()
    with pytest.raises(ValueError, match="n_playouts"):
        eng.submit(req("v0", n_playouts=0))
    with pytest.raises(ValueError, match="n_playouts"):
        eng.submit(req("v1", n_playouts=2.5))
    with pytest.raises(ValueError, match="n_tasks"):
        eng.submit(req("v2", n_tasks=-1))
    with pytest.raises(ValueError, match="to_move"):
        eng.submit(req("v3", to_move=3))
    with pytest.raises(ValueError, match="cp"):
        eng.submit(req("v4", cp=float("nan")))
    with pytest.raises(ValueError, match="cp"):
        eng.submit(req("v5", cp=-0.5))
    with pytest.raises(TypeError, match="cp"):
        eng.submit(req("v6", cp="high"))
    with pytest.raises(ValueError, match="deadline_s"):
        eng.submit(req("v7", deadline_s=-1.0))
    with pytest.raises(ValueError, match="deadline_s"):
        eng.submit(req("v8", deadline_s=float("inf")))
    with pytest.raises(ValueError, match="board shape"):
        eng.submit(req("v9", board=np.zeros(7, np.int8)))
    with pytest.raises(TypeError, match="board dtype"):
        eng.submit(req("v10", board=np.zeros(SIZE * SIZE, np.float32)))
    with pytest.raises(ValueError, match="board cells"):
        eng.submit(req("v11", board=np.full(SIZE * SIZE, 7, np.int8)))
    with pytest.raises(ValueError):
        eng.submit(req("v12", game="chess"))          # unregistered game
    assert not eng.has_work()                         # nothing leaked in


# --------------------------------------------------------- chaos drain (PBT) ----
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_chaos_trace_always_drains(seed, warm):
    """Mixed hex+gomoku Poisson trace + random fault plan: the engine
    always drains; every request ends in exactly one of
    answered | shed | deadline_expired; answered results pass the guard;
    fully-run answered searches are bit-identical to fault-free refs."""
    rng = np.random.default_rng(seed)
    plan = rz.FaultPlan.generate(
        seed=seed, n_ticks=80, n_slots=4, rate=float(rng.uniform(0.05, 0.4)))
    eng = engine(n_slots=2, grain=int(rng.integers(1, 3)),
                 injector=rz.FaultInjector(plan), quarantine_after=3,
                 max_queue=8, retry_backoff=(1, 4))
    n = int(rng.integers(4, 9))
    reqs = [req(i, ("hex", "gomoku")[int(rng.integers(2))], seed=i,
                deadline_s=(None if rng.random() < 0.7
                            else float(rng.uniform(0.5, 2.0))))
            for i in range(n)]
    arrivals = np.cumsum(rng.exponential(0.01, n))
    eng.run_trace(list(zip(arrivals, reqs)), max_ticks=20_000)
    statuses = {r.rid: r.result["status"] for r in reqs}
    assert all(s in ("answered", "shed", "deadline_expired")
               for s in statuses.values())
    assert all(r.done for r in reqs)
    for r in reqs:
        if r.result["status"] != "answered":
            continue
        expected = (None if r.result.get("reused_visits")
                    else r.result["playouts"])
        assert rz.validate_result(r.result, expected) == []
        if r.result["rounds"] == r.result["rounds_total"]:
            assert_same_search(r, reference(eng, r))
    assert run_chunk._cache_size() == warm
