"""Dry-run machinery smoke: one small cell on an 8-device subprocess.

The full 40-cell x 2-mesh sweep runs via ``python -m repro.launch.dryrun
--all`` (artifacts/dryrun); this test proves the plumbing (input specs,
shardings, lower+compile, cost extraction) on a reduced mesh quickly.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.compat import make_auto_mesh
from repro.launch import inputs as inp
from repro.launch import dryrun
from repro.roofline import hlo_costs
mesh = make_auto_mesh((4, 2), ("data", "model"))
arch, shape = sys.argv[1], sys.argv[2]
ov = {"n_layers": 2, "d_model": 256, "n_heads": 4, "n_kv_heads": 2,
      "d_ff": 512, "vocab": 4096}
lowered, cfg, spec, rules = dryrun.lower_cell(arch, shape, mesh,
                                              cfg_overrides=ov, unroll=False)
compiled = lowered.compile()
mem = compiled.memory_analysis()
costs = hlo_costs.rollup(compiled.as_text())
assert costs.flops > 0, "parser found no flops"
assert mem.temp_size_in_bytes > 0
print("OK", costs.flops, costs.coll_count)
"""


@pytest.mark.parametrize("arch,shape", [
    ("smollm-135m", "train_4k"),
    ("smollm-135m", "decode_32k"),
    ("qwen1.5-0.5b", "prefill_32k"),
])
def test_dryrun_cell_subprocess(arch, shape):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT, arch, shape],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_input_specs_all_cells():
    """input_specs builds (abstractly, no devices needed) for all 40 cells."""
    from repro import configs
    from repro.launch.inputs import input_specs
    n = 0
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        for sname, sh in configs.SHAPES.items():
            ok, why = configs.applicable(cfg, sh)
            if not ok:
                assert "full-attn" in why
                continue
            spec = input_specs(arch, sname)
            assert spec["cfg"].vocab == cfg.vocab
            n += 1
    assert n == 32  # 40 logical cells - 8 long_500k full-attn skips

def test_cell_count_documented():
    """10 archs x 4 shapes = 40; long_500k runs only for zamba2 + xlstm."""
    from repro import configs
    total = len(configs.ARCHS) * len(configs.SHAPES)
    assert total == 40
    runnable = 0
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        runnable += sum(
            1 for sh in configs.SHAPES.values()
            if configs.applicable(cfg, sh)[0])
    assert runnable == 32
