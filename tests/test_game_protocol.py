"""Game-protocol conformance suite (DESIGN.md §13).

Every REGISTERED game is run against the seam's contracts: legal_mask/place
round-trips, protocol-driven play reaches a terminal position, winners agree
with a pure-python reference, the fused ``playout_batch`` is bit-identical
to the vmapped per-lane ``playout_scalar`` oracle, and whole GSCPM searches
through the seam hold the tree invariants (``check_invariants`` — including
the draw-aware value range) on random positions. Gomoku-specific tests pin
the draw path (value 0 → credit 0.5) through ``backup_paths``,
``root_move_stats``, and a forced-draw end-to-end search, plus the
mid-board terminal semantics (a five empties ``legal_mask``, so won
positions are evaluated, never expanded). A source check keeps the search
core free of direct game imports.
"""

from __future__ import annotations

import dataclasses
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import game as game_mod
from repro.core.gscpm import GSCPMConfig, gscpm_search
from repro.core.tree import (backup_paths, check_invariants, init_tree,
                             root_move_stats, root_value)

GAME_SIZES = {"hex": 5, "gomoku": 7}
GAMES = sorted(game_mod.available_games())


def make(name: str):
    return game_mod.make_game(name, GAME_SIZES[name])


def random_board(game, rng: np.random.Generator, fill: float) -> jnp.ndarray:
    """Alternating random stones on `fill` of the cells (may be terminal)."""
    n = game.n_cells
    b = np.zeros(n, dtype=np.int8)
    idx = rng.permutation(n)[: int(n * fill)]
    for t, i in enumerate(idx):
        b[i] = 1 if t % 2 == 0 else 2
    return jnp.asarray(b)


def played_board(game, rng: np.random.Generator, n_moves: int):
    """A position reached by LEGAL protocol play (never past the end)."""
    b = game.init_board()
    player = 1
    for _ in range(n_moves):
        legal = np.flatnonzero(np.asarray(game.legal_mask(b)))
        if len(legal) == 0:
            break
        b = game.place(b, jnp.int32(rng.choice(legal)), jnp.int32(player))
        player = 3 - player
    return b, player


# ------------------------------------------------------------- registry ----
def test_registry_lists_builtin_games():
    assert {"hex", "gomoku"} <= set(game_mod.available_games())
    with pytest.raises(ValueError, match="unknown game"):
        game_mod.make_game("chess", 8)


def test_games_of_equal_size_are_distinct():
    """Game objects must compare/hash by TYPE, not just fields: plain
    NamedTuple equality would make HexGame(7) == GomokuGame(7), and a jit
    cache keyed on a static game argument (mcts._run) would silently run
    one game's compiled program on the other's boards."""
    h = game_mod.make_game("hex", 7)
    g = game_mod.make_game("gomoku", 7)
    assert h != g and g != h
    assert hash(h) != hash(g)
    assert h == game_mod.make_game("hex", 7)
    assert h != game_mod.make_game("hex", 9)
    # end-to-end: same (shape, cp, n_iters) sequential searches must NOT
    # share a program — the gomoku tree sees draws (half credits), which
    # the hex program can never produce
    from repro.core.mcts import uct_search

    key = jax.random.PRNGKey(0)
    board = h.init_board()
    t_hex, _ = uct_search(board, 1, 48, key, board_size=7, tree_cap=512)
    t_gom, _ = uct_search(board, 1, 48, key, board_size=7, tree_cap=512,
                          game="gomoku")
    assert not np.array_equal(np.asarray(t_hex.wins),
                              np.asarray(t_gom.wins))


def test_search_core_is_game_agnostic():
    """The acceptance bar: no direct game coupling left in the search core."""
    from repro.core import gscpm, mcts, root_parallel

    for mod in (gscpm, mcts, root_parallel):
        src = inspect.getsource(mod)
        assert "import hex" not in src and "hx." not in src, mod.__name__


# ------------------------------------------------------ protocol contracts ----
@pytest.mark.parametrize("name", GAMES)
def test_legal_place_roundtrip(name):
    g = make(name)
    rng = np.random.default_rng(7)
    for fill in (0.0, 0.3, 0.6):
        b = random_board(g, rng, fill)
        legal = np.asarray(g.legal_mask(b))
        assert legal.shape == (g.n_cells,)
        # legal moves are a subset of the empty cells
        assert not (legal & (np.asarray(b) != 0)).any()
        if legal.any():
            mv = int(np.flatnonzero(legal)[0])
            b2 = g.place(b, jnp.int32(mv), jnp.int32(1))
            assert int(b2[mv]) == 1
            np.testing.assert_array_equal(
                np.delete(np.asarray(b2), mv), np.delete(np.asarray(b), mv))
            assert not bool(g.legal_mask(b2)[mv])


@pytest.mark.parametrize("name", GAMES)
def test_protocol_play_reaches_terminal(name):
    """Playing legal moves must end within max_moves, at a position that is
    terminal_batch-positive and legal_mask-empty, with a defined winner."""
    g = make(name)
    rng = np.random.default_rng(11)
    b, _ = played_board(g, rng, g.max_moves + 1)
    assert bool(g.terminal_batch(b[None])[0])
    assert not np.asarray(g.legal_mask(b)).any()
    w = int(g.winner_batch(b[None])[0])
    assert w in (0, 1, 2)
    if name == "hex":
        assert w != 0  # Hex theorem: no draws


def py_hex_winner(board: np.ndarray, size: int) -> int:
    """Flood-fill reference winner of a FILLED hex board."""
    deltas = [(-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0)]
    seen = set()
    stack = [(0, c) for c in range(size) if board[c] == 1]
    while stack:
        r, c = stack.pop()
        if (r, c) in seen:
            continue
        seen.add((r, c))
        if r == size - 1:
            return 1
        for dr, dc in deltas:
            rr, cc = r + dr, c + dc
            if (0 <= rr < size and 0 <= cc < size
                    and board[rr * size + cc] == 1 and (rr, cc) not in seen):
                stack.append((rr, cc))
    return 2


def py_gomoku_winner(board: np.ndarray, size: int) -> int:
    """Line-scan reference: 1/2 if that color owns a five (black priority,
    matching `winner_scan_batch` on illegal double-five boards), else 0."""
    grid = board.reshape(size, size)
    for p in (1, 2):
        for dr, dc in ((0, 1), (1, 0), (1, 1), (1, -1)):
            for r in range(size):
                for c in range(size):
                    rr, cc = r + 4 * dr, c + 4 * dc
                    if not (0 <= rr < size and 0 <= cc < size):
                        continue
                    if all(grid[r + k * dr, c + k * dc] == p
                           for k in range(5)):
                        return p
    return 0


@pytest.mark.parametrize("name", GAMES)
def test_winner_matches_python_reference(name):
    g = make(name)
    size = GAME_SIZES[name]
    rng = np.random.default_rng(size)
    ref = {"hex": py_hex_winner, "gomoku": py_gomoku_winner}[name]
    # hex's winner contract needs filled boards; gomoku's scan is defined
    # (five-or-nothing) on any board
    fills = (1.0,) if name == "hex" else (0.3, 0.6, 1.0)
    for fill in fills:
        boards = jnp.stack([random_board(g, rng, fill) for _ in range(16)])
        got = np.asarray(g.winner_batch(boards))
        want = np.asarray([ref(np.asarray(b), size) for b in boards])
        np.testing.assert_array_equal(got, want, err_msg=f"{name} {fill=}")


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), name=st.sampled_from(GAMES))
def test_playout_batch_bit_identical_to_scalar(seed, name):
    """The fused (W, cells) playout equals W vmapped per-lane oracles —
    for Gomoku that pits the completion-time formulation against the
    sequential move-by-move loop (same RNG stream per lane)."""
    g = make(name)
    rng = np.random.default_rng(seed)
    W = 8
    boards = jnp.stack(
        [random_board(g, rng, float(rng.uniform(0.0, 0.6))) for _ in range(W)])
    keys = jax.random.split(jax.random.PRNGKey(seed), W)
    to_move = 1 + seed % 2
    got = g.playout_batch(boards, to_move, keys)
    want = jax.vmap(
        lambda b, k: g.playout_scalar(b, jnp.int32(to_move), k))(boards, keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("name", GAMES)
def test_winner_probe_contract(name):
    """``winner_probe`` is the PARTIAL-board status probe (-1 ongoing,
    0 draw, 1|2 winner) the session layer polls after every move — unlike
    ``winner_batch``, whose contract only covers terminal boards. An empty
    board is ongoing; a legally played-out terminal board must agree with
    ``winner_batch``."""
    g = make(name)
    assert int(g.winner_probe(g.init_board())) == -1
    rng = np.random.default_rng(23)
    b, _ = played_board(g, rng, g.max_moves + 1)
    w = int(g.winner_probe(b))
    assert w == int(g.winner_batch(b[None])[0])
    assert w >= 0


def test_winner_probe_detects_midboard_wins():
    """A win must register the move it appears, long before the board
    fills: a black top-bottom chain on hex, a black five on gomoku."""
    size = 5
    hexes = make("hex")
    b = np.zeros(size * size, dtype=np.int8)
    for r in range(size):
        b[r * size] = 1                       # column 0, rows 0..4
    assert int(hexes.winner_probe(jnp.asarray(b))) == 1
    g5 = game_mod.make_game("gomoku", size)
    assert int(g5.winner_probe(jnp.asarray(b))) == 1  # a vertical five
    b[2 * size] = 0                           # break both chains
    assert int(hexes.winner_probe(jnp.asarray(b))) == -1


def test_winner_probe_gomoku_draw_only_when_full():
    """The forced-draw position stays ONGOING while empties remain (either
    player could still move) and becomes a DRAW once filled."""
    g5 = game_mod.make_game("gomoku", 5)      # the draw position is 5x5
    b = drawn_gomoku_position()
    assert int(g5.winner_probe(b)) == -1
    full = jnp.asarray(np.where(np.asarray(b) == 0, 1, np.asarray(b)))
    assert int(g5.winner_probe(full)) == 0


# ----------------------------------------------------- search through seam ----
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), name=st.sampled_from(GAMES),
       workers=st.sampled_from([2, 8]))
def test_property_search_invariants_every_game(seed, name, workers):
    """GSCPM through the seam holds the (draw-aware) tree invariants and the
    [0, 1] value range from arbitrary legally-reached positions."""
    g = make(name)
    rng = np.random.default_rng(seed)
    b, player = played_board(g, rng, int(rng.integers(0, 10)))
    cfg = GSCPMConfig(game=name, board_size=GAME_SIZES[name], n_playouts=64,
                      n_tasks=8, n_workers=workers, tree_cap=4096)
    tree, stats = gscpm_search(b, player, cfg, jax.random.PRNGKey(seed))
    check_invariants(tree)
    assert 0.0 <= stats["root_value"] <= 1.0
    assert int(np.asarray(tree.visits[0])) == stats["playouts"]


@pytest.mark.parametrize("name", GAMES)
def test_full_search_scalar_paths_bit_identical(name):
    """descent/playout oracle configs survive the seam for every game."""
    g = make(name)
    base = GSCPMConfig(game=name, board_size=GAME_SIZES[name], n_playouts=64,
                       n_tasks=8, n_workers=4, tree_cap=2048)
    key = jax.random.PRNGKey(29)
    t0, s0 = gscpm_search(g.init_board(), 1, base, key)
    for repl in ({"playout": "scalar"}, {"descent": "scalar"}):
        t1, s1 = gscpm_search(g.init_board(), 1,
                              dataclasses.replace(base, **repl), key)
        nn = int(t0.n_nodes)
        assert nn == int(t1.n_nodes), repl
        for f in ("parent", "move", "to_move", "n_children"):
            np.testing.assert_array_equal(
                np.asarray(getattr(t0, f)[:nn]),
                np.asarray(getattr(t1, f)[:nn]), err_msg=f"{repl} {f}")
        np.testing.assert_allclose(np.asarray(t0.visits[:nn]),
                                   np.asarray(t1.visits[:nn]))
        np.testing.assert_allclose(np.asarray(t0.wins[:nn]),
                                   np.asarray(t1.wins[:nn]))


def test_hex_game_methods_match_module_functions():
    """The seam adds NO computation on Hex: protocol methods are bit-equal
    to the pre-refactor module entry points (same RNG schedule ⇒ the
    pre-seam trees are preserved — the PR 3/4 equivalence pattern)."""
    from repro.core import hex as hx

    g = game_mod.make_game("hex", 5)
    spec = hx.HexSpec(5)
    rng = np.random.default_rng(0)
    W = 8
    boards = jnp.stack([random_board(g, rng, 0.4) for _ in range(W)])
    keys = jax.random.split(jax.random.PRNGKey(1), W)
    np.testing.assert_array_equal(
        np.asarray(g.playout_batch(boards, 1, keys)),
        np.asarray(hx.playout_batch(boards, 1, keys, spec)))
    filled = hx.random_fill_batch(boards, 1, keys, spec)
    np.testing.assert_array_equal(
        np.asarray(g.winner_batch(filled)),
        np.asarray(hx.winner_batch(filled, spec)))
    np.testing.assert_array_equal(
        np.asarray(g.legal_mask(boards[0])),
        np.asarray(hx.legal_mask(boards[0])))
    mvs = jnp.asarray([3, 9, 0, 17], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(g.replay_moves(mvs, jnp.int32(3), jnp.int32(1))),
        np.asarray(hx.replay_moves(mvs, jnp.int32(3), jnp.int32(1), spec)))


# -------------------------------------------------------- gomoku: the draw ----
def drawn_gomoku_position():
    """5x5 free-style position where EVERY completion is a draw: each of the
    12 five-windows already contains both colors among its fixed stones, so
    neither player can ever own one, whatever fills the two empties."""
    pattern = [
        1, 1, 2, 1, 1,
        2, 2, 1, 2, 2,
        1, 1, 2, 1, 1,
        2, 2, 1, 2, 2,
        1, 1, 2, 1, 1,
    ]
    b = np.asarray(pattern, dtype=np.int8)
    b[5] = 0   # (1, 0)
    b[19] = 0  # (3, 4)
    return jnp.asarray(b)


def test_gomoku_draw_credit_through_backup_paths():
    """A draw (value 0) credits every node on the path 0.5 — between the
    loss (0) and the win (1), the first non-{0,1} increment the tree sees."""
    tree = init_tree(16, 25, 1)
    from repro.core.gscpm import expand_batch

    tree, ids = expand_batch(tree, jnp.array([0, 0]), jnp.array([3, 7]),
                             jnp.ones(2, bool))
    paths = jnp.stack([jnp.array([0, ids[0]]), jnp.array([0, ids[1]])])
    values = jnp.array([0, 1], dtype=jnp.int8)   # one draw, one BLACK win
    tree = backup_paths(tree, paths, values, jnp.ones(2))
    assert float(tree.visits[0]) == 2.0
    # mover-into-root is WHITE (to_move=1): draw pays 0.5, BLACK's win 0
    assert float(tree.wins[0]) == 0.5
    # children's mover is BLACK: draw pays 0.5, the BLACK win pays 1
    assert float(tree.wins[ids[0]]) == 0.5
    assert float(tree.wins[ids[1]]) == 1.0
    v, w = root_move_stats(tree, 25)
    assert float(v[3]) == 1.0 and float(w[3]) == 0.5
    assert float(v[7]) == 1.0 and float(w[7]) == 1.0
    check_invariants(tree)


def test_gomoku_all_draw_search_is_exactly_half():
    """End-to-end: from the forced-draw position every playout returns 0,
    so wins == visits/2 at every node and root_value == 0.5 exactly."""
    b = drawn_gomoku_position()
    cfg = GSCPMConfig(game="gomoku", board_size=5, n_playouts=64, n_tasks=8,
                      n_workers=4, tree_cap=512)
    tree, stats = gscpm_search(b, 1, cfg, jax.random.PRNGKey(5))
    check_invariants(tree)
    assert stats["root_value"] == 0.5
    nn = int(tree.n_nodes)
    np.testing.assert_allclose(np.asarray(tree.wins[:nn]),
                               np.asarray(tree.visits[:nn]) / 2.0)
    v, w = root_move_stats(tree, 25)
    np.testing.assert_allclose(np.asarray(w), np.asarray(v) / 2.0)


def test_gomoku_finds_immediate_win():
    """Black has an open four on row 3 of a 7x7 board; either extension
    (cells 21 / 26) wins outright — the winning child's value is exactly 1
    (every playout from a won position returns its pre-existing winner)."""
    size = 7
    b = np.zeros(size * size, dtype=np.int8)
    for c in (1, 2, 3, 4):
        b[3 * size + c] = 1
    for cell in (0, 6, 42, 48):
        b[cell] = 2
    cfg = GSCPMConfig(game="gomoku", board_size=size, n_playouts=512,
                      n_tasks=16, n_workers=8, tree_cap=8192)
    tree, stats = gscpm_search(jnp.asarray(b), 1, cfg, jax.random.PRNGKey(2))
    win_moves = (3 * size + 0, 3 * size + 5)
    assert stats["best_move"] in win_moves
    kids = np.asarray(tree.children[0][: int(tree.n_children[0])])
    j = kids[list(np.asarray(tree.move)[kids]).index(stats["best_move"])]
    assert float(tree.wins[j]) == float(tree.visits[j]) > 0


def test_gomoku_won_position_is_terminal_not_expanded():
    """A position already containing a five has NO legal moves: the search
    cannot grow past the end of the game, and every playout backs up the
    pre-existing winner."""
    size = 7
    b = np.zeros(size * size, dtype=np.int8)
    for c in range(5):
        b[2 * size + c] = 1          # black five on row 2
    for cell in (40, 41, 45, 46):
        b[cell] = 2
    g = game_mod.make_game("gomoku", size)
    assert not np.asarray(g.legal_mask(jnp.asarray(b))).any()
    cfg = GSCPMConfig(game="gomoku", board_size=size, n_playouts=32,
                      n_tasks=4, n_workers=4, tree_cap=256)
    tree, stats = gscpm_search(jnp.asarray(b), 2, cfg, jax.random.PRNGKey(0))
    assert int(tree.n_nodes) == 1            # nothing expanded
    # mover into the root is BLACK (to_move=2), who owns the five
    assert float(tree.wins[0]) == float(tree.visits[0]) == stats["playouts"]
