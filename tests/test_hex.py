"""Hex environment tests: flood-fill vs union-find oracle, Hex theorem property."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import hex as hx


# ---------------------------------------------------------------- oracle ----
class UnionFind:
    def __init__(self, n):
        self.p = list(range(n))

    def find(self, x):
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[ra] = rb


def oracle_connected(board: np.ndarray, player: int, size: int) -> bool:
    """Union-find connectivity — the paper's own data structure."""
    n = size * size
    uf = UnionFind(n + 2)  # two virtual edge nodes
    A, B = n, n + 1
    nbr = hx.neighbor_table(size)
    for i in range(n):
        if board[i] != player:
            continue
        r, c = divmod(i, size)
        if player == 1:  # black: top/bottom
            if r == 0:
                uf.union(i, A)
            if r == size - 1:
                uf.union(i, B)
        else:  # white: left/right
            if c == 0:
                uf.union(i, A)
            if c == size - 1:
                uf.union(i, B)
        for j in nbr[i]:
            if j < n and board[j] == player:
                uf.union(i, int(j))
    return uf.find(A) == uf.find(B)


def random_board(rng: np.random.Generator, size: int, fill: float) -> np.ndarray:
    n = size * size
    b = np.zeros(n, dtype=np.int8)
    k = int(n * fill)
    idx = rng.permutation(n)[:k]
    # alternate stones like a real game
    for t, i in enumerate(idx):
        b[i] = 1 if t % 2 == 0 else 2
    return b


# ----------------------------------------------------------------- tests ----
@pytest.mark.parametrize("size", [3, 5, 7, 11])
def test_connected_matches_union_find(size):
    spec = hx.HexSpec(size)
    rng = np.random.default_rng(0)
    f = jax.jit(lambda b, p: hx.connected(b, p, spec))
    for fill in (0.0, 0.3, 0.6, 1.0):
        for _ in range(8):
            b = random_board(rng, size, fill)
            for player in (1, 2):
                got = bool(f(jnp.asarray(b), jnp.int8(player)))
                want = oracle_connected(b, player, size)
                assert got == want, (size, fill, player, b.reshape(size, size))


def test_straight_line_wins():
    size = 5
    spec = hx.HexSpec(size)
    b = np.zeros(size * size, dtype=np.int8)
    b[2::size] = 1  # black column -> top..bottom
    assert bool(hx.connected(jnp.asarray(b), jnp.int8(1), spec))
    assert not bool(hx.connected(jnp.asarray(b), jnp.int8(2), spec))
    b2 = np.zeros(size * size, dtype=np.int8)
    b2[2 * size : 3 * size] = 2  # white row -> left..right
    assert bool(hx.connected(jnp.asarray(b2), jnp.int8(2), spec))
    assert not bool(hx.connected(jnp.asarray(b2), jnp.int8(1), spec))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), size=st.sampled_from([3, 5, 7]))
def test_hex_theorem_exactly_one_winner(seed, size):
    """A filled board has exactly one winner (the Hex no-draw theorem).

    This is the property the playout relies on: winner() may run a single
    flood-fill because the two outcomes are mutually exclusive and exhaustive.
    """
    spec = hx.HexSpec(size)
    key = jax.random.PRNGKey(seed)
    board = hx.random_fill(hx.empty_board(spec), jnp.int32(1), key, spec)
    b = np.asarray(board)
    assert (b != 0).all()
    black = oracle_connected(b, 1, size)
    white = oracle_connected(b, 2, size)
    assert black != white  # exactly one
    assert int(hx.winner(board, spec)) == (1 if black else 2)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_fill_alternates_fairly(seed):
    """Filling an empty odd-size board gives to_move ceil(n/2) stones."""
    size = 5
    spec = hx.HexSpec(size)
    key = jax.random.PRNGKey(seed)
    board = hx.random_fill(hx.empty_board(spec), jnp.int32(2), key, spec)
    b = np.asarray(board)
    n = size * size
    assert (b == 2).sum() == (n + 1) // 2  # to_move goes first
    assert (b == 1).sum() == n // 2


def test_random_fill_preserves_existing_stones():
    size = 5
    spec = hx.HexSpec(size)
    b0 = hx.empty_board(spec).at[3].set(1).at[7].set(2)
    out = hx.random_fill(b0, jnp.int32(1), jax.random.PRNGKey(3), spec)
    assert int(out[3]) == 1 and int(out[7]) == 2
    assert (np.asarray(out) != 0).all()


def test_replay_moves():
    size = 5
    spec = hx.HexSpec(size)
    moves = jnp.array([0, 6, 12, 18, 24, 0, 0], dtype=jnp.int32)
    board = hx.replay_moves(moves, jnp.int32(5), jnp.int32(1), spec)
    b = np.asarray(board)
    assert b[0] == 1 and b[6] == 2 and b[12] == 1 and b[18] == 2 and b[24] == 1
    assert (b != 0).sum() == 5


def test_playout_value_perspectives_sum_to_one():
    size = 5
    spec = hx.HexSpec(size)
    key = jax.random.PRNGKey(11)
    v1 = hx.playout_value(hx.empty_board(spec), jnp.int32(1), jnp.int32(1), key, spec)
    v2 = hx.playout_value(hx.empty_board(spec), jnp.int32(1), jnp.int32(2), key, spec)
    assert float(v1) + float(v2) == 1.0


def test_playout_vmappable():
    size = 5
    spec = hx.HexSpec(size)
    keys = jax.random.split(jax.random.PRNGKey(0), 16)
    boards = jnp.tile(hx.empty_board(spec)[None], (16, 1))
    f = jax.jit(jax.vmap(lambda b, k: hx.playout(b, jnp.int32(1), k, spec)))
    ws = np.asarray(f(boards, keys))
    assert set(np.unique(ws)).issubset({1, 2})
    # an empty board should not be deterministic across 16 random playouts
    assert len(set(ws.tolist())) == 2
