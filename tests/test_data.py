"""Data pipeline: determinism, resumability, host sharding, prefetch."""

from __future__ import annotations

import numpy as np

from repro import configs
from repro.data.pipeline import (DataConfig, Prefetcher, make_batch_fn,
                                 synth_batch)

CFG = configs.reduced_config("smollm-135m")


def test_deterministic_per_step():
    dc = DataConfig(seq_len=64, global_batch=4, seed=9)
    a = synth_batch(dc, 512, step=3)
    b = synth_batch(dc, 512, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(dc, 512, step=4)
    assert (a["tokens"] != c["tokens"]).any()


def test_host_sharding_disjoint():
    full = DataConfig(seq_len=32, global_batch=8, seed=1)
    parts = [DataConfig(seq_len=32, global_batch=8, seed=1,
                        process_index=i, process_count=2) for i in range(2)]
    f = synth_batch(full, 512, 0)
    ps = [synth_batch(p, 512, 0) for p in parts]
    assert all(p["tokens"].shape[0] == 4 for p in ps)
    assert f["tokens"].shape[0] == 8
    # different hosts generate different (independent) data
    assert (ps[0]["tokens"] != ps[1]["tokens"]).any()


def test_labels_shifted():
    dc = DataConfig(seq_len=32, global_batch=2, seed=2)
    b = synth_batch(dc, 512, 0)
    # labels are the next-token stream: they must mostly overlap shifted
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert (b["tokens"] >= 1).all() and (b["tokens"] < 512).all()


def test_prefetcher_resume():
    dc = DataConfig(seq_len=16, global_batch=2, seed=3)
    fn = make_batch_fn(dc, CFG)
    p1 = Prefetcher(fn, start_step=0)
    seen = [next(p1) for _ in range(3)]
    state = p1.state()
    p1.close()
    assert [s for s, _ in seen] == [0, 1, 2]
    assert state == 3
    p2 = Prefetcher(fn, start_step=state)
    s, batch = next(p2)
    p2.close()
    assert s == 3
    np.testing.assert_array_equal(batch["tokens"], fn(3)["tokens"])


def test_prefetcher_surfaces_errors():
    def bad(step):
        raise RuntimeError("boom")
    p = Prefetcher(bad, start_step=0)
    try:
        import pytest
        with pytest.raises(RuntimeError):
            next(p)
    finally:
        p.close()


def test_modalities():
    vlm = configs.reduced_config("paligemma-3b")
    dc = DataConfig(seq_len=32, global_batch=2, seed=4)
    b = make_batch_fn(dc, vlm)(0)
    assert b["patches"].shape == (2, vlm.n_patches, vlm.vision_width)
    enc = configs.reduced_config("seamless-m4t-medium")
    b2 = make_batch_fn(dc, enc, src_len=24)(0)
    assert b2["frames"].shape == (2, 24, enc.vision_width)
