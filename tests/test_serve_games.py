"""Serving-equivalence suite for TPFIFO game-search serving (DESIGN.md §14).

The correctness anchors of `repro.serve.games`:

- **bit-identity**: a search served in m-round quanta with forced
  tail-requeue preemption produces bit-identical root move statistics to
  the same search run uninterrupted (`gscpm_search`, same RNG schedule) —
  for hex AND gomoku, from empty and midgame positions;
- **FIFO admission** is preserved under mixed game classes and mixed
  playout budgets, and a saturated class never head-of-line-blocks
  another class's traffic;
- **one compiled quantum per game class**: per-request budget/Cp/grain/
  deadline sweeps across admissions trigger ZERO recompiles, and mixed
  hex+gomoku traffic compiles exactly one `run_chunk` program per class;
- **deadline expiry** retires a request with whatever stats it has —
  never a crash, never a poisoned slot.
"""

from __future__ import annotations

import collections
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import scheduler
from repro.core.gscpm import gscpm_search, run_chunk
from repro.core.tree import root_summary
from repro.serve.games import GameRequest, TPFIFOGameEngine
from repro.serve.tpfifo import QueueStats

SIZE = 5


def engine(**kw):
    kw.setdefault("n_slots", 1)
    kw.setdefault("grain", 1)
    kw.setdefault("n_workers", 4)
    kw.setdefault("tree_cap", 512)
    return TPFIFOGameEngine(**kw)


def req(rid, game="hex", **kw):
    kw.setdefault("board_size", SIZE)
    kw.setdefault("n_playouts", 64)
    kw.setdefault("n_tasks", 8)
    kw.setdefault("seed", rid)
    return GameRequest(rid=rid, game=game, **kw)


def reference(eng, r):
    """The uninterrupted search the served request must match bit-for-bit."""
    cfg = eng.request_cfg(r)
    board = (cfg.game_obj.init_board() if r.board is None
             else jnp.asarray(r.board, jnp.int8))
    tree, _ = gscpm_search(board, r.to_move, cfg, jax.random.key(r.seed))
    return root_summary(tree, cfg.game_obj.n_actions)


def assert_same_search(r, ref):
    np.testing.assert_array_equal(r.result["root_visits"],
                                  ref["root_visits"])
    np.testing.assert_array_equal(r.result["root_wins"], ref["root_wins"])
    assert r.result["best_move"] == ref["best_move"]
    assert r.result["root_value"] == ref["root_value"]
    assert r.result["tree_nodes"] == ref["tree_nodes"]


def midgame_board(game, k=4, seed=0):
    rng = np.random.default_rng(seed)
    b = np.zeros(SIZE * SIZE, dtype=np.int8)
    for t, i in enumerate(rng.permutation(SIZE * SIZE)[:k]):
        b[i] = 1 if t % 2 == 0 else 2
    return b


# ------------------------------------------------------------ bit-identity ----
@pytest.mark.parametrize("game", ["hex", "gomoku"])
def test_preempted_quanta_bit_identical_to_uninterrupted(game):
    """Two same-class requests on ONE slot with preempt_quanta=1 force
    tail-requeue preemption every quantum; each interleaved, repeatedly
    preempted search must equal its uninterrupted twin bit-for-bit —
    including a midgame-position request with to_move=2."""
    eng = engine(preempt_quanta=1)
    reqs = [req(0, game),
            req(1, game, n_playouts=32, n_tasks=4,
                board=midgame_board(game), to_move=2)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 2
    assert eng.stats().n_preemptions > 0       # the forcing actually forced
    for r in reqs:
        assert not r.result["deadline_expired"]
        assert r.result["rounds"] == r.result["rounds_total"]
        assert_same_search(r, reference(eng, r))


def test_mixed_class_traffic_does_not_perturb_searches():
    """Hex and gomoku interleaved through one engine with preemption: every
    request still matches its uninterrupted single-tenant search."""
    eng = engine(n_slots=1, grain=2, preempt_quanta=1)
    reqs = [req(0, "hex"), req(1, "gomoku", n_playouts=48, n_tasks=12),
            req(2, "hex", n_playouts=32, n_tasks=4, cp=1.7),
            req(3, "gomoku", n_playouts=64, n_tasks=16, cp=0.4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert_same_search(r, reference(eng, r))


# ------------------------------------------------------------- admission ----
def test_fifo_admission_order_mixed_classes_and_budgets():
    """With free slots for everyone, global admission order == submission
    order regardless of game class or playout budget."""
    eng = engine(n_slots=3, grain=2)
    mix = [("hex", 32), ("gomoku", 64), ("hex", 16), ("gomoku", 32),
           ("hex", 48)]
    reqs = [req(i, g, n_playouts=n, n_tasks=4) for i, (g, n) in enumerate(mix)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert eng.admission_order == [0, 1, 2, 3, 4]
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]


def test_saturated_class_never_blocks_other_class():
    """hex0, hex1, gomoku0 on 1-slot pools: hex1 must wait for hex0's slot,
    but gomoku0 passes it (per-class pools kill cross-game HOL blocking);
    per-class admission order still follows submission order."""
    eng = engine(n_slots=1, grain=2)
    reqs = [req(0, "hex"), req(1, "hex", n_playouts=32, n_tasks=4),
            req(2, "gomoku", n_playouts=32, n_tasks=4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert eng.admission_order[:2] == [0, 2]   # gomoku passed the full pool
    assert eng.admission_order == [0, 2, 1]
    games = {r.rid: r.game for r in reqs}
    hex_order = [rid for rid in eng.admission_order if games[rid] == "hex"]
    assert hex_order == [0, 1]


# ------------------------------------------------------------ compilation ----
def test_zero_recompiles_across_budget_cp_grain_deadline_sweeps():
    """Once the game classes are warm, per-request n_playouts/n_tasks/Cp/
    deadline sweeps and engine grain/policy/preemption changes never grow
    run_chunk's jit cache."""
    warm = engine()
    for i, g in enumerate(["hex", "gomoku"]):
        warm.submit(req(i, g, n_playouts=16, n_tasks=4))
    warm.run()
    before = run_chunk._cache_size()

    eng = engine(n_slots=2, grain=3, policy="rebalance", preempt_quanta=2)
    sweeps = [("hex", 16, 2, 0.4, None), ("gomoku", 48, 6, 1.7, 30.0),
              ("hex", 96, 12, 2.5, 30.0), ("gomoku", 24, 24, 0.9, None),
              ("hex", 40, 5, 1.0, 30.0)]
    reqs = [req(i, g, n_playouts=n, n_tasks=t, cp=cp, deadline_s=dl)
            for i, (g, n, t, cp, dl) in enumerate(sweeps)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == len(sweeps)
    assert run_chunk._cache_size() == before


def test_one_compiled_quantum_per_game_class():
    """Mixed hex+gomoku traffic at a fresh board size compiles EXACTLY one
    quantum program per game class, admissions/preemptions included."""
    size = 6                       # unused by any other test in this module
    before = run_chunk._cache_size()
    eng = engine(n_slots=1, grain=1, preempt_quanta=1)
    for i, (g, n) in enumerate([("hex", 32), ("gomoku", 16), ("hex", 16),
                                ("gomoku", 32)]):
        eng.submit(req(i, g, board_size=size, n_playouts=n, n_tasks=4,
                       cp=0.5 + 0.3 * i))
    done = eng.run()
    assert len(done) == 4
    assert run_chunk._cache_size() == before + 2


# -------------------------------------------------------------- deadlines ----
def test_deadline_expiry_retires_without_poisoning_slot():
    """An already-expired deadline retires the request with empty stats
    (best_move -1, zero visits) and the slot serves the next request to its
    FULL budget — no crash, no poisoned slot."""
    eng = engine()
    dead = req(0, deadline_s=0.0)
    follow = req(1, n_playouts=32, n_tasks=4)
    eng.submit(dead)
    eng.submit(follow)
    done = eng.run()
    assert len(done) == 2
    assert dead.done and dead.result["deadline_expired"]
    assert dead.result["rounds"] == 0 and dead.result["playouts"] == 0
    assert dead.result["best_move"] == -1
    assert (dead.result["root_visits"] == 0).all()
    assert not follow.result["deadline_expired"]
    assert follow.result["rounds"] == follow.result["rounds_total"]
    assert follow.result["playouts"] == 32
    assert_same_search(follow, reference(eng, follow))
    assert eng.stats().n_finished == 2


def test_mid_search_deadline_ships_partial_stats():
    """A deadline expiring mid-search retires the request with whatever the
    tree holds: a consistent partial root summary (visits account exactly
    for the rounds that ran)."""
    eng = engine(grain=1)
    r = req(0, n_playouts=8192, n_tasks=2048, deadline_s=0.2)  # 512 rounds
    eng.submit(r)
    eng.run()
    assert r.done and r.result["deadline_expired"]
    assert 0 < r.result["rounds"] < r.result["rounds_total"]
    assert r.result["root_visits"].sum() == r.result["playouts"] > 0
    assert r.result["best_move"] >= 0


# ------------------------------------------------- budgets and telemetry ----
def test_playout_budget_conserved_and_queue_stats():
    """Every finished request's dense root visits sum to exactly its
    scheduled playout budget, preemptions notwithstanding; QueueStats
    aggregates per-request telemetry (tokens == committed rounds)."""
    eng = engine(n_slots=2, grain=2, preempt_quanta=1)
    mix = [("hex", 64, 8), ("gomoku", 32, 8), ("hex", 32, 4),
           ("gomoku", 64, 16)]
    reqs = [req(i, g, n_playouts=n, n_tasks=t)
            for i, (g, n, t) in enumerate(mix)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    rounds_total = 0
    for r in reqs:
        cfg = eng.request_cfg(r)
        sch = scheduler.make_schedule(cfg.n_playouts, cfg.n_tasks,
                                      cfg.n_workers, cfg.scheduler)
        assert r.result["playouts"] == \
            scheduler.schedule_stats(sch)["lane_iterations"]
        assert r.result["root_visits"].sum() == r.result["playouts"]
        assert r.result["queue_wait_s"] >= 0
        assert r.result["latency_s"] >= r.result["queue_wait_s"]
        rounds_total += r.result["rounds"]
    st = eng.stats()
    assert isinstance(st, QueueStats)
    assert st.n_finished == 4
    assert st.tokens == rounds_total
    assert st.quanta >= 4
    assert 0 <= st.latency_p50 <= st.latency_p95


def test_submit_rejects_bad_requests():
    eng = engine()
    with pytest.raises(ValueError):
        eng.submit(req(0, game="chess"))             # unregistered game
    with pytest.raises(ValueError):
        eng.submit(req(1, board=np.zeros(7, np.int8)))  # wrong cell count
    with pytest.raises(ValueError):
        eng.submit(req(2, n_playouts=0))


# ----------------------------------------------------- scheduling property ----
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       slots=st.sampled_from([1, 2]),
       grain=st.sampled_from([1, 2, 4]),
       preempt=st.sampled_from([1, 2]))
def test_property_mixed_traffic_never_starves(seed, slots, grain, preempt):
    """Host-side scheduling property (search dispatch stubbed out): any mix
    of game classes, budgets, and grains drains completely — every request
    finishes with its exact round budget, each admission segment commits
    >=1 round (the PR 2 livelock guard), and per-class admission order
    follows submission order."""
    rng = np.random.default_rng(seed)
    with mock.patch("repro.serve.games.run_schedule_round",
                    lambda tree, board, cfg, key, rnd, cp: tree):
        # guard off: the stubbed dispatch never commits visits, so the
        # PR 9 result guard would (correctly) reject every retirement
        eng = engine(n_slots=slots, grain=grain, preempt_quanta=preempt,
                     tree_cap=64, guard=False)
        games = ("hex", "gomoku")
        reqs = [req(i, games[int(rng.integers(2))],
                    n_playouts=int(rng.integers(8, 129)),
                    n_tasks=int(2 ** rng.integers(0, 5)))
                for i in range(int(rng.integers(3, 8)))]
        for r in reqs:
            eng.submit(r)
        done = eng.run()
    assert len(done) == len(reqs)
    for r in reqs:
        cfg = eng.request_cfg(r)
        sch = scheduler.make_schedule(cfg.n_playouts, cfg.n_tasks,
                                      cfg.n_workers, cfg.scheduler)
        assert r.result["rounds"] == len(sch)
        assert r.result["playouts"] == \
            scheduler.schedule_stats(sch)["lane_iterations"]
    for t in eng.finished_tickets:
        # progress guard: preemption only after >=1 committed round, so
        # segments (preemptions + 1) never exceed committed rounds
        assert t.preemptions + 1 <= len(t.req.out)
    by_game = {r.rid: r.game for r in reqs}
    first_admissions = list(dict.fromkeys(eng.admission_order))
    for g in ("hex", "gomoku"):
        submitted = [r.rid for r in reqs if r.game == g]
        admitted = [rid for rid in first_admissions if by_game[rid] == g]
        assert admitted == submitted
