"""TPFIFO serving: queue discipline, chunked prefill, preemption, compiles.

The correctness anchors:
- grain invariance: greedy output is bit-identical for any quantum size
  (the grain dial moves scheduling boundaries, never the computation);
- lockstep equivalence: the unified prefill/decode micro-step path produces
  the same greedy tokens as SlotEngine's whole-prompt-prefill + decode path;
- lossless preemption: requeue + chunked re-prefill of prompt ⊕ out resumes
  a greedy request bit-identically;
- one compiled quantum: occupancy, admissions, retirements, grain changes
  and prompt-length mixes never grow ``run_quantum``'s jit cache.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import scheduler
from repro.models import api
from repro.serve.engine import Request, SlotEngine
from repro.serve.tpfifo import (QueueStats, TPFIFOEngine, TPFIFOMCTSEngine,
                                run_quantum)

B, MAX_LEN = 2, 32


@pytest.fixture(scope="module")
def small_lm():
    cfg = configs.reduced_config("smollm-135m").replace(n_layers=2)
    params = api.init_params(cfg, jax.random.key(0))
    return cfg, params


def mixed_requests(cfg, lens=(6, 4, 9, 5, 7), max_new=5, seed=1):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        size=(int(n),)).astype(np.int32),
                    max_new=max_new)
            for i, n in enumerate(lens)]


def engine(cfg, params, **kw):
    kw.setdefault("grain", 4)
    return TPFIFOEngine(params, cfg, n_slots=B, max_len=MAX_LEN,
                        eos_id=-1, **kw)


def outs(done):
    return {r.rid: list(r.out) for r in done}


# --------------------------------------------------------------- fairness ----
def test_fifo_order_preserved_mixed_lengths(small_lm):
    """Admission order == submission order regardless of prompt lengths,
    and every request completes with its full budget."""
    cfg, params = small_lm
    eng = engine(cfg, params)
    reqs = mixed_requests(cfg)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == len(reqs)
    assert eng.admission_order == [r.rid for r in reqs]
    assert all(len(r.out) == 5 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)


def test_grain_invariance_greedy(small_lm):
    """Same requests at grain 1/4/16 -> identical greedy outputs: the grain
    only moves dispatch boundaries."""
    cfg, params = small_lm
    ref = None
    for grain in (1, 4, 16):
        eng = engine(cfg, params, grain=grain)
        for r in mixed_requests(cfg):
            eng.submit(r)
        o = outs(eng.run())
        if ref is None:
            ref = o
        assert o == ref, f"grain {grain} diverged"


def test_matches_lockstep_greedy(small_lm):
    """The unified micro-step path == SlotEngine's prefill+decode path,
    including the max_new=1 budget edge (one token, emitted at admission
    on the lockstep side)."""
    cfg, params = small_lm
    eng = engine(cfg, params)
    lock = SlotEngine(params, cfg, n_slots=B, max_len=MAX_LEN, eos_id=-1)
    for e in (eng, lock):
        for r in mixed_requests(cfg):
            e.submit(r)
        one = mixed_requests(cfg, lens=(5,), max_new=1, seed=4)[0]
        one.rid = 10
        e.submit(one)
    o_eng, o_lock = outs(eng.run()), outs(lock.run())
    assert o_eng == o_lock
    assert len(o_eng[10]) == 1          # budget honored exactly, both paths


def test_run_reusable_after_long_service(small_lm):
    """run() bounds ticks per CALL, not per engine lifetime: an engine that
    has already served many ticks must still drain new submissions."""
    cfg, params = small_lm
    eng = engine(cfg, params)
    eng.submit(mixed_requests(cfg, lens=(4,), max_new=2)[0])
    assert len(eng.run()) == 1
    eng._ticks = 10_000            # simulate a long-lived server
    r2 = mixed_requests(cfg, lens=(6,), max_new=2, seed=3)[0]
    r2.rid = 99
    eng.submit(r2)
    done = eng.run()
    assert done[-1].rid == 99 and len(done[-1].out) == 2


# ------------------------------------------------------------- preemption ----
def test_preempt_resume_lossless(small_lm):
    """A preempted request resumes without losing generated tokens: the
    requeued request re-prefills prompt ⊕ out and greedy decoding lands on
    the exact same continuation."""
    cfg, params = small_lm
    base = engine(cfg, params)
    for r in mixed_requests(cfg):
        base.submit(r)
    ref = outs(base.run())

    eng = engine(cfg, params, grain=2, preempt_quanta=1)
    for r in mixed_requests(cfg):
        eng.submit(r)
    done = eng.run()
    st = eng.stats()
    assert st.n_preemptions > 0            # the knob actually fired
    assert len(done) == 5
    assert outs(done) == ref               # ...and cost zero tokens


def test_one_per_core_runs_to_completion(small_lm):
    """The paper's one-task-per-lane baseline never preempts, even with the
    preemption knob set."""
    cfg, params = small_lm
    eng = engine(cfg, params, policy="one_per_core", preempt_quanta=1)
    for r in mixed_requests(cfg):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5
    assert eng.stats().n_preemptions == 0


def test_rebalance_widens_quanta_when_lanes_idle(small_lm):
    """rebalance re-splits idle lanes' budget over active ones: with 1
    active request on B slots the dispatch quantum grows by ~B/1."""
    cfg, params = small_lm
    eng = engine(cfg, params, grain=4, policy="rebalance")
    eng.submit(mixed_requests(cfg)[0])
    eng._admit_free_slots()
    assert eng._tick_m() == 4 * B


# --------------------------------------------------- chunked prefill / HOL ----
def test_chunked_prefill_never_blocks_short_requests(small_lm):
    """A long prompt prefills in grain-sized chunks while a short request
    decodes: the short request must finish first (no head-of-line blocking,
    unlike a monolithic prefill of the long prompt)."""
    cfg, params = small_lm
    rng = np.random.default_rng(0)
    long_req = Request(rid=0, prompt=rng.integers(
        1, cfg.vocab, size=(24,)).astype(np.int32), max_new=3)
    short_req = Request(rid=1, prompt=rng.integers(
        1, cfg.vocab, size=(4,)).astype(np.int32), max_new=3)
    eng = engine(cfg, params, grain=2)
    eng.submit(long_req)
    eng.submit(short_req)
    done = eng.run()
    assert [r.rid for r in done] == [1, 0]
    assert len(long_req.out) == 3 and len(short_req.out) == 3


# ------------------------------------------------------------ compilation ----
def test_no_recompile_across_occupancy_and_grain(small_lm):
    """One compiled quantum serves every queue occupancy, admission
    pattern, prompt-length mix, and grain size at fixed (n_slots,
    max_len)."""
    cfg, params = small_lm
    eng = engine(cfg, params)
    for r in mixed_requests(cfg):
        eng.submit(r)
    eng.run()
    before = run_quantum._cache_size()
    # different occupancy (1 request), different lengths, different grain,
    # preemption on — same shapes
    eng2 = engine(cfg, params, grain=7, preempt_quanta=2)
    eng2.submit(mixed_requests(cfg, lens=(11,), max_new=3)[0])
    eng2.run()
    eng3 = engine(cfg, params, grain=2)
    for r in mixed_requests(cfg, lens=(3, 12, 8), max_new=2, seed=9):
        eng3.submit(r)
    eng3.run()
    assert run_quantum._cache_size() == before


# --------------------------------------------------------------- telemetry ----
def test_queue_stats_telemetry(small_lm):
    cfg, params = small_lm
    eng = engine(cfg, params)
    for r in mixed_requests(cfg):
        eng.submit(r)
    eng.run()
    st = eng.stats()
    assert isinstance(st, QueueStats)
    assert st.n_finished == 5
    assert st.tokens == 25
    assert st.quanta >= 5                  # every request ran >=1 quantum
    assert st.throughput_tok_s > 0
    assert 0 <= st.queue_wait_p50 <= st.queue_wait_p95
    assert 0 <= st.latency_p50 <= st.latency_p95
    assert st.service_p50 > 0
    # B slots: later submissions wait for a slot, so someone queued
    assert st.queue_wait_p95 > 0


def test_submit_rejects_oversized_request(small_lm):
    cfg, params = small_lm
    eng = engine(cfg, params)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0,
                           prompt=np.arange(1, MAX_LEN - 2, dtype=np.int32),
                           max_new=8))


# ------------------------------------------------------------ quantum plans ----
def test_quantum_plan_covers_work_exactly():
    for policy in ("fifo", "rebalance"):
        for steps, grain in ((33, 8), (5, 8), (16, 4), (1, 4)):
            plan = scheduler.quantum_plan(steps, grain, policy)
            assert sum(plan) == steps, (policy, steps, grain)
            assert all(m >= 1 for m in plan)
    # one_per_core: a single monolithic quantum
    assert scheduler.quantum_plan(33, 8, "one_per_core") == [33]


# ------------------------------------------------------------- MCTS engine ----
def test_tpfifo_mcts_engine_serves_queue(small_lm):
    """Search-guided TPFIFO: quanta of m search+commit rounds, preemption
    at quantum boundaries, FIFO order preserved."""
    from repro.serve.mcts_decode import MCTSDecodeConfig

    cfg, params = small_lm
    dcfg = MCTSDecodeConfig(n_playouts=8, n_tasks=2, n_workers=2, branch=3,
                            max_depth=2, rollout_len=2, tree_cap=64)
    eng = TPFIFOMCTSEngine(params, cfg, dcfg, n_slots=2, max_prompt_len=16,
                           grain=2, eos_id=-1, preempt_quanta=1)
    reqs = mixed_requests(cfg, lens=(4, 6, 5), max_new=3)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    # preempted requests re-enter the admission log; FIRST admissions must
    # still be in FIFO submission order
    assert list(dict.fromkeys(eng.admission_order)) == [0, 1, 2]
    assert all(len(r.out) == 3 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)
    st = eng.stats()
    assert st.n_finished == 3 and st.tokens == 9
