"""Sharding rules: logical axis mapping, divisibility, shape-specific rules."""

from __future__ import annotations

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import SHAPES
from repro.launch.inputs import rules_for_shape
from repro.sharding import rules as shr


def _mesh2(d=2, m=2):
    n = d * m
    if len(jax.devices()) < n:
        pytest.skip("needs >= 4 devices")
    return jax.make_mesh((d, m), ("data", "model"))


def test_logical_to_spec_basic():
    spec = shr.logical_to_spec(("batch", None, "heads", None))
    assert spec == P(("pod", "data"), None, "model", None)


def test_no_axis_reuse_within_spec():
    # embed->data and batch->(pod,data): data must not be used twice
    spec = shr.logical_to_spec(("batch", "embed"))
    flat = []
    for e in spec:
        if isinstance(e, tuple):
            flat += list(e)
        elif e is not None:
            flat.append(e)
    assert len(flat) == len(set(flat))


def test_divisible_spec_drops_nondividing():
    mesh = jax.make_mesh((1,), ("model",))
    spec = shr._divisible_spec(P("model"), (7,), mesh)   # 7 % 1 == 0 ok
    assert spec == P("model")
    mesh2 = None
    try:
        mesh2 = _mesh2()
    except Exception:
        pytest.skip("no 4 devices")
    s = shr._divisible_spec(P("model", "data"), (3, 4), mesh2)
    assert s == P(None, "data")                           # 3 % 2 != 0 dropped


def test_rules_for_shape_decode():
    r = rules_for_shape(SHAPES["decode_32k"])
    assert r["kv_len"] == "model"
    r1 = rules_for_shape(SHAPES["long_500k"])
    assert r1["batch"] is None
    assert r1["kv_len"] == ("data", "model")
    rt = rules_for_shape(SHAPES["train_4k"])
    assert rt["kv_len"] == shr.DEFAULT_RULES["kv_len"]


def test_shard_noop_outside_mesh():
    import jax.numpy as jnp
    x = jnp.zeros((4, 4))
    assert shr.shard(x, ("batch", None)) is x
