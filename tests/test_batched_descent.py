"""Level-synchronous batched descent vs the per-lane scalar oracle.

The tentpole contract (DESIGN.md §11): ``select_batch`` /
``select_token_batch`` — all W lanes stepping down the tree in lockstep,
one ``kernels.ops.uct_select`` (W, C) tile per level — must be bit-identical
to ``jax.vmap(select_one)`` / ``jax.vmap(select_token_path)`` under the same
RNG schedule, both per-descent and across whole searches; and sweeping the
traced knobs (Cp, grain, scheduler) must never grow the jit caches.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hex as hx
from repro.core.gscpm import (GSCPMConfig, expand_batch, gscpm_search,
                              run_chunk, select_batch, select_one)
from repro.core.root_parallel import gscpm_search_batch
from repro.core.tree import child_stat_tile, init_tree
from repro.kernels import ops
from repro.kernels import uct_select as _us
from repro.serve.mcts_decode import (MCTSDecodeConfig, backup_values,
                                     select_token_batch, select_token_path)


def built_tree(size: int, key, n_playouts: int = 192):
    """A mid-search Hex tree with real stats to descend."""
    board = hx.empty_board(hx.HexSpec(size))
    cfg = GSCPMConfig(board_size=size, n_playouts=n_playouts, n_tasks=8,
                      n_workers=4, tree_cap=4096)
    tree, _ = gscpm_search(board, 1, cfg, key)
    return tree, board, hx.HexGame(size)


# ------------------------------------------------------- descent oracle ----
@pytest.mark.parametrize("size", [5, 7])
@pytest.mark.parametrize("W", [1, 4, 16])
@pytest.mark.parametrize("noise_scale", [0.0, 1e-3])
def test_select_batch_matches_vmapped_select_one(size, W, noise_scale):
    tree, board, spec = built_tree(size, jax.random.PRNGKey(size))
    keys = jax.random.split(jax.random.PRNGKey(100 + W), W)
    cp = jnp.float32(1.0)
    want = jax.vmap(
        lambda k: select_one(tree, board, spec, cp, k, noise_scale))(keys)
    got = select_batch(tree, board, spec, cp, keys, noise_scale)
    for name, w, g in zip(("path", "depth", "leaf", "board", "n_empty"),
                          want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                      err_msg=f"{name} diverged")


@pytest.mark.parametrize("vl_rounds", [1, 3])
@pytest.mark.parametrize("W,noise", [(4, 1e-3), (8, 0.0), (8, 1e-3)])
def test_full_search_batched_equals_scalar(vl_rounds, W, noise):
    """Whole searches — selection, expansion, playout, backup — produce
    bit-identical trees whichever descent runs (same RNG schedule)."""
    board = hx.empty_board(hx.HexSpec(5))
    base = GSCPMConfig(board_size=5, n_playouts=128, n_tasks=8, n_workers=W,
                       vl_rounds=vl_rounds, select_noise=noise,
                       tree_cap=2048, descent="batched")
    key = jax.random.PRNGKey(17)
    t_b, s_b = gscpm_search(board, 1, base, key)
    t_s, s_s = gscpm_search(board, 1,
                            dataclasses.replace(base, descent="scalar"), key)
    assert int(t_b.n_nodes) == int(t_s.n_nodes)
    nn = int(t_b.n_nodes)
    for f in ("parent", "move", "to_move", "n_children"):
        np.testing.assert_array_equal(
            np.asarray(getattr(t_b, f)[:nn]), np.asarray(getattr(t_s, f)[:nn]),
            err_msg=f)
    np.testing.assert_allclose(np.asarray(t_b.visits[:nn]),
                               np.asarray(t_s.visits[:nn]))
    np.testing.assert_allclose(np.asarray(t_b.wins[:nn]),
                               np.asarray(t_s.wins[:nn]))
    assert s_b["best_move"] == s_s["best_move"]


def test_forest_vmap_composes_with_batched_descent():
    """Root-parallel vmap over E members runs the batched descent unchanged:
    each member's forest tree equals its own single-tree search."""
    board = hx.empty_board(hx.HexSpec(5))
    cfg = GSCPMConfig(board_size=5, n_playouts=64, n_tasks=4, n_workers=4,
                      tree_cap=1024)
    key = jax.random.PRNGKey(3)
    forest, _ = gscpm_search_batch(board, 1, cfg, key, n_trees=2)
    for e in range(2):
        single, _ = gscpm_search(
            board, 1, cfg, jax.random.fold_in(key, e))
        np.testing.assert_allclose(np.asarray(forest.visits[e]),
                                   np.asarray(single.visits))


# ----------------------------------------------------- token-tree oracle ----
def token_tree(cfg: MCTSDecodeConfig, seed: int):
    """Synthetic token tree: dedup-expanded tokens + scored backups.

    Proposals target distinct non-full leaves only (as ``propose_token``
    guarantees in the real path), so ``branch`` is never exceeded.
    """
    tree = init_tree(cfg.tree_cap, cfg.branch, 1)
    rng = np.random.default_rng(seed)
    for i in range(6):
        nn = int(tree.n_nodes)
        nc = np.asarray(tree.n_children[:nn])
        open_leaves = np.flatnonzero(nc < cfg.branch)
        leaves = rng.choice(open_leaves, size=min(4, len(open_leaves)),
                            replace=False).astype(np.int32)
        W = len(leaves)
        toks = rng.integers(1, 50, size=(W,)).astype(np.int32)
        tree, new_ids = expand_batch(tree, jnp.asarray(leaves),
                                     jnp.asarray(toks), jnp.ones((W,), bool))
        paths = jnp.where(new_ids[:, None] < tree.cap,
                          jnp.stack([jnp.zeros((W,), jnp.int32), new_ids], 1),
                          tree.cap)
        vals = jnp.asarray(rng.uniform(0.1, 1.0, size=(W,)), jnp.float32)
        tree = backup_values(tree, paths, vals, jnp.ones((W,)))
    return tree


@pytest.mark.parametrize("W", [1, 4, 8])
def test_select_token_batch_matches_oracle(W):
    cfg = MCTSDecodeConfig(branch=4, max_depth=3, tree_cap=128)
    tree = token_tree(cfg, seed=0)
    keys = jax.random.split(jax.random.PRNGKey(W), W)
    cp = jnp.float32(1.0)
    want = jax.vmap(lambda k: select_token_path(tree, cfg, k, cp))(keys)
    got = select_token_batch(tree, cfg, cp, keys)
    for name, w, g in zip(("path", "depth", "leaf"), want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                      err_msg=f"{name} diverged")


# ------------------------------------------------------------- gather op ----
def test_child_stat_tile_matches_scalar_gather():
    tree, _, _ = built_tree(5, jax.random.PRNGKey(1))
    nodes = jnp.asarray([0, 1, int(tree.n_nodes) - 1, 0], jnp.int32)
    safe, valid, wins, visits, vloss, ptot = child_stat_tile(tree, nodes)
    C = tree.max_children
    for i, n in enumerate(np.asarray(nodes)):
        nk = int(tree.n_children[n])
        v = np.arange(C) < nk
        np.testing.assert_array_equal(np.asarray(valid[i]), v)
        s = np.where(v, np.asarray(tree.children[n]), tree.cap)
        np.testing.assert_array_equal(np.asarray(safe[i]), s)
        np.testing.assert_allclose(np.asarray(wins[i]),
                                   np.asarray(tree.wins)[s])
        np.testing.assert_allclose(
            np.asarray(ptot[i]),
            float(tree.visits[n]) + float(tree.vloss[n]))


# --------------------------------------------------------- compile counts ----
def test_cp_grain_scheduler_sweeps_do_not_retrace():
    """The fig7/ablation sweep contract: Cp, grain and scheduler are traced
    or host-only knobs, so the whole grid shares ONE compiled chunk."""
    board = hx.empty_board(hx.HexSpec(5))
    key = jax.random.PRNGKey(0)
    gscpm_search(board, 1, GSCPMConfig(board_size=5, n_playouts=32,
                                       n_tasks=4, n_workers=4,
                                       tree_cap=512), key)
    before = run_chunk._cache_size()
    for cp in (0.3, 1.0, 2.4):
        for n_tasks in (2, 4, 16):
            for sched in ("fifo", "rebalance"):
                cfg = GSCPMConfig(board_size=5, n_playouts=32,
                                  n_tasks=n_tasks, n_workers=4,
                                  tree_cap=512, cp=cp, scheduler=sched)
                gscpm_search(board, 1, cfg, key)
    assert run_chunk._cache_size() == before


def test_kernel_jit_cp_is_traced():
    """The Pallas kernel itself never recompiles across Cp values."""
    W, C = 8, 16
    z = jnp.zeros((W, C))
    valid = jnp.ones((W, C), bool)
    ptot = jnp.ones((W,))
    _us.uct_select(z, z, z, ptot, valid, jnp.float32(1.0), interpret=True)
    before = _us.uct_select._cache_size()
    for cp in (0.25, 0.7, 3.0):
        _us.uct_select(z, z, z, ptot, valid, jnp.float32(cp), interpret=True)
    assert _us.uct_select._cache_size() == before


# ------------------------------------------------------- done-lane masking ----
def test_lane_mask_holds_done_lanes():
    """A masked lane's row is fully invalid -> deterministic slot 0; live
    lanes' picks are unaffected by other lanes' masks."""
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 4)
    W, C = 6, 8
    visits = jnp.round(jax.random.uniform(ks[0], (W, C)) * 9)
    wins = jnp.round(jax.random.uniform(ks[1], (W, C)) * visits)
    valid = jax.random.uniform(ks[2], (W, C)) > 0.3
    ptot = jnp.maximum(visits.sum(-1), 1.0)
    mask = jnp.asarray([True, False, True, False, True, True])
    cp = jnp.float32(1.0)
    free = ops.uct_select(wins, visits, jnp.zeros((W, C)), ptot, valid, cp)
    held = ops.uct_select(wins, visits, jnp.zeros((W, C)), ptot, valid, cp,
                          lane_mask=mask)
    np.testing.assert_array_equal(np.asarray(held)[np.asarray(mask)],
                                  np.asarray(free)[np.asarray(mask)])
    assert (np.asarray(held)[~np.asarray(mask)] == 0).all()
    # pallas kernel agrees on the masked tile
    interp = ops.uct_select(wins, visits, jnp.zeros((W, C)), ptot, valid, cp,
                            lane_mask=mask, interpret=True)
    np.testing.assert_array_equal(np.asarray(held), np.asarray(interp))
