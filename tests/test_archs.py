"""Per-architecture smoke tests: reduced twins, one train + serve pass.

Every assigned arch instantiates its family-faithful reduced config and
runs (a) a forward loss + gradient step asserting finiteness and shapes,
(b) prefill + a few decode steps asserting logits shape and finiteness,
(c) decode-vs-forward consistency for the families where teacher-forced
decode must reproduce the parallel forward.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, make_batch_fn
from repro.models import api

ARCHS = list(configs.ARCHS)


def _setup(arch: str, seq_len: int = 32, batch: int = 2):
    cfg = configs.reduced_config(arch)
    params = api.init_params(cfg, jax.random.key(0))
    dc = DataConfig(seq_len=seq_len, global_batch=batch, seed=3)
    fn = make_batch_fn(dc, cfg, src_len=24)
    b = {k: jnp.asarray(v) for k, v in fn(0).items()}
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(
            np.random.default_rng(0).standard_normal(
                (batch, cfg.n_patches, cfg.vision_width), np.float32))
    return cfg, params, b


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg, params, batch = _setup(arch)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss(p, cfg, batch))(params)
    assert jnp.isfinite(loss), (arch, float(loss))
    assert float(loss) > 0.1  # CE of an untrained model
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert jnp.isfinite(g).all(), (arch, path)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch):
    cfg, params, batch = _setup(arch)
    B = batch["tokens"].shape[0]
    max_len = batch["tokens"].shape[1] + 8
    logits, cache = api.prefill(params, cfg, batch, max_len)
    assert logits.shape == (B, 1, cfg.vocab)
    assert jnp.isfinite(logits).all(), arch
    pos = jnp.full((B,), batch["tokens"].shape[1], jnp.int32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(3):
        logits, cache = api.decode(params, cfg, tok, pos + i, cache)
        assert logits.shape == (B, 1, cfg.vocab)
        assert jnp.isfinite(logits).all(), (arch, i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen1.5-0.5b", "xlstm-1.3b",
                                  "zamba2-7b", "deepseek-v2-236b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == parallel forward logits."""
    cfg, params, batch = _setup(arch, seq_len=16)
    toks = batch["tokens"]
    B, S = toks.shape
    # parallel forward logits at every position
    from repro.models.transformer import lm_hidden
    from repro.models.layers import unembed
    hidden, _ = lm_hidden(params, cfg, toks)
    full_logits = unembed(params["embed"], hidden, cfg)
    # prefill on the first half, decode the second half teacher-forced
    half = S // 2
    logits, cache = api.prefill(params, cfg, {"tokens": toks[:, :half]}, S + 1)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, half - 1]),
        atol=2e-3, rtol=2e-2)
    for t in range(half, S):
        step_logits, cache = api.decode(
            params, cfg, toks[:, t:t + 1], jnp.int32(t), cache)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, t]),
            atol=3e-3, rtol=3e-2, err_msg=f"{arch} pos {t}")


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_spec_sanity(arch):
    """The FULL config builds abstract params with the published size."""
    cfg = configs.get_config(arch)
    n = api.n_params(cfg)
    expected = {
        "zamba2-7b": 7e9, "llama3-8b": 8e9, "smollm-135m": 0.135e9,
        "qwen1.5-0.5b": 0.46e9, "qwen1.5-4b": 4e9,
        "deepseek-v2-236b": 236e9, "qwen3-moe-235b-a22b": 235e9,
        "xlstm-1.3b": 1.3e9, "paligemma-3b": 2.5e9,
        "seamless-m4t-medium": 1.0e9,
    }[arch]
    assert 0.7 * expected < n < 1.35 * expected, (arch, n, expected)
    # abstract params build without allocation
    ap = api.abstract_params(cfg)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in jax.tree.leaves(ap))
