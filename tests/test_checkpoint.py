"""Checkpoint store: roundtrip, atomicity, async, GC, elastic reshard."""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(key=0):
    k = jax.random.key(key)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros((16,), jnp.bfloat16)},
        "opt": {"m": jnp.ones((8, 16)), "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 5, t, extra={"data_step": 6})
    assert store.latest_step(str(tmp_path)) == 5
    got, extra = store.restore(str(tmp_path), 5, t)
    assert extra == {"data_step": 6}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_ignores_partial(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 5, t)
    # a crashed save: tmp dir + corrupt LATEST must not break restore
    os.makedirs(tmp_path / "step_000000009.tmp")
    (tmp_path / "LATEST").write_text("step_000000099")
    assert store.latest_step(str(tmp_path)) == 5


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 1, t)
    bad = jax.tree.map(lambda x: jnp.zeros((3,) + x.shape, x.dtype), t)
    with pytest.raises(ValueError):
        store.restore(str(tmp_path), 1, bad)


def test_async_saver_and_gc(tmp_path):
    s = store.AsyncSaver(str(tmp_path), keep=2)
    t = _tree()
    for step in (1, 2, 3, 4):
        s.save(step, t, extra={"data_step": step})
        s.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]
    assert store.latest_step(str(tmp_path)) == 4


def test_elastic_reshard_subprocess(tmp_path):
    """Save under an 8-device mesh, restore under a 4-device mesh."""
    script = r"""
import os, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import store
base = sys.argv[2]
mesh = jax.make_mesh((len(jax.devices()),), ("data",))
sh = NamedSharding(mesh, P("data"))
t = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
if sys.argv[3] == "save":
    t = jax.device_put(t, {"w": sh})
    store.save(base, 1, t)
else:
    got, _ = store.restore(base, 1, {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)},
                           shardings={"w": sh})
    assert got["w"].sharding.num_devices == len(jax.devices())
    np.testing.assert_array_equal(np.asarray(got["w"]).ravel(), np.arange(32))
print("OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    for ndev, mode in (("8", "save"), ("4", "load")):
        out = subprocess.run(
            [sys.executable, "-c", script, ndev, str(tmp_path), mode],
            capture_output=True, text=True, env=env, timeout=240)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout
