"""Async round pipelining (DESIGN.md §18): bit-identity with the blocking
engine, zero recompiles, clean auto-disable, and device-wait accounting.

Uses the UNIQUE class combo (board 5, cap 640) so the zero-recompile
pins isolate pipelining from compilation triggered by other test files
sharing this process's jit cache."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.gscpm import run_chunk
from repro.obsv.trace import TraceRecorder
from repro.serve.games import GameRequest, TPFIFOGameEngine
from repro.serve.resilience import FaultInjector, FaultPlan

SIZE = 5
CAP = 640


def engine(pipeline=None, n_slots=2, **kw):
    return TPFIFOGameEngine(n_slots=n_slots, grain=2, preempt_quanta=2,
                            n_workers=4, tree_cap=CAP, pipeline=pipeline,
                            **kw)


def submit_mix(eng, n=6):
    for i in range(n):
        eng.submit(GameRequest(rid=i, game=["hex", "gomoku"][i % 2],
                               board_size=SIZE, n_playouts=48 + 16 * (i % 3),
                               n_tasks=8, seed=i))


# ------------------------------------------------------------ bit-identity ----
def test_pipelined_bit_identical_to_blocking_and_zero_recompiles():
    """EVERY retired request answers bitwise-identically whether its
    retirement readback blocked inline or was deferred a tick — and the
    pipelined run compiles nothing new (same quantum programs, same
    summary program)."""
    blocking = engine(pipeline=False)
    submit_mix(blocking)
    blocking.run()
    assert blocking.pipeline is False
    before = run_chunk._cache_size()

    pipelined = engine(pipeline=True)
    submit_mix(pipelined)
    pipelined.run()
    assert pipelined.pipeline is True
    assert run_chunk._cache_size() == before    # zero recompiles

    ra = {r.rid: r.result for r in blocking.finished}
    rb = {r.rid: r.result for r in pipelined.finished}
    assert set(ra) == set(rb) == set(range(6))
    for rid in ra:
        np.testing.assert_array_equal(ra[rid]["root_visits"],
                                      rb[rid]["root_visits"])
        np.testing.assert_array_equal(ra[rid]["root_wins"],
                                      rb[rid]["root_wins"])
        assert ra[rid]["best_move"] == rb[rid]["best_move"]
        assert ra[rid]["playouts"] == rb[rid]["playouts"]
        assert ra[rid]["rounds"] == rb[rid]["rounds"]
        assert ra[rid]["status"] == rb[rid]["status"]


def test_pipelined_default_on_and_drains_pending():
    """Pipelining is the default; run() must not exit with a retirement
    still deferred — every submitted request finishes answered."""
    eng = engine()                              # pipeline=None -> on
    assert eng.pipeline is True
    submit_mix(eng, n=5)
    eng.run()
    assert not eng._pending_retire
    assert not eng.has_work()
    assert len(eng.finished) == 5
    assert all(r.result["status"] == "answered" for r in eng.finished)


# ------------------------------------------------------------- auto-disable ----
def test_pipeline_auto_disables_under_observers_and_chaos():
    assert engine(pipeline=True, tracer=TraceRecorder()).pipeline is False
    plan = FaultPlan.generate(seed=1, n_ticks=10, n_slots=2, rate=0.1)
    inj = FaultInjector(plan)
    assert engine(pipeline=True, injector=inj).pipeline is False
    assert engine(pipeline=True, snapshots=True).pipeline is False
    assert engine(pipeline=True).pipeline is True


# --------------------------------------------------------- device accounting ----
def test_device_wait_recorded_in_stats():
    eng = engine(pipeline=False)
    submit_mix(eng, n=3)
    eng.run()
    qs = eng.stats()
    assert qs.device_wait_s > 0.0               # retirements blocked inline
    assert "device_wait_s" in qs.as_dict()


def test_forest_request_served_matches_batch_search():
    """A FOREST tenant (n_trees > 1) through the pipelined engine answers
    exactly what the standalone batch search answers for the same seed."""
    import jax

    from repro.core.root_parallel import gscpm_search_batch, merged_root_stats

    eng = engine()
    eng.submit(GameRequest(rid=0, game="hex", board_size=SIZE,
                           n_playouts=48, n_tasks=8, seed=3, n_trees=3))
    eng.run()
    res = eng.finished[0].result
    assert res["n_trees"] == 3
    assert res["playouts"] == 3 * 48

    cfg = eng.request_cfg(GameRequest(rid=0, game="hex", board_size=SIZE,
                                      n_playouts=48, n_tasks=8, seed=3,
                                      n_trees=3))
    from repro.core import hex as hx
    board = hx.empty_board(hx.HexSpec(SIZE))
    forest, stats = gscpm_search_batch(board, 1, cfg, jax.random.key(3),
                                       n_trees=3, shard="auto")
    mv, mw = merged_root_stats(forest, SIZE * SIZE)
    np.testing.assert_array_equal(res["root_visits"], np.asarray(mv))
    np.testing.assert_array_equal(res["root_wins"], np.asarray(mw))
    assert res["best_move"] == stats["best_move_sum"]
    assert res["best_move_vote"] == stats["best_move_vote"]
    assert res["member_best_moves"] == stats["member_best_moves"]


def test_forest_request_rejects_sessions_and_bad_widths():
    eng = engine()
    with pytest.raises(ValueError):
        eng.submit(GameRequest(rid=1, game="hex", board_size=SIZE,
                               n_playouts=16, n_tasks=8, seed=0, n_trees=0))
    with pytest.raises(ValueError):
        eng.submit(GameRequest(rid=2, game="hex", board_size=SIZE,
                               n_playouts=16, n_tasks=8, seed=0,
                               n_trees=True))
    with pytest.raises(ValueError):
        eng.submit(GameRequest(rid=3, game="hex", board_size=SIZE,
                               n_playouts=16, n_tasks=8, seed=0,
                               n_trees=2, session=object()))
