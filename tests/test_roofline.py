"""Roofline machinery: HLO cost parser vs known modules, collective parsing."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import collectives as coll
from repro.roofline import hlo_costs
from repro.roofline.terms import RooflineTerms, active_params, model_flops


def test_scan_trip_scaling():
    """Parser flops for a scanned matmul chain ~= n x single-matmul flops."""
    n, m = 12, 128

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    ws = jax.ShapeDtypeStruct((n, m, m), jnp.float32)
    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    got = hlo_costs.rollup(hlo)
    want = n * 2 * m**3
    assert want * 0.9 < got.flops < want * 1.6, (got.flops, want)
    assert got.while_trips and got.while_trips[0]["trip"] == n


def test_unrolled_matches_xla():
    """On a loop-free module the parser tracks XLA's own flops closely."""
    def f(a, b):
        return jnp.tanh(a @ b).sum()
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    got = hlo_costs.rollup(compiled.as_text())
    from repro.compat import cost_analysis_dict
    xla = cost_analysis_dict(compiled)["flops"]
    assert 0.5 * xla <= got.flops <= 2.0 * xla, (got.flops, xla)


def test_collective_parse_synthetic():
    text = """
ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %ag = f32[16,128]{1,0} all-gather(%p), replica_groups=[2,4]<=[8], dimensions={0}
  %ar = bf16[32,32]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[8,8]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
}
"""
    cs = coll.parse_collectives(text)
    assert [c.op for c in cs] == ["all-gather", "all-reduce",
                                  "collective-permute"]
    ag, ar, cp = cs
    assert ag.group_size == 4 and ag.result_bytes == 16 * 128 * 4
    assert ag.operand_bytes == ag.result_bytes // 4
    assert ar.group_size == 4 and ar.result_bytes == 32 * 32 * 2
    assert ar.wire_bytes == pytest.approx(2 * 3 / 4 * 32 * 32 * 2)
    assert cp.wire_bytes == 8 * 8 * 4


def test_active_params_moe():
    from repro import configs
    cfg = configs.get_config("qwen3-moe-235b-a22b")
    from repro.models import api
    n = api.n_params(cfg)
    a = active_params(cfg, n)
    # a22b: ~22B active of ~235B total
    assert 15e9 < a < 30e9, a
    dense = configs.get_config("llama3-8b")
    assert active_params(dense, api.n_params(dense)) == api.n_params(dense)


def test_roofline_terms():
    t = RooflineTerms(flops_per_chip=197e12, hbm_bytes_per_chip=819e9,
                      wire_bytes_per_chip=0.0, chips=256,
                      model_flops_global=197e12 * 256 / 2,
                      attn_flops_global=0.0)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.bottleneck in ("compute", "memory")
    assert t.mfu == pytest.approx(0.5)
    assert t.useful_ratio == pytest.approx(0.5)


def test_model_flops_kinds():
    from repro import configs
    from repro.models import api
    cfg = configs.get_config("llama3-8b")
    n = api.n_params(cfg)
    tr = model_flops(cfg, n, "train", 4096, 256)
    pf = model_flops(cfg, n, "prefill", 4096, 256)
    de = model_flops(cfg, n, "decode", 4096, 256)
    assert tr == pytest.approx(3 * pf)
    assert de == pytest.approx(pf / 4096)
