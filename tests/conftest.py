"""Pytest bootstrap: make src/ and the tests dir importable everywhere.

Keeps `PYTHONPATH=src python -m pytest` (the tier-1 command) and a bare
`pytest` invocation equivalent, and lets test modules import the local
`hypcompat` shim regardless of pytest's import mode.
"""

import os
import sys

_HERE = os.path.dirname(__file__)
_SRC = os.path.join(os.path.dirname(_HERE), "src")
for p in (_HERE, _SRC):
    if p not in sys.path:
        sys.path.insert(0, p)
