"""Serving: slot engine semantics + GSCPM token-tree decoding."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.serve.engine import MCTSSlotEngine, Request, SlotEngine
from repro.serve.mcts_decode import (MCTSDecodeConfig, backup_values,
                                     mcts_decode_search,
                                     mcts_decode_search_batch)


@pytest.fixture(scope="module")
def small_lm():
    cfg = configs.reduced_config("smollm-135m").replace(n_layers=2)
    params = api.init_params(cfg, jax.random.key(0))
    return cfg, params


def test_slot_engine_completes(small_lm):
    cfg, params = small_lm
    eng = SlotEngine(params, cfg, n_slots=2, max_len=48)
    rng = np.random.default_rng(0)
    for rid in range(5):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(1, cfg.vocab, size=(6,),
                                               dtype=np.int64).astype(np.int32),
                           max_new=5))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) == 5 or r.out[-1] == eng.eos_id for r in done)


def test_slot_engine_greedy_matches_direct(small_lm):
    """One request through the engine == direct prefill+decode loop."""
    cfg, params = small_lm
    prompt = np.arange(1, 9, dtype=np.int32)
    eng = SlotEngine(params, cfg, n_slots=1, max_len=32, temperature=0.0,
                     eos_id=-1)
    eng.submit(Request(rid=0, prompt=prompt, max_new=4))
    out = eng.run()[0].out

    logits, cache = api.prefill(params, cfg, {"tokens": jnp.asarray(prompt)[None]},
                                32)
    toks = [int(jnp.argmax(logits[0, 0]))]
    pos = len(prompt)
    for i in range(3):
        logits, cache = api.decode(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos + i], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits[0, 0])))
    assert out == toks


def test_mcts_decode_tree_growth(small_lm):
    cfg, params = small_lm
    prompt = jnp.arange(1, 7, dtype=jnp.int32)
    dcfg = MCTSDecodeConfig(n_playouts=24, n_tasks=6, n_workers=4, branch=4,
                            max_depth=3, rollout_len=3, tree_cap=128)
    tree, stats = mcts_decode_search(params, cfg, prompt, dcfg,
                                     jax.random.key(2))
    assert stats["playouts"] == 24
    assert 1 < stats["tree_nodes"] <= 128
    assert stats["root_children"] <= dcfg.branch
    # best token must be one of the root's children
    kids = np.asarray(tree.children[0][: int(tree.n_children[0])])
    moves = np.asarray(tree.move)[kids]
    assert stats["best_token"] in moves.tolist()
    # visits consistent: root visits == playouts
    assert float(tree.visits[0]) == pytest.approx(24.0)


def test_mcts_decode_grain_invariance(small_lm):
    """Same playout budget at different grains -> same amount of search."""
    cfg, params = small_lm
    prompt = jnp.arange(1, 7, dtype=jnp.int32)
    sizes = []
    for n_tasks in (4, 12):
        dcfg = MCTSDecodeConfig(n_playouts=24, n_tasks=n_tasks, n_workers=4,
                                branch=4, max_depth=3, rollout_len=3,
                                tree_cap=128)
        _, stats = mcts_decode_search(params, cfg, prompt, dcfg,
                                      jax.random.key(3))
        assert stats["playouts"] == 24
        sizes.append(stats["tree_nodes"])
    assert all(s > 1 for s in sizes)


def test_mcts_decode_batch_mixed_lengths(small_lm):
    """B=3 requests (mixed prompt lengths, one masked) through ONE shared
    jitted step per round: per-request trees grow independently; the masked
    slot's tree stays empty."""
    from repro.core.root_parallel import check_forest_invariants

    cfg, params = small_lm
    dcfg = MCTSDecodeConfig(n_playouts=24, n_tasks=6, n_workers=4, branch=4,
                            max_depth=3, rollout_len=3, tree_cap=128)
    prompts = np.zeros((3, 6), np.int32)
    prompts[0, :6] = np.arange(1, 7)
    prompts[1, :4] = np.arange(2, 6)
    prompts[2, :5] = 7
    forest, stats = mcts_decode_search_batch(
        params, cfg, jnp.asarray(prompts), dcfg, jax.random.key(2),
        prompt_lens=jnp.asarray([6, 4, 5], jnp.int32),
        request_mask=jnp.asarray([True, True, False]))
    assert stats["n_active_requests"] == 2
    assert stats["playouts"] == 2 * 24
    # active requests searched; masked request untouched
    assert all(n > 1 for n in stats["tree_nodes"][:2])
    assert stats["tree_nodes"][2] == 1 and stats["best_tokens"][2] == -1
    assert all(0 <= t < cfg.vocab for t in stats["best_tokens"][:2])
    assert all(0 < c <= dcfg.branch for c in stats["root_children"][:2])
    # per-request root visits == that request's playout budget
    np.testing.assert_allclose(np.asarray(forest.visits[:2, 0]), 24.0)
    # token trees back up continuous values, not win/draw/loss credits
    check_forest_invariants(jax.tree.map(lambda x: x[:2], forest),
                            discrete_credits=False)


def test_mcts_decode_prompt_len_traced_no_recompile(small_lm):
    """Two batches with different prompt lengths but identical shapes must
    reuse the same compiled search program (prompt_len is traced)."""
    from repro.serve import mcts_decode as md

    cfg, params = small_lm
    dcfg = MCTSDecodeConfig(n_playouts=8, n_tasks=2, n_workers=2, branch=3,
                            max_depth=2, rollout_len=2, tree_cap=64)
    prompts = np.ones((2, 8), np.int32)
    mcts_decode_search_batch(params, cfg, jnp.asarray(prompts), dcfg,
                             jax.random.key(0),
                             prompt_lens=jnp.asarray([8, 8], jnp.int32))
    before = md.run_chunk_batch._cache_size()
    mcts_decode_search_batch(params, cfg, jnp.asarray(prompts), dcfg,
                             jax.random.key(0),
                             prompt_lens=jnp.asarray([5, 3], jnp.int32))
    assert md.run_chunk_batch._cache_size() == before


def test_mcts_slot_engine_serves_queue(small_lm):
    """More requests than slots: all finish, outputs land in request order
    of admission, and the fixed token buffer never recompiles the search."""
    cfg, params = small_lm
    dcfg = MCTSDecodeConfig(n_playouts=8, n_tasks=2, n_workers=2, branch=3,
                            max_depth=2, rollout_len=2, tree_cap=64)
    eng = MCTSSlotEngine(params, cfg, dcfg, n_slots=2, max_prompt_len=12,
                         eos_id=-1)
    rng = np.random.default_rng(0)
    for rid in range(3):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(1, cfg.vocab, size=(4,),
                                               dtype=np.int64).astype(np.int32),
                           max_new=2))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out) == 2 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)
    # 2 slots, 3 requests, 2 tokens each -> 4 lockstep ticks
    assert len(eng.search_stats) == 4


def test_mcts_slot_engine_rejects_oversized_prompt(small_lm):
    cfg, params = small_lm
    dcfg = MCTSDecodeConfig(n_workers=2, branch=3, max_depth=2, rollout_len=2)
    eng = MCTSSlotEngine(params, cfg, dcfg, n_slots=1, max_prompt_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.arange(1, 8, dtype=np.int32),
                           max_new=4))


def test_backup_values():
    from repro.core.tree import init_tree
    tree = init_tree(8, 4, 1)
    paths = jnp.asarray([[0, 1, 8, 8], [0, 8, 8, 8]], jnp.int32)
    vals = jnp.asarray([0.5, 1.0])
    w = jnp.asarray([1.0, 1.0])
    t2 = backup_values(tree, paths, vals, w)
    assert float(t2.visits[0]) == 2.0
    assert float(t2.wins[0]) == pytest.approx(1.5)
    assert float(t2.visits[1]) == 1.0
    assert float(t2.wins[1]) == pytest.approx(0.5)
    assert float(t2.visits[tree.cap]) == 0.0  # pad row untouched
