"""GSCPM core tests: oracle equivalence, tree invariants, schedulers, quality."""

import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core import hex as hx
from repro.core import mcts, scheduler
from repro.core.gscpm import GSCPMConfig, expand_batch, gscpm_search
from repro.core.tree import best_child, check_invariants, init_tree, root_value

SIZE = 5


def cfg(**kw):
    base = dict(board_size=SIZE, n_playouts=256, n_tasks=8, n_workers=4,
                tree_cap=4096, select_noise=1e-3)
    base.update(kw)
    return GSCPMConfig(**base)


# ---------------------------------------------------------------- oracle ----
def test_w1_matches_sequential_oracle():
    """GSCPM with one lane, one task, no noise == sequential UCT, bit-exact.

    This pins the batched dedup-expansion + scatter-add backup machinery to
    the scalar reference implementation under an identical RNG schedule.
    """
    key = jax.random.PRNGKey(7)
    board = hx.empty_board(hx.HexSpec(SIZE))
    n = 128
    t_seq, s_seq = mcts.uct_search(board, 1, n, key, board_size=SIZE,
                                   tree_cap=1024)
    c = cfg(n_playouts=n, n_tasks=1, n_workers=1, select_noise=0.0,
            tree_cap=1024, scheduler="fifo")
    t_par, s_par = gscpm_search(board, 1, c, key)

    assert int(t_seq.n_nodes) == int(t_par.n_nodes)
    nn = int(t_seq.n_nodes)
    np.testing.assert_array_equal(np.asarray(t_seq.parent[:nn]),
                                  np.asarray(t_par.parent[:nn]))
    np.testing.assert_array_equal(np.asarray(t_seq.move[:nn]),
                                  np.asarray(t_par.move[:nn]))
    np.testing.assert_allclose(np.asarray(t_seq.visits[:nn]),
                               np.asarray(t_par.visits[:nn]))
    np.testing.assert_allclose(np.asarray(t_seq.wins[:nn]),
                               np.asarray(t_par.wins[:nn]))
    assert s_seq["best_move"] == s_par["best_move"]


@pytest.mark.parametrize("policy", ["fifo", "rebalance", "one_per_core"])
@pytest.mark.parametrize("workers,tasks", [(4, 8), (8, 8), (8, 3), (4, 64)])
def test_invariants_all_schedulers(policy, workers, tasks):
    key = jax.random.PRNGKey(3)
    board = hx.empty_board(hx.HexSpec(SIZE))
    c = cfg(n_workers=workers, n_tasks=tasks, scheduler=policy)
    tree, stats = gscpm_search(board, 1, c, key)
    check_invariants(tree)
    assert stats["playouts"] > 0
    # root visits == executed playouts (every iteration backs up thru root)
    assert int(np.asarray(tree.visits[0])) == stats["playouts"]


def test_vl_rounds_invariants():
    key = jax.random.PRNGKey(9)
    board = hx.empty_board(hx.HexSpec(SIZE))
    tree, stats = gscpm_search(board, 1, cfg(n_workers=8, vl_rounds=4), key)
    check_invariants(tree)
    assert np.asarray(tree.vloss).sum() == 0.0  # vloss reset after each step


def test_root_visits_equal_budget_fifo():
    key = jax.random.PRNGKey(0)
    board = hx.empty_board(hx.HexSpec(SIZE))
    c = cfg(n_playouts=256, n_tasks=16, n_workers=4)
    tree, stats = gscpm_search(board, 1, c, key)
    assert stats["playouts"] == 256
    assert int(np.asarray(tree.visits[0])) == 256


# ----------------------------------------------------------- search skill ----
def crossing_position():
    """Black column c=2 and white row r=2, both missing only (2,2).

    Whoever takes cell 12 wins instantly; every other black move leaves cell
    12 to a coin-flip in random playouts (≈0.5 value) while taking it is a
    deterministic win (1.0) — a sharply forced test position.
    """
    spec = hx.HexSpec(SIZE)
    b = hx.empty_board(spec)
    for r in (0, 1, 3, 4):
        b = b.at[r * SIZE + 2].set(1)  # black column
    for c in (0, 1, 3, 4):
        b = b.at[2 * SIZE + c].set(2)  # white row
    return b, 2 * SIZE + 2


def test_finds_immediate_win():
    b, win_move = crossing_position()
    tree, stats = gscpm_search(b, 1, cfg(n_playouts=512, n_workers=8),
                               jax.random.PRNGKey(1))
    assert stats["best_move"] == win_move
    assert stats["root_value"] > 0.6
    # the winning child's value estimate must be exactly 1.0 (deterministic win)
    kids = np.asarray(tree.children[0][: int(tree.n_children[0])])
    mv = np.asarray(tree.move)[kids]
    j = kids[list(mv).index(win_move)]
    assert float(tree.wins[j]) == float(tree.visits[j]) > 0


def test_quality_parity_parallel_vs_sequential():
    """Parallel search overhead must not destroy move quality (same winning
    move found by W=8 noisy search and sequential search)."""
    b, win_move = crossing_position()
    _, s_seq = mcts.uct_search(b, 1, 512, jax.random.PRNGKey(2), board_size=SIZE,
                               tree_cap=4096)
    _, s_par = gscpm_search(b, 1, cfg(n_playouts=512, n_workers=8, n_tasks=16),
                            jax.random.PRNGKey(2))
    assert s_seq["best_move"] == win_move
    assert s_par["best_move"] == win_move


# ------------------------------------------------------------ expansion ----
def test_expand_batch_dedup_and_slots():
    tree = init_tree(64, 25, 1)
    leaves = jnp.array([0, 0, 0, 0], dtype=jnp.int32)
    moves = jnp.array([3, 3, 7, -1], dtype=jnp.int32)  # dup (0,3); one invalid
    active = jnp.array([True, True, True, True])
    tree2, ids = expand_batch(tree, leaves, moves, active)
    ids = np.asarray(ids)
    assert int(tree2.n_nodes) == 3  # root + 2 unique children
    assert ids[0] == ids[1] != 64  # duplicates collapse
    assert ids[3] == 64            # invalid proposal -> PAD
    assert int(tree2.n_children[0]) == 2
    kids = np.asarray(tree2.children[0][:2])
    assert sorted(np.asarray(tree2.move)[kids].tolist()) == [3, 7]
    check_invariants(tree2._replace(visits=tree2.visits.at[0].set(1.0)))


def test_expand_batch_multi_leaf():
    tree = init_tree(64, 25, 1)
    # create two children of root first
    tree, _ = expand_batch(tree, jnp.array([0, 0]), jnp.array([1, 2]),
                           jnp.array([True, True]))
    l1, l2 = int(tree.children[0, 0]), int(tree.children[0, 1])
    leaves = jnp.array([l1, l2, l1, l2], dtype=jnp.int32)
    moves = jnp.array([5, 5, 6, 9], dtype=jnp.int32)
    tree2, ids = expand_batch(tree, leaves, moves, jnp.ones(4, bool))
    assert int(tree2.n_nodes) == 7
    assert int(tree2.n_children[l1]) == 2
    assert int(tree2.n_children[l2]) == 2
    ids = np.asarray(ids)
    assert len(set(ids.tolist())) == 4  # all distinct here


def test_expand_batch_capacity_clamp():
    tree = init_tree(2, 25, 1)  # room for root + 1 node only
    tree2, ids = expand_batch(tree, jnp.array([0, 0, 0]),
                              jnp.array([1, 2, 3]), jnp.ones(3, bool))
    ids = np.asarray(ids)
    assert int(tree2.n_nodes) == 2
    assert (ids == 2).sum() == 2  # two proposals hit the PAD row (cap=2)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       workers=st.sampled_from([2, 4, 8]),
       tasks=st.sampled_from([1, 4, 6, 32]),
       policy=st.sampled_from(["fifo", "rebalance"]))
def test_property_invariants_random_positions(seed, workers, tasks, policy):
    """Tree invariants hold from arbitrary midgame positions under any
    (workers × grain × scheduler) combination."""
    rng = np.random.default_rng(seed)
    spec = hx.HexSpec(SIZE)
    b = np.zeros(SIZE * SIZE, dtype=np.int8)
    k = int(rng.integers(0, 12))
    idx = rng.permutation(SIZE * SIZE)[:k]
    for t, i in enumerate(idx):
        b[i] = 1 if t % 2 == 0 else 2
    to_move = 1 if k % 2 == 0 else 2
    c = cfg(n_playouts=64, n_tasks=tasks, n_workers=workers, scheduler=policy)
    tree, stats = gscpm_search(jnp.asarray(b), to_move, c,
                               jax.random.PRNGKey(seed))
    check_invariants(tree)
    assert 0.0 <= stats["root_value"] <= 1.0


# ------------------------------------------------------------- scheduler ----
def test_fifo_masks_tail_lanes():
    s = scheduler.make_schedule(640, n_tasks=10, n_workers=4, policy="fifo")
    assert len(s) == 3
    assert s[-1].active.sum() == 2  # 10 tasks on 4 lanes -> 2 lanes idle
    st_ = scheduler.schedule_stats(s)
    assert st_["utilization"] < 1.0


def test_rebalance_keeps_lanes_busy():
    s = scheduler.make_schedule(640, n_tasks=10, n_workers=4, policy="rebalance")
    st_ = scheduler.schedule_stats(s)
    assert st_["lane_iterations"] == 640
    # only the final sub-width round may mask lanes
    assert all(r.active.all() for r in s[:-1])


def test_schedules_preserve_budget():
    for policy in ("fifo", "rebalance", "one_per_core", "sequential"):
        s = scheduler.make_schedule(512, 16, 8, policy)
        assert scheduler.schedule_stats(s)["lane_iterations"] == 512, policy


@settings(max_examples=15, deadline=None)
@given(n_playouts=st.integers(8, 640),
       tasks=st.sampled_from([1, 3, 5, 8, 10, 32]),
       workers=st.sampled_from([2, 4, 8]))
def test_property_rebalance_stats(n_playouts, tasks, workers):
    """`schedule_stats` properties of the rebalance policy vs fifo:

    - total-playout conservation: both policies schedule exactly the same
      lane-iteration budget (playouts are fungible; the split may floor);
    - idle-lane fraction: rebalance never utilizes lanes worse than fifo
      (it exists to re-split fifo's masked tail across all lanes);
    - rebalance idles lanes only in the final sub-width round, and wastes
      fewer than W lane-iterations doing so.
    """
    fifo = scheduler.make_schedule(n_playouts, tasks, workers, "fifo")
    reb = scheduler.make_schedule(n_playouts, tasks, workers, "rebalance")
    sf = scheduler.schedule_stats(fifo)
    sr = scheduler.schedule_stats(reb)
    assert sr["lane_iterations"] == sf["lane_iterations"]
    assert sr["lane_iterations"] <= n_playouts
    assert sr["utilization"] >= sf["utilization"] - 1e-12
    assert all(r.active.all() for r in reb[:-1])
    assert sr["masked_lane_iterations"] < workers


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       grain=st.sampled_from([1, 2, 3, 8]),
       policy=st.sampled_from(["fifo", "rebalance"]))
def test_property_quantum_plan_serves_mixed_requests(seed, grain, policy):
    """`quantum_plan` over a mixed-game request set — the host-side schedule
    TPFIFO game serving runs on:

    - budget conservation: each request's quanta sum to EXACTLY its GSC-PM
      round count (rounds are commit points; dropping or duplicating one
      would break the bit-identity contract);
    - every quantum makes progress (>=1 round — the PR 2 livelock guard);
    - round-robin tail-requeue service drains the whole set in at most
      max-plan-length queue cycles: no request is ever starved by a mix of
      budgets and game classes.
    """
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(2, 7))
    rounds_of = []
    for _ in range(n_req):
        sch = scheduler.make_schedule(int(rng.integers(8, 1025)),
                                      int(2 ** rng.integers(0, 7)),
                                      int(2 ** rng.integers(1, 4)), "fifo")
        rounds_of.append(len(sch))
    plans = [scheduler.quantum_plan(n, grain, policy) for n in rounds_of]
    for n, plan in zip(rounds_of, plans):
        assert sum(plan) == n
        assert min(plan) >= 1
    queue = collections.deque(range(n_req))
    rem, nxt, cycles = list(rounds_of), [0] * n_req, 0
    while queue:
        cycles += 1
        for _ in range(len(queue)):
            r = queue.popleft()
            q = plans[r][nxt[r]] if nxt[r] < len(plans[r]) else grain
            served = min(q, rem[r])
            assert served >= 1          # progress per admission segment
            rem[r] -= served
            nxt[r] += 1
            if rem[r]:
                queue.append(r)
    assert all(v == 0 for v in rem)
    assert cycles <= max(len(p) for p in plans)


def test_rng_streams_differ_between_tasks():
    """Different tasks must explore differently (per-task MKL-stream analogue)."""
    key = jax.random.PRNGKey(0)
    board = hx.empty_board(hx.HexSpec(SIZE))
    t1, _ = gscpm_search(board, 1, cfg(n_playouts=64, n_tasks=1, n_workers=1,
                                       select_noise=0.0), key)
    t2, _ = gscpm_search(board, 1, cfg(n_playouts=64, n_tasks=2, n_workers=1,
                                       select_noise=0.0), key)
    assert not np.array_equal(np.asarray(t1.visits[:64]),
                              np.asarray(t2.visits[:64]))
