"""Concurrent hex + gomoku searches through the TPFIFO game engine.

Four search-a-move requests — two hex, two gomoku, mixed playout budgets,
one with a time-to-move deadline — share one engine (DESIGN.md §14). Each
game class gets its own slot pool and ONE compiled quantum program; the
engine interleaves m-round quanta with tail-requeue preemption, and every
answer is bit-identical to running that search alone.

    PYTHONPATH=src python examples/serve_games.py
"""

from repro.serve.games import GameRequest, TPFIFOGameEngine


def main():
    eng = TPFIFOGameEngine(n_slots=1, grain=2, preempt_quanta=1,
                           n_workers=8)
    requests = [
        GameRequest(rid="hex-big", game="hex", board_size=7,
                    n_playouts=2048, n_tasks=64, seed=0),
        GameRequest(rid="gomoku-big", game="gomoku", board_size=7,
                    n_playouts=2048, n_tasks=64, seed=1),
        # small requests arrive behind the big ones; preemption lets them
        # slip between quanta instead of waiting out the whole searches
        GameRequest(rid="hex-quick", game="hex", board_size=7,
                    n_playouts=256, n_tasks=32, seed=2),
        GameRequest(rid="gomoku-dl", game="gomoku", board_size=7,
                    n_playouts=4096, n_tasks=64, seed=3, deadline_s=4.5),
    ]
    for r in requests:
        eng.submit(r)
    done = eng.run()

    for r in done:
        res = r.result
        tag = "  <- deadline, partial stats" if res["deadline_expired"] else ""
        print(f"{str(r.rid):>10}: {res['game']:>6} -> move {res['best_move']:>3} "
              f"value {res['root_value']:+.3f}  "
              f"{res['playouts']:>5} playouts "
              f"({res['rounds']}/{res['rounds_total']} rounds, "
              f"{res['preemptions']} preemptions){tag}")
    st = eng.stats()
    print(f"\n{st.n_finished} searches, {st.quanta} quanta, "
          f"{st.n_preemptions} preemptions; move latency p50/p95 "
          f"{st.latency_p50:.2f}/{st.latency_p95:.2f} s")


if __name__ == "__main__":
    main()
