"""Trace a multi-tenant serve run and fit its measured work/span profile.

Four tenants — two hex, two gomoku, mixed budgets and grains — share one
TPFIFO game engine with the full observability stack attached (DESIGN.md
§15): a ``TraceRecorder`` captures admissions, per-quantum spans, preempts,
device syncs, and jit compiles as Chrome/Perfetto trace-event JSON; a
``MetricsRegistry`` keeps the running counters; and the device-plane
``SearchMetrics`` accumulator rides every search (results stay
bit-identical). Afterwards ``repro.obsv.profile`` least-squares the
per-round dispatch burden out of the recorded quantum spans and prints the
measured-vs-analytic parallelism table — the Fig 9 overlay, from this very
run's trace instead of guessed constants.

    PYTHONPATH=src python examples/trace_serving.py
    # then load /tmp/trace_serving.json in chrome://tracing or
    # https://ui.perfetto.dev
"""

from repro.obsv import MetricsRegistry, TraceRecorder, validate_trace
from repro.obsv.profile import (
    fit_dispatch_profile,
    format_table,
    measured_vs_analytic,
)
from repro.serve.games import GameRequest, TPFIFOGameEngine

TRACE_PATH = "/tmp/trace_serving.json"


def main():
    tracer = TraceRecorder(process_name="trace-serving-example")
    registry = MetricsRegistry()
    eng = TPFIFOGameEngine(n_slots=1, grain=2, preempt_quanta=1,
                           n_workers=8, metrics=True,
                           tracer=tracer, registry=registry)
    # a compile-only warm-up request per game class keeps the profiling
    # spans clean (the fitter also excludes compile-tainted spans itself)
    for rid, game in (("warm-hex", "hex"), ("warm-gomoku", "gomoku")):
        eng.submit(GameRequest(rid=rid, game=game, board_size=7,
                               n_playouts=8, n_tasks=8, seed=9))
    eng.run()

    tenants = [
        GameRequest(rid="hex-big", game="hex", board_size=7,
                    n_playouts=2048, n_tasks=64, seed=0),
        GameRequest(rid="gomoku-big", game="gomoku", board_size=7,
                    n_playouts=2048, n_tasks=64, seed=1),
        GameRequest(rid="hex-quick", game="hex", board_size=7,
                    n_playouts=256, n_tasks=32, seed=2),
        GameRequest(rid="gomoku-quick", game="gomoku", board_size=7,
                    n_playouts=512, n_tasks=16, seed=3),
    ]
    for r in tenants:
        eng.submit(r)
    done = eng.run()

    for r in done:
        res = r.result
        if str(r.rid).startswith("warm"):
            continue
        dm = res["metrics"]
        print(f"{str(r.rid):>12}: {res['game']:>6} -> move "
              f"{res['best_move']:>3} value {res['root_value']:+.3f}  "
              f"{res['playouts']:>5} playouts, depth mean "
              f"{dm['depth_mean']:.2f}, {dm['expansions']} expansions, "
              f"leaf-collision rate {dm['leaf_collision_rate']:.2f}")

    n_events = validate_trace(tracer.to_dict())
    tracer.save(TRACE_PATH)
    print(f"\ntrace: {n_events} events -> {TRACE_PATH} "
          f"(chrome://tracing or ui.perfetto.dev)")
    print("compile counts:", tracer.compile_counts())
    print("\ncounters:")
    for line in registry.exposition().strip().splitlines():
        if not line.startswith("#"):
            print(f"  {line}")

    profile = fit_dispatch_profile(tracer, n_workers=8)
    print(f"\nmeasured dispatch profile ({profile['n_spans']} spans, "
          f"{profile['n_excluded_compile']} compile-tainted excluded): "
          f"t_round {profile['t_round_s']*1e3:.2f} ms, "
          f"t_iter {profile['t_iter_s']*1e3:.3f} ms"
          + ("" if profile["identifiable"] else "  [rank-deficient fit]"))
    rows = measured_vs_analytic(profile, n_playouts=2048,
                                task_counts=(16, 64, 256, 1024), n_cores=61)
    print(format_table(rows))


if __name__ == "__main__":
    main()
