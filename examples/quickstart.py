"""Quickstart: Grain-Size Controlled Parallel MCTS on 9x9 Hex.

Runs the paper's core experiment in miniature: a sequential UCT baseline,
then GSCPM at a sweep of grain sizes, printing the speedup curve (the
Fig 7 shape: coarse grains starve the lanes, fine grains saturate them).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import hex as hx
from repro.core.gscpm import GSCPMConfig, gscpm_search
from repro.core.mcts import uct_search


def main():
    board_size, n_playouts, n_workers = 9, 1024, 16
    spec = hx.HexSpec(board_size)
    board = hx.empty_board(spec)
    key = jax.random.key(0)

    print(f"Hex {board_size}x{board_size}, {n_playouts} playouts, "
          f"{n_workers} lanes")
    uct_search(board, 1, 64, key, board_size=board_size)      # warm-up
    _, seq = uct_search(board, 1, n_playouts, key, board_size=board_size)
    print(f"sequential: {seq['playouts_per_s']:8.0f} playouts/s  "
          f"best move {seq['best_move']}  root value {seq['root_value']:.3f}")

    for n_tasks in (n_workers, 64, 256):
        cfg = GSCPMConfig(board_size=board_size, n_playouts=n_playouts,
                          n_tasks=n_tasks, n_workers=n_workers,
                          scheduler="fifo")
        gscpm_search(board, 1, cfg, key)                      # warm-up
        _, st = gscpm_search(board, 1, cfg, key)
        label = ("one-task-per-lane" if n_tasks == n_workers
                 else f"grain m={cfg.grain}")
        print(f"GSCPM nTasks={n_tasks:4d} ({label:17s}): "
              f"{st['playouts_per_s']:8.0f} playouts/s  "
              f"speedup {st['playouts_per_s']/seq['playouts_per_s']:5.2f}x  "
              f"best move {st['best_move']}")


if __name__ == "__main__":
    main()
