"""Self-play sessions: two whole games with cross-move tree reuse.

Two ``GameSession`` tenants — one playing 5x5 Hex, one 5x5 Gomoku — share
a single TPFIFO engine and play their games to completion (DESIGN.md §16).
Each session holds its device-resident search tree between moves: after
every ``play(move)`` the tree is re-rooted onto the played child
(``core.tree.reroot_tree``), so the next search starts from the retained
subtree and only runs the remainder of its evidence budget. Per-move lines
print the retained-visit fraction — the statistic the warm-vs-cold
benchmark (benchmarks/selfplay.py) aggregates.

    PYTHONPATH=src python examples/selfplay.py
"""

from repro.serve.games import GameSession, TPFIFOGameEngine

SIZE = 5
PLAYOUTS = 256
OUTCOME = {0: "draw", 1: "player 1 wins", 2: "player 2 wins"}


def play_out(eng, sess: GameSession, max_moves: int = 25) -> None:
    print(f"[{sess.game} {SIZE}x{SIZE}] session {sess.name}")
    for _ in range(max_moves):
        req = sess.make_request(n_playouts=PLAYOUTS, n_tasks=16)
        eng.submit(req)
        eng.run()
        res = req.result
        mv = res["best_move"]
        if mv < 0:
            break
        sess.play(mv)
        print(f"  mv{len(sess.moves):>3} p{3 - sess.to_move} -> {mv:>3}  "
              f"{res['playouts']:>4} fresh playouts, "
              f"reused {res['reused_visits']:>4} visits; after re-root "
              f"retained {sess.retained_fraction:.2f} of the tree's "
              f"evidence")
        if sess.over():
            break
    print(f"  {OUTCOME.get(sess.winner(), 'unfinished')} "
          f"after {len(sess.moves)} moves\n")


def main():
    # one engine, two game classes: each class compiles ONE quantum
    # program and owns its own slot pool; both sessions ride it
    eng = TPFIFOGameEngine(n_slots=2, grain=4, n_workers=8, tree_cap=2048)
    play_out(eng, GameSession(eng, "hex", SIZE, base_seed=0))
    play_out(eng, GameSession(eng, "gomoku", SIZE, base_seed=1))


if __name__ == "__main__":
    main()
