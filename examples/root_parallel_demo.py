"""Root parallelism demo: an ensemble of trees in one jitted program.

Searches a 7x7 Hex opening with E independent GSCPM trees advanced by a
single compiled program per round (DESIGN.md §3), prints each member's own
move choice, the two merge disciplines' answers, and the aggregate
throughput vs the single-tree baseline; then repeats with periodic root
synchronization so members share discoveries mid-search.

    PYTHONPATH=src python examples/root_parallel_demo.py
"""

import jax

from repro.core.gscpm import GSCPMConfig, gscpm_search
from repro.core.root_parallel import gscpm_search_batch


def main():
    # the classic root-parallel regime: each member is a NARROW searcher
    # (few lanes); the ensemble axis carries the parallelism
    board_size, n_playouts, n_workers, n_trees = 7, 1024, 2, 8
    cfg = GSCPMConfig(board_size=board_size, n_playouts=n_playouts,
                      n_tasks=16, n_workers=n_workers, tree_cap=2048)
    board = cfg.game_obj.init_board()
    key = jax.random.key(0)

    print(f"Hex {board_size}x{board_size}, {n_playouts} playouts/tree, "
          f"{n_workers} lanes/tree, E={n_trees} trees")

    gscpm_search(board, 1, cfg, key)                    # warm-up
    _, single = gscpm_search(board, 1, cfg, key)
    print(f"single tree      : {single['playouts_per_s']:9.0f} playouts/s  "
          f"best move {single['best_move']}")

    for merge_every, label in ((0, "independent"), (2, "sync every 2 rounds")):
        gscpm_search_batch(board, 1, cfg, key, n_trees=n_trees,
                           merge_every=merge_every)     # warm-up
        _, st = gscpm_search_batch(board, 1, cfg, key, n_trees=n_trees,
                                   merge_every=merge_every)
        print(f"E={n_trees} ({label:20s}): {st['playouts_per_s']:9.0f} "
              f"playouts/s  aggregate "
              f"{st['playouts_per_s'] / single['playouts_per_s']:5.2f}x")
        print(f"    member votes {st['member_best_moves']}")
        print(f"    visit-sum merge -> {st['best_move_sum']}   "
              f"majority vote -> {st['best_move_vote']}")


if __name__ == "__main__":
    main()
