"""Serving e2e driver: batched requests + GSCPM-guided decoding.

Part 1 serves a batch of prompts through the continuous-batching slot
engine (one compiled decode step, slots refill from the queue).
Part 2 decodes with Grain-Size Controlled MCTS — the paper's technique as
a first-class serving feature — and shows the grain-size dial: the same
playout budget at different nTasks.
Part 3 serves MULTIPLE search-guided requests at once: the MCTS slot
engine gives every request its own token tree and advances all of them
through one shared jitted step (root parallelism, DESIGN.md §3).
Part 4 swaps the lockstep pool for the TPFIFO work-sharing queue
(DESIGN.md §10): grain-sized quanta, chunked prefill, preemption, and
per-request queue telemetry.

    PYTHONPATH=src python examples/serve_mcts.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api
from repro.serve.engine import Request, SlotEngine
from repro.serve.mcts_decode import MCTSDecodeConfig, mcts_decode_search


def main():
    cfg = configs.reduced_config("smollm-135m")
    params = api.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)

    # ---- part 1: continuous-batched greedy serving --------------------
    eng = SlotEngine(params, cfg, n_slots=4, max_len=64)
    for rid in range(8):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(rid=rid, prompt=rng.integers(
            1, cfg.vocab, size=(plen,)).astype(np.int32), max_new=12))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    tok = sum(len(r.out) for r in done)
    print(f"slot engine: {len(done)} requests, {tok} tokens, "
          f"{tok/dt:.1f} tok/s (4 slots, 1 compiled decode step)")

    # ---- part 2: GSCPM decoding, sweeping the grain dial --------------
    prompt = jnp.asarray(rng.integers(1, cfg.vocab, size=(12,)), jnp.int32)
    for n_tasks in (4, 16, 64):
        dcfg = MCTSDecodeConfig(n_playouts=64, n_tasks=n_tasks, n_workers=4,
                                branch=6, max_depth=4, rollout_len=6)
        _, st = mcts_decode_search(params, cfg, prompt, dcfg,
                                   jax.random.key(1))
        print(f"GSCPM nTasks={n_tasks:3d} grain m={st['grain']:3d}: "
              f"{st['playouts']} playouts -> tree {st['tree_nodes']:4d} "
              f"nodes, best token {st['best_token']} "
              f"({st['playouts_per_s']:.0f} playouts/s)")

    # ---- part 3: multi-user MCTS serving (one tree per request) -------
    from repro.serve.engine import MCTSSlotEngine

    dcfg = MCTSDecodeConfig(n_playouts=32, n_tasks=8, n_workers=4,
                            branch=4, max_depth=3, rollout_len=4,
                            tree_cap=256)
    meng = MCTSSlotEngine(params, cfg, dcfg, n_slots=3, max_prompt_len=32)
    for rid in range(5):
        plen = int(rng.integers(4, 10))
        meng.submit(Request(rid=rid, prompt=rng.integers(
            1, cfg.vocab, size=(plen,)).astype(np.int32), max_new=4))
    t0 = time.perf_counter()
    done = meng.run()
    dt = time.perf_counter() - t0
    tok = sum(len(r.out) for r in done)
    searches = len(meng.search_stats)
    print(f"MCTS slot engine: {len(done)} requests, {tok} searched tokens "
          f"in {searches} lockstep ticks, {tok/dt:.1f} tok/s "
          f"(3 slots, 3 trees, one jitted search step)")

    # ---- part 4: TPFIFO work-sharing queue (DESIGN.md §10) ------------
    # the paper's thread pool as a serving scheduler: grain-sized quanta,
    # chunked prefill (the 28-token prompt never blocks the short ones),
    # preemption+requeue after 4 quanta, p50/p95 queue telemetry
    from repro.serve.tpfifo import TPFIFOEngine

    qeng = TPFIFOEngine(params, cfg, n_slots=4, max_len=64, grain=8,
                        policy="fifo", preempt_quanta=4)
    qeng.submit(Request(rid=0, prompt=rng.integers(
        1, cfg.vocab, size=(28,)).astype(np.int32), max_new=8))
    for rid in range(1, 8):
        plen = int(rng.integers(4, 10))
        qeng.submit(Request(rid=rid, prompt=rng.integers(
            1, cfg.vocab, size=(plen,)).astype(np.int32), max_new=12))
    t0 = time.perf_counter()
    done = qeng.run()
    dt = time.perf_counter() - t0
    st = qeng.stats()
    tok = sum(len(r.out) for r in done)
    print(f"TPFIFO engine: {len(done)} requests, {tok} tokens in "
          f"{qeng._ticks} quanta of m=8, {tok/dt:.1f} tok/s; queue wait "
          f"p50/p95 {st.queue_wait_p50*1e3:.0f}/{st.queue_wait_p95*1e3:.0f} "
          f"ms, latency p95 {st.latency_p95*1e3:.0f} ms, "
          f"{st.n_preemptions} preemptions")


if __name__ == "__main__":
    main()
