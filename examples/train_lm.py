"""End-to-end LM training driver on the synthetic pipeline.

Trains a SmolLM-family model with the full production stack: deterministic
data pipeline, AdamW + cosine, microbatch grad accumulation (the grain-size
dial), async checkpointing with resume, bad-step skip, straggler watchdog.

Default is a fast CPU-sized twin; ``--full`` trains the real 135M config
(the "~100M model for a few hundred steps" e2e driver — expect hours on
CPU, minutes on a real accelerator).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]
"""

import argparse

from repro import configs
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import LoopConfig, train


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--full", action="store_true",
                   help="the real smollm-135m config (slow on CPU)")
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    p.add_argument("--microbatches", type=int, default=2)
    args = p.parse_args()

    if args.full:
        cfg = configs.get_config("smollm-135m").replace(
            param_dtype="float32", compute_dtype="float32")
        data = DataConfig(seq_len=512, global_batch=8)
    else:
        cfg = configs.reduced_config("smollm-135m").replace(
            n_layers=4, d_model=256, d_ff=512, vocab=2048)
        data = DataConfig(seq_len=128, global_batch=16)

    out = train(
        cfg,
        OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        data,
        LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100,
                   log_every=20, n_microbatches=args.microbatches),
    )
    h = out["history"]
    print(f"\nloss {h[0]['loss']:.3f} -> {out['final_loss']:.3f} over "
          f"{len(h)} steps ({out['steps_per_s']:.2f} steps/s); "
          f"checkpoints in {args.ckpt_dir} (re-run to resume)")


if __name__ == "__main__":
    main()
