"""Chaos serving demo: the TPFIFO game engine absorbing injected faults.

Serves a mixed hex+gomoku request batch twice — once clean, once under a
seeded ``FaultPlan`` (dispatch errors, NaN root-stat poisoning, clock
stalls, duplicate submissions; DESIGN.md §17) — and shows the resilience
machinery at work: failed quanta retried from committed snapshots with
exponential backoff, repeatedly-failing slots quarantined while the
survivors keep serving, corrupted answers caught by the result guard, and
every recovered result **bit-identical** to the clean run. The same
behavior is drivable from the CLI:

    python -m repro.launch.serve --mcts-game mixed --scheduler tpfifo \\
        --chaos-rate 0.2 --chaos-seed 7 --quarantine-after 3 --max-queue 16

Run me:  PYTHONPATH=src python examples/chaos_serving.py
"""

import numpy as np

from repro.core.gscpm import run_chunk
from repro.serve.games import GameRequest, TPFIFOGameEngine
from repro.serve.resilience import FaultInjector, FaultPlan


def requests():
    return [GameRequest(rid=i, game=("hex", "gomoku")[i % 2], board_size=5,
                        n_playouts=128, n_tasks=32, seed=i)
            for i in range(6)]


def main():
    # clean reference serve (also warms the per-class quantum programs)
    clean_eng = TPFIFOGameEngine(n_slots=2, grain=2, n_workers=4,
                                 tree_cap=512)
    clean = requests()
    for r in clean:
        clean_eng.submit(r)
    clean_eng.run()
    cache = run_chunk._cache_size()
    print(f"clean serve: {len(clean_eng.finished)} answered, "
          f"{cache} compiled quantum programs")

    # chaos serve: same seeds, deterministic fault plan
    plan = FaultPlan.generate(seed=7, n_ticks=200, n_slots=4, rate=0.25)
    injector = FaultInjector(plan)
    eng = TPFIFOGameEngine(n_slots=2, grain=2, n_workers=4, tree_cap=512,
                           injector=injector, quarantine_after=3,
                           max_queue=16, retry_backoff=(1, 8))
    chaos = requests()
    for r in chaos:
        eng.submit(r)
    eng.run(max_ticks=20_000)

    st = eng.stats()
    fired = injector.summary()
    print(f"chaos serve: {fired['fired_total']} faults fired "
          f"{fired['fired']}, {st.n_retries} retries, "
          f"{st.n_quarantined} quarantined slots, {st.n_shed} shed")

    ref = {r.rid: r.result for r in clean}
    for r in sorted(chaos, key=lambda r: r.rid):
        res = r.result
        same = (np.array_equal(res["root_visits"],
                               ref[r.rid]["root_visits"])
                and np.array_equal(res["root_wins"],
                                   ref[r.rid]["root_wins"]))
        print(f"  req {r.rid}: {res['game']:>6} -> move {res['best_move']:>3}"
              f"  status={res['status']}  retries={res.get('retries', 0)}"
              f"  bit-identical to clean: {same}")
        assert same, "recovery must be bit-identical"
    grown = run_chunk._cache_size() - cache
    print(f"jit cache growth across chaos: {grown} (must be 0)")
    assert grown == 0


if __name__ == "__main__":
    main()
