"""Training step: loss + grad + AdamW, microbatched, optionally compressed.

Two jit-able step functions:

- ``train_step`` — the GSPMD path: batch sharded over ("pod","data"),
  gradient all-reduce inserted automatically by the partitioner.
- ``train_step_compressed`` — identical math, but the step runs inside a
  ``shard_map`` that is *manual over the pod axis only* (data/model stay on
  the GSPMD auto path); the cross-pod gradient reduction goes through
  int8 block-quantized all-gather (``repro.optim.compression``) — the
  DCN-friendly distributed-optimization trick from DESIGN.md §6.

**Grain size control, the training-side analogue** (DESIGN.md §4): the
global batch is split into ``n_microbatches`` grains accumulated under
``lax.scan``. Exactly like the paper's nTasks dial, more grains trade
parallel width (per-step live activation memory) against loop overhead;
§Perf hillclimbs it.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import api
from repro.models.common import ModelConfig
from repro.optim import adamw
from repro.optim.compression import compressed_psum_tree
from repro.sharding import rules as shr

BATCH_KEYS = ("tokens", "labels", "mask", "patches", "frames")


def make_state(cfg: ModelConfig, key: jax.Array,
               moment_dtype: str = "float32") -> dict:
    params = api.init_params(cfg, key)
    return {"params": params,
            "opt": adamw.init_opt_state(params, moment_dtype)}


def abstract_state(cfg: ModelConfig, moment_dtype: str = "float32") -> dict:
    """ShapeDtypeStruct state tree (dry-run stand-in, no allocation)."""
    params = api.abstract_params(cfg)
    mdt = jnp.dtype(moment_dtype)
    mom = lambda p: jax.ShapeDtypeStruct(p.shape, mdt)
    return {"params": params,
            "opt": {"m": jax.tree.map(mom, params),
                    "v": jax.tree.map(mom, params),
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def state_axes(cfg: ModelConfig) -> dict:
    """Logical-axis tree parallel to the state (moments shard like params)."""
    axes = api.param_axes(cfg)
    is_ax = lambda x: isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)
    return {"params": axes,
            "opt": {"m": jax.tree.map(lambda a: a, axes, is_leaf=is_ax),
                    "v": jax.tree.map(lambda a: a, axes, is_leaf=is_ax),
                    "step": ()}}


def _split_micro(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for every present batch leaf."""
    def sp(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])
    return {k: sp(v) for k, v in batch.items() if k in BATCH_KEYS}


def _mean_grads(cfg: ModelConfig, params, batch: dict, n_micro: int,
                accum_dtype=jnp.float32):
    """Microbatch-accumulated (loss, grads) — the grain-size scan."""
    loss_fn = lambda p, b: api.loss(p, cfg, b)
    if n_micro <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads
    micro = _split_micro(batch, n_micro)

    def body(carry, mb):
        acc_loss, acc_g = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        return (acc_loss + loss,
                jax.tree.map(lambda a, gg: a + gg.astype(a.dtype),
                             acc_g, g)), None

    from repro.models.common import maybe_scan
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
    (loss_sum, grad_sum), _ = maybe_scan(cfg, body, (jnp.float32(0.0), zeros),
                                         micro)
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: (g * inv).astype(g.dtype),
                                        grad_sum)


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
                    n_microbatches: int = 1, accum_dtype=jnp.float32):
    """The GSPMD train step: state, batch -> state, metrics."""

    def step(state: dict, batch: dict):
        loss, grads = _mean_grads(cfg, state["params"], batch, n_microbatches,
                                  accum_dtype)
        params, opt, metrics = adamw.adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        metrics["loss"] = loss
        return {"params": params, "opt": opt}, metrics

    return step


def make_train_step_compressed(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
                               mesh, n_microbatches: int = 1,
                               pod_axis: str = "pod"):
    """Manual-over-pod step with the int8-compressed cross-pod reduce."""
    from jax.sharding import PartitionSpec as P
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))[pod_axis]

    def inner(state: dict, batch: dict):
        loss, grads = _mean_grads(cfg, state["params"], batch, n_microbatches)
        # cross-pod mean with int8 on the wire (exact path: lax.pmean)
        grads = jax.tree.map(lambda g: g / n_pods,
                             compressed_psum_tree(grads, pod_axis))
        loss = jax.lax.pmean(loss, pod_axis)
        params, opt, metrics = adamw.adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        metrics["loss"] = loss
        return {"params": params, "opt": opt}, metrics

    def step(state: dict, batch: dict):
        batch_specs = {k: P(pod_axis) for k in batch}
        f = compat.shard_map(
            inner, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), state), batch_specs),
            out_specs=(jax.tree.map(lambda _: P(), state),
                       {"loss": P(), "grad_norm": P(), "lr": P(),
                        "skipped": P()}),
            axis_names={pod_axis}, check=False)
        return f(state, batch)

    return step


# --------------------------------------------------------------- shardings ----
def state_shardings(mesh, cfg: ModelConfig, rules=None):
    axes = state_axes(cfg)
    is_ax = lambda x: isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)
    return jax.tree.map(
        lambda a: shr.named_sharding(mesh, a, rules), axes, is_leaf=is_ax)


def batch_shardings(mesh, batch_tree, rules=None):
    def leading_batch(x):
        ndim = len(x.shape) if hasattr(x, "shape") else x.ndim
        return shr.named_sharding_for(
            mesh, ("batch",) + (None,) * (ndim - 1), tuple(x.shape), rules)
    return jax.tree.map(leading_batch, batch_tree)


def jit_train_step(step_fn, mesh, cfg: ModelConfig, batch_tree, rules=None,
                   donate: bool = True):
    """jit with explicit in/out shardings for the production mesh."""
    ss = state_shardings(mesh, cfg, rules)
    bs = batch_shardings(mesh, batch_tree, rules)
    ms = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return jax.jit(
        step_fn,
        in_shardings=(ss, bs),
        out_shardings=(ss, {"loss": ms, "grad_norm": ms, "lr": ms,
                            "skipped": ms}),
        donate_argnums=(0,) if donate else ())
