"""Fault-tolerant training loop: checkpoint/restart, skip-on-spike, watchdog.

Designed for the 1000+-node posture (DESIGN.md §6):

- **restart**: on startup the loop restores the newest valid checkpoint
  (params + optimizer + data-iterator step) and continues exactly where the
  failed run left off; a crash can lose at most `ckpt_every` steps.
- **async checkpointing**: device->host snapshot is synchronous (cheap),
  disk I/O overlaps the next steps.
- **bad-step skip**: non-finite grad norms leave params/moments untouched
  (see ``repro.optim.adamw``) — a poisoned batch or a flaky host cannot
  corrupt the run.
- **straggler watchdog**: per-step wall times feed a rolling median; steps
  slower than ``watchdog_factor`` x median are surfaced to the log (on real
  pods this is where you page / trigger hot-spare swap; on CPU it just
  reports). This is the monitoring half of straggler mitigation; the
  scheduling half is the paper's own grain-size story (fine-grained
  microbatches keep lanes busy).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, Prefetcher, make_batch_fn
from repro.models.common import ModelConfig
from repro.optim import adamw
from repro.train import step as step_mod


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    n_microbatches: int = 1
    watchdog_factor: float = 3.0
    seed: int = 0


def train(cfg: ModelConfig, opt_cfg: adamw.OptConfig, data_cfg: DataConfig,
          loop: LoopConfig, mesh=None,
          log: Callable[[str], None] = print) -> dict[str, Any]:
    """Run (or resume) a training job; returns final metrics + history."""
    step_fn = step_mod.make_train_step(cfg, opt_cfg, loop.n_microbatches)
    batch_fn = make_batch_fn(data_cfg, cfg)

    start_step = 0
    state = None
    saver = None
    if loop.ckpt_dir:
        saver = store.AsyncSaver(loop.ckpt_dir, keep=loop.ckpt_keep)
        last = store.latest_step(loop.ckpt_dir)
        if last is not None:
            template = step_mod.abstract_state(cfg)
            shardings = (step_mod.state_shardings(mesh, cfg)
                         if mesh is not None else None)
            state, extra = store.restore(loop.ckpt_dir, last, template,
                                         shardings)
            start_step = int(extra.get("data_step", last))
            log(f"[resume] restored step {last}, data_step {start_step}")

    if state is None:
        state = step_mod.make_state(cfg, jax.random.key(loop.seed))
        if mesh is not None:
            state = jax.device_put(state, step_mod.state_shardings(mesh, cfg))

    if mesh is not None:
        example = batch_fn(start_step)
        jstep = step_mod.jit_train_step(step_fn, mesh, cfg, example)
    else:
        jstep = jax.jit(step_fn, donate_argnums=(0,))

    data = Prefetcher(batch_fn, start_step=start_step)
    history: list[dict] = []
    times: list[float] = []
    stragglers = 0
    next_step = start_step
    try:
        for step, batch in data:
            if step >= loop.steps:
                break
            next_step = step + 1
            t0 = time.perf_counter()
            state, metrics = jstep(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            times.append(dt)
            if len(times) >= 8:
                med = statistics.median(times[-64:])
                if dt > loop.watchdog_factor * med:
                    stragglers += 1
                    log(f"[watchdog] step {step}: {dt:.3f}s "
                        f">{loop.watchdog_factor:.1f}x median {med:.3f}s "
                        f"(straggler suspected)")
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m["step"] = step
            m["time_s"] = dt
            history.append(m)
            if m["skipped"]:
                log(f"[skip] step {step}: non-finite grads, update skipped")
            if step % loop.log_every == 0:
                log(f"step {step:5d} loss {m['loss']:.4f} "
                    f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} {dt:.3f}s")
            if saver and (step + 1) % loop.ckpt_every == 0:
                saver.save(step + 1, state,
                           extra={"data_step": next_step})
        if saver:
            saver.save(next_step, state, extra={"data_step": next_step})
            saver.wait()
    finally:
        data.close()

    return {
        "final_loss": history[-1]["loss"] if history else float("nan"),
        "history": history,
        "stragglers": stragglers,
        "steps_per_s": (len(times) / sum(times)) if times else 0.0,
        "state": state,
    }
