"""Sequential UCT search (paper Fig 1) — oracle + Table II baseline.

Single-worker, one-iteration-at-a-time. Selection reuses the deterministic
``select_one`` primitive; expansion and backup are written independently with
scalar updates so the batched dedup/scatter machinery in ``gscpm.py`` has a
simple implementation to be tested against (same RNG schedule ⇒ bit-identical
trees; see tests/test_gscpm.py). Game-agnostic like the rest of the search
stack (DESIGN.md §13): every game-specific step routes through the batched
``Game`` protocol (``repro.core.game``), and the scalar backup credits draws
(playout value 0) with 0.5 exactly as ``tree.backup_paths`` does.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core import game as game_mod
from repro.core.gscpm import propose_move, select_one
from repro.core.tree import NO_NODE, Tree, best_child, init_tree, root_value


def uct_iteration(tree: Tree, root_board: jnp.ndarray, game,
                  cp: float, key: jax.Array) -> Tree:
    """One select→expand→playout→backup iteration (scalar updates)."""
    k_noise, k_move, k_po = jax.random.split(key, 3)
    path, depth, leaf, board, n_empty = select_one(
        tree, root_board, game, cp, k_noise, noise_scale=0.0)
    mv = propose_move(tree, leaf, board, game, k_move)
    expanding = mv >= 0

    # ---- scalar expansion (the lock-protected region in the paper) ----
    new = jnp.where(expanding & (tree.n_nodes < tree.cap), tree.n_nodes, tree.cap)
    did = new < tree.cap
    slot = tree.n_children[leaf]
    tgt_leaf = jnp.where(did, leaf, tree.cap)
    tree = tree._replace(
        parent=tree.parent.at[new].set(jnp.where(did, leaf, NO_NODE)),
        move=tree.move.at[new].set(jnp.where(did, mv, NO_NODE)),
        to_move=tree.to_move.at[new].set(jnp.where(did, 3 - tree.to_move[leaf], 0)),
        children=tree.children.at[tgt_leaf, jnp.where(did, slot, 0)].set(
            jnp.where(did, new, tree.children[tgt_leaf, jnp.where(did, slot, 0)])),
        n_children=tree.n_children.at[tgt_leaf].add(did.astype(jnp.int32)),
        n_nodes=tree.n_nodes + did.astype(jnp.int32),
    )
    tree = tree._replace(
        parent=tree.parent.at[tree.cap].set(NO_NODE),
        move=tree.move.at[tree.cap].set(NO_NODE),
        n_children=tree.n_children.at[tree.cap].set(0),
    )
    path = path.at[depth + 1].set(jnp.where(did, new, tree.cap))

    # ---- playout (the game's batched evaluation stage at width 1: same
    # fill RNG, per-game winner dispatch through kernels.ops) ----
    mover = tree.to_move[leaf]
    b2 = jnp.where(expanding, game.place(board, jnp.maximum(mv, 0), mover),
                   board)
    nxt = jnp.where(expanding, 3 - mover, mover)
    w = game.playout_batch(b2[None], nxt[None], k_po[None])[0]

    # ---- scalar backup (the paper's atomic w_j / n_j walk) ----
    wv = w.astype(jnp.int32)

    def body(i, t):
        node = path[i]
        on = node != t.cap
        # 1 if the mover-into-node won the playout, 0.5 on a draw (value 0)
        credit = jnp.where(
            wv == 0, 0.5,
            ((3 - t.to_move[node]) == wv).astype(jnp.float32))
        tgt = jnp.where(on, node, t.cap)
        t = t._replace(visits=t.visits.at[tgt].add(jnp.where(on, 1.0, 0.0)),
                       wins=t.wins.at[tgt].add(jnp.where(on, credit, 0.0)))
        return t

    tree = jax.lax.fori_loop(0, path.shape[0], body, tree)
    return tree._replace(visits=tree.visits.at[tree.cap].set(0.0),
                         wins=tree.wins.at[tree.cap].set(0.0))


@functools.partial(jax.jit, static_argnames=("game", "cp", "n_iters"),
                   donate_argnums=(0,))
def _run(tree: Tree, root_board: jnp.ndarray, game, cp: float,
         task_key: jax.Array, n_iters: int) -> Tree:
    def body(i, t):
        return uct_iteration(t, root_board, game, cp,
                             jax.random.fold_in(task_key, i))
    return jax.lax.fori_loop(0, n_iters, body, tree)


def uct_search(board: jnp.ndarray, to_move: int, n_playouts: int, key: jax.Array,
               *, board_size: int = 11, cp: float = 1.0,
               tree_cap: int = 1 << 15, game: str = "hex") -> tuple[Tree, dict]:
    """Sequential UCTSearch(r, m) with the same RNG schedule as GSCPM's
    task 0 (``fold_in(fold_in(key, 0), i)``) for oracle comparisons."""
    g = game_mod.make_game(game, board_size)
    tree = init_tree(tree_cap, g.n_actions, to_move)
    task_key = jax.random.fold_in(key, 0)
    t0 = time.perf_counter()
    tree = _run(tree, board, g, cp, task_key, n_playouts)
    jax.block_until_ready(tree.visits)
    dt = time.perf_counter() - t0
    stats = {
        "time_s": dt,
        "playouts": n_playouts,
        "playouts_per_s": n_playouts / max(dt, 1e-9),
        "tree_nodes": int(tree.n_nodes),
        "root_value": float(root_value(tree)),
        "best_move": int(best_child(tree)),
    }
    return tree, stats
