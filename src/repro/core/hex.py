"""Pure-JAX Hex game environment.

The paper's benchmark application is a from-scratch 11x11 Hex engine. Board
cells are indexed row-major. Player 1 (BLACK) connects the TOP edge to the
BOTTOM edge; player 2 (WHITE) connects LEFT to RIGHT. A *move* is the flat
index of an empty cell.

Hardware adaptation (DESIGN.md §2/§9): the paper uses a disjoint-set
(union-find) structure for connectivity. Union-find is pointer-chasing and
hostile to vector hardware, so we use the vectorizable equivalent: a frontier
flood-fill to a fixpoint (`lax.while_loop` over neighbor dilation). Semantics
are identical (tested against a python union-find oracle in tests/test_hex.py).

The playout exploits the Hex theorem: a completely filled board has exactly
one winner, so a playout = randomly fill all empty cells with alternating
stones, then run ONE connectivity check for BLACK (if BLACK is not connected,
WHITE is). This mirrors the paper's "highly optimized" engine, which also
evaluates terminal positions only.

Everything is fixed-shape and `vmap`/`jit` friendly.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

EMPTY = jnp.int8(0)
BLACK = jnp.int8(1)  # connects top <-> bottom
WHITE = jnp.int8(2)  # connects left <-> right


class HexSpec(NamedTuple):
    """Static board description (python ints; safe to close over in jit)."""

    size: int

    @property
    def n_cells(self) -> int:
        return self.size * self.size


def neighbor_table(size: int) -> np.ndarray:
    """(n_cells, 6) int32 neighbor indices; `n_cells` acts as a pad sentinel.

    Hex adjacency on a rhombus: (r-1,c), (r-1,c+1), (r,c-1), (r,c+1),
    (r+1,c-1), (r+1,c).
    """
    n = size * size
    tbl = np.full((n, 6), n, dtype=np.int32)
    deltas = [(-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0)]
    for r in range(size):
        for c in range(size):
            i = r * size + c
            for k, (dr, dc) in enumerate(deltas):
                rr, cc = r + dr, c + dc
                if 0 <= rr < size and 0 <= cc < size:
                    tbl[i, k] = rr * size + cc
    return tbl


@functools.lru_cache(maxsize=None)
def _static_tables(size: int):
    """Neighbor table + edge masks as numpy constants (cached per size)."""
    n = size * size
    nbr = neighbor_table(size)
    top = np.zeros(n, dtype=bool)
    top[:size] = True
    bottom = np.zeros(n, dtype=bool)
    bottom[n - size :] = True
    left = np.zeros(n, dtype=bool)
    left[::size] = True
    right = np.zeros(n, dtype=bool)
    right[size - 1 :: size] = True
    return nbr, top, bottom, left, right


def empty_board(spec: HexSpec) -> jnp.ndarray:
    return jnp.zeros(spec.n_cells, dtype=jnp.int8)


def place(board: jnp.ndarray, move: jnp.ndarray, player: jnp.ndarray) -> jnp.ndarray:
    """Place `player`'s stone at flat index `move` (no legality check)."""
    return board.at[move].set(player.astype(jnp.int8))


def legal_mask(board: jnp.ndarray) -> jnp.ndarray:
    return board == EMPTY


def connected(board: jnp.ndarray, player: jnp.ndarray, spec: HexSpec) -> jnp.ndarray:
    """True iff `player` has a chain between their two edges.

    Frontier flood-fill to a fixpoint. The padded board (extra sentinel cell)
    keeps every gather in-bounds without branching.
    """
    nbr, top, bottom, left, right = _static_tables(spec.size)
    nbr = jnp.asarray(nbr)
    player = player.astype(jnp.int8)
    mine = board == player
    start = jnp.where(player == BLACK, jnp.asarray(top), jnp.asarray(left))
    goal = jnp.where(player == BLACK, jnp.asarray(bottom), jnp.asarray(right))

    reach0 = mine & start

    def body(state):
        reach, _ = state
        padded = jnp.concatenate([reach, jnp.zeros((1,), dtype=bool)])
        # cell joins the reach-set if any neighbor is reached and it is ours
        nbr_reached = padded[nbr].any(axis=1)
        new = reach | (nbr_reached & mine)
        return new, (new != reach).any()

    def cond(state):
        _, changed = state
        return changed

    reach, _ = jax.lax.while_loop(cond, body, (reach0, reach0.any()))
    return (reach & goal).any()


def winner(board: jnp.ndarray, spec: HexSpec) -> jnp.ndarray:
    """Winner of a FILLED board (Hex theorem: exactly one exists).

    One flood-fill: if BLACK is not connected, WHITE is. Returns int8 in
    {1, 2}. On a partially filled board, returns BLACK connectivity result
    (i.e. 1 if black connected else 2) — callers must only use this on
    terminal/filled boards; `connected` is the general check.
    """
    black_wins = connected(board, BLACK, spec)
    return jnp.where(black_wins, BLACK, WHITE)


def random_fill(
    board: jnp.ndarray, to_move: jnp.ndarray, key: jax.Array, spec: HexSpec
) -> jnp.ndarray:
    """Fill every empty cell with alternating stones in a random order.

    Equivalent to playing uniformly-random legal moves to the end of the game
    (the paper's playout policy): assign a random rank to each empty cell; the
    cell with the k-th smallest rank receives the stone of the player who is
    k-th to move.
    """
    empties = board == EMPTY
    n_empty_before = jnp.cumsum(empties) - empties  # rank among empties, stable
    noise = jax.random.uniform(key, board.shape)
    # random order of the empty cells: argsort noise restricted to empties
    order_key = jnp.where(empties, noise, jnp.inf)
    order = jnp.argsort(order_key)  # empties first in random order
    rank = jnp.zeros(board.shape, dtype=jnp.int32).at[order].set(
        jnp.arange(board.shape[0], dtype=jnp.int32)
    )
    to_move = to_move.astype(jnp.int32)
    other = jnp.int32(3) - to_move
    fill_color = jnp.where((rank % 2) == 0, to_move, other).astype(jnp.int8)
    del n_empty_before
    return jnp.where(empties, fill_color, board)


def playout(
    board: jnp.ndarray, to_move: jnp.ndarray, key: jax.Array, spec: HexSpec
) -> jnp.ndarray:
    """Run one random playout; return the winning player (int8 1|2)."""
    filled = random_fill(board, to_move, key, spec)
    return winner(filled, spec)


def playout_value(
    board: jnp.ndarray,
    to_move: jnp.ndarray,
    perspective: jnp.ndarray,
    key: jax.Array,
    spec: HexSpec,
) -> jnp.ndarray:
    """Playout result as 1.0 if `perspective` wins else 0.0."""
    w = playout(board, to_move, key, spec)
    return (w == perspective.astype(jnp.int8)).astype(jnp.float32)


def replay_moves(
    moves: jnp.ndarray, n_moves: jnp.ndarray, first_player: jnp.ndarray, spec: HexSpec
) -> jnp.ndarray:
    """Reconstruct a board from a move list (fixed-length, masked by n_moves)."""
    board = empty_board(spec)

    def body(i, b):
        player = jnp.where((i % 2) == 0, first_player, 3 - first_player)
        return jnp.where(i < n_moves, place(b, moves[i], player), b)

    return jax.lax.fori_loop(0, moves.shape[0], body, board)
