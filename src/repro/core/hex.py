"""Pure-JAX Hex game environment.

The paper's benchmark application is a from-scratch 11x11 Hex engine. Board
cells are indexed row-major. Player 1 (BLACK) connects the TOP edge to the
BOTTOM edge; player 2 (WHITE) connects LEFT to RIGHT. A *move* is the flat
index of an empty cell.

Hardware adaptation (DESIGN.md §2/§9/§12): the paper uses a disjoint-set
(union-find) structure for connectivity. Union-find is pointer-chasing and
hostile to vector hardware, so we use two vectorizable equivalents:

- a frontier flood-fill to a fixpoint (`lax.while_loop` over neighbor
  dilation) — the scalar oracle (`connected`/`winner`), O(board diameter)
  steps, tested against a python union-find oracle in tests/test_hex.py;
  its batched gather-free twin (`winner_flood_batch`) is the CPU/GPU
  winner dispatch;
- **batched pointer-doubling** connected-component labeling
  (`cc_labels_batch` / `connected_batch`) — the Shiloach–Vishkin/FastSV
  hook-and-jump scheme over a whole (W, n_cells) tile at once, converging
  in O(log n_cells) rounds with ONE convergence loop for all W lanes: the
  vector-hardware formulation the `kernels/hex_winner.py` Pallas kernel
  compiles on TPU (bit-exact vs the flood-fill oracle,
  tests/test_hex_batch.py).

`winner_batch`/`playout_batch` pick the right body per backend through
``kernels.ops.hex_winner`` (DESIGN.md §12).

The playout exploits the Hex theorem: a completely filled board has exactly
one winner, so a playout = randomly fill all empty cells with alternating
stones, then run ONE connectivity check for BLACK (if BLACK is not connected,
WHITE is). This mirrors the paper's "highly optimized" engine, which also
evaluates terminal positions only. ``playout_batch`` fuses
place→fill→winner for W lanes: one sort-free fill pass + one connectivity
solve per sync iteration instead of W interleaved while-loops.

Everything is fixed-shape and `vmap`/`jit` friendly.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import game as game_mod

EMPTY = jnp.int8(0)
BLACK = jnp.int8(1)  # connects top <-> bottom
WHITE = jnp.int8(2)  # connects left <-> right


class HexSpec(NamedTuple):
    """Static board description (python ints; safe to close over in jit)."""

    size: int

    @property
    def n_cells(self) -> int:
        return self.size * self.size


def neighbor_table(size: int) -> np.ndarray:
    """(n_cells, 6) int32 neighbor indices; `n_cells` acts as a pad sentinel.

    Hex adjacency on a rhombus: (r-1,c), (r-1,c+1), (r,c-1), (r,c+1),
    (r+1,c-1), (r+1,c).
    """
    n = size * size
    tbl = np.full((n, 6), n, dtype=np.int32)
    deltas = [(-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0)]
    for r in range(size):
        for c in range(size):
            i = r * size + c
            for k, (dr, dc) in enumerate(deltas):
                rr, cc = r + dr, c + dc
                if 0 <= rr < size and 0 <= cc < size:
                    tbl[i, k] = rr * size + cc
    return tbl


@functools.lru_cache(maxsize=None)
def _static_tables(size: int):
    """Neighbor table + edge masks as numpy constants (cached per size)."""
    n = size * size
    nbr = neighbor_table(size)
    top = np.zeros(n, dtype=bool)
    top[:size] = True
    bottom = np.zeros(n, dtype=bool)
    bottom[n - size :] = True
    left = np.zeros(n, dtype=bool)
    left[::size] = True
    right = np.zeros(n, dtype=bool)
    right[size - 1 :: size] = True
    return nbr, top, bottom, left, right


# the six hex neighbors as (row, col) offsets on the rhombus board
_DELTAS = ((-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0))


@functools.lru_cache(maxsize=None)
def _shift_tables(size: int):
    """Neighborhood as six STATIC flat shifts + per-cell validity masks.

    The gather-free formulation of hex adjacency: the neighbor of cell i in
    direction (dr, dc) sits at flat offset dr*size + dc, so a whole
    (W, n_cells) tile reads it with one roll — the same trick the Pallas
    kernel uses (`kernels/hex_winner.py`), which keeps the batched hot
    paths free of (W, n, 6) gathers.
    """
    n = size * size
    offs, masks = [], []
    for dr, dc in _DELTAS:
        m = np.zeros(n, dtype=bool)
        for r in range(size):
            cc_lo, cc_hi = max(0, -dc), min(size, size - dc)
            if 0 <= r + dr < size:
                m[r * size + cc_lo : r * size + cc_hi] = True
        offs.append(dr * size + dc)
        masks.append(m)
    return tuple(offs), np.stack(masks)


def empty_board(spec: HexSpec) -> jnp.ndarray:
    return jnp.zeros(spec.n_cells, dtype=jnp.int8)


def place(board: jnp.ndarray, move: jnp.ndarray, player: jnp.ndarray) -> jnp.ndarray:
    """Place `player`'s stone at flat index `move` (no legality check)."""
    return board.at[move].set(player.astype(jnp.int8))


def legal_mask(board: jnp.ndarray) -> jnp.ndarray:
    return board == EMPTY


def connected(board: jnp.ndarray, player: jnp.ndarray, spec: HexSpec) -> jnp.ndarray:
    """True iff `player` has a chain between their two edges.

    Frontier flood-fill to a fixpoint. The padded board (extra sentinel cell)
    keeps every gather in-bounds without branching.
    """
    nbr, top, bottom, left, right = _static_tables(spec.size)
    nbr = jnp.asarray(nbr)
    player = player.astype(jnp.int8)
    mine = board == player
    start = jnp.where(player == BLACK, jnp.asarray(top), jnp.asarray(left))
    goal = jnp.where(player == BLACK, jnp.asarray(bottom), jnp.asarray(right))

    reach0 = mine & start

    def body(state):
        reach, _ = state
        padded = jnp.concatenate([reach, jnp.zeros((1,), dtype=bool)])
        # cell joins the reach-set if any neighbor is reached and it is ours
        nbr_reached = padded[nbr].any(axis=1)
        new = reach | (nbr_reached & mine)
        return new, (new != reach).any()

    def cond(state):
        _, changed = state
        return changed

    reach, _ = jax.lax.while_loop(cond, body, (reach0, reach0.any()))
    return (reach & goal).any()


def winner(board: jnp.ndarray, spec: HexSpec) -> jnp.ndarray:
    """Winner of a FILLED board (Hex theorem: exactly one exists).

    One flood-fill: if BLACK is not connected, WHITE is. Returns int8 in
    {1, 2}.

    CONTRACT: the board must be completely filled. On a partially filled
    board this silently returns the BLACK connectivity result (1 if black
    is connected else 2) — which is NOT "who is winning"; WHITE may simply
    not have finished a chain yet. Callers that cannot prove the board is
    filled must use `connected` (the general check) or `winner_checked`
    (this function plus a debug assertion). The in-repo filled-board call
    sites (the playout phase) route through the fast batched path
    (`winner_batch` / `playout_batch`).
    """
    black_wins = connected(board, BLACK, spec)
    return jnp.where(black_wins, BLACK, WHITE)


def winner_checked(board: jnp.ndarray, spec: HexSpec) -> jnp.ndarray:
    """`winner` with a guard asserting the filled-board contract.

    Eager calls assert immediately; traced calls assert at runtime via a
    debug callback (so the check survives `jit`, at callback cost — use it
    at boundaries/debugging, not inside the search hot loop).
    """
    filled = (board != EMPTY).all()
    msg = ("winner_checked: board is not completely filled — winner() is "
           "only defined on terminal boards (use `connected` instead)")
    if isinstance(filled, jax.core.Tracer):
        def _assert_filled(ok):
            if not bool(ok):
                raise AssertionError(msg)
        jax.debug.callback(_assert_filled, filled)
    else:
        assert bool(filled), msg
    return winner(board, spec)


# ------------------------------------------------- batched (W, cells) ops ----
def doubling_rounds(n_cells: int) -> int:
    """Fixed pointer-doubling round budget: ceil(log2(n_cells)) + 2.

    The hook-and-jump round below (scatter-min hooking + pointer jump)
    converges well inside this bound — empirically <= 7 rounds on random
    AND adversarial snake/comb/solid boards up to 25x25, against caps of
    9-12 (tests/test_hex_batch.py pins convergence at exactly this budget,
    adversarial shapes included). The Pallas kernel runs exactly this many
    rounds with no runtime convergence check, so DO NOT tighten this
    budget without re-running those tests at the larger sizes; the jnp
    path early-exits at the batch fixpoint.
    """
    return int(math.ceil(math.log2(max(2, n_cells)))) + 2


def cc_labels_batch(stones: jnp.ndarray, spec: HexSpec,
                    rounds: int | None = None) -> jnp.ndarray:
    """Min-index connected-component labels by pointer doubling.

    stones: (W, n_cells) bool — per-lane membership mask (one player's
    stones). Returns (W, n_cells) int32 labels: cells of one connected
    component share the component's minimum cell index; non-member cells
    keep their own index.

    This is the PRAM pointer-jumping (Shiloach–Vishkin / FastSV) scheme the
    paper's §VPU discussion points at, batched over all W lanes. Each round:

      1. hook (gather):   m[i]    = min over same-stone closed nbhd of P
      2. hook (scatter):  P[P[i]] = min(P[P[i]], m[i])   — roots adopt the
                          best label their subtree has seen (the step that
                          makes convergence O(log n) instead of O(diameter))
      3. jump:            P[i]    = P[P[i]]              — pointer doubling

    Labels are monotone non-increasing ints, so the fixpoint exists and is
    the exact component-min labeling (hook fixpoint => locally constant =>
    min per component). ``rounds=None`` runs ONE `lax.while_loop` to the
    fixpoint of the whole batch (early exit, typical 4-6 rounds);
    ``rounds=k`` runs a fixed `fori_loop` (the kernel-shaped variant the
    fixed-step-count test exercises).
    """
    nbr, *_ = _static_tables(spec.size)
    nbr = jnp.asarray(nbr)                     # (n, 6), sentinel == n
    W, n = stones.shape
    widx = jnp.arange(W, dtype=jnp.int32)[:, None]
    P0 = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (W, n))

    # same-stone adjacency, fixed across rounds: (W, n, 6)
    stones_pad = jnp.concatenate(
        [stones, jnp.zeros((W, 1), dtype=bool)], axis=1)
    ok = stones_pad[:, nbr] & stones[:, :, None]

    def one_round(P):
        P_pad = jnp.concatenate(
            [P, jnp.full((W, 1), n, dtype=jnp.int32)], axis=1)
        nbr_lbl = jnp.where(ok, P_pad[:, nbr], n)            # (W, n, 6)
        m = jnp.minimum(P, nbr_lbl.min(axis=2))              # gather hook
        Q = P.at[widx, P].min(m)                             # scatter hook
        Q = jnp.minimum(Q, m)
        return jnp.take_along_axis(Q, Q, axis=1)             # pointer jump

    if rounds is None:
        def cond(st):
            return st[1]

        def body(st):
            P, _ = st
            Q = one_round(P)
            return Q, (Q != P).any()

        P, _ = jax.lax.while_loop(cond, body, (P0, jnp.bool_(True)))
        return P
    return jax.lax.fori_loop(0, rounds, lambda _, P: one_round(P), P0)


def connected_batch(boards: jnp.ndarray, player, spec: HexSpec) -> jnp.ndarray:
    """Batched `connected`: (W, n_cells) boards -> (W,) bool.

    ``player`` is a scalar or (W,) array. Exactly equal to
    ``jax.vmap(connected)`` (tests/test_hex_batch.py), but evaluates the
    whole batch with one O(log n) pointer-doubling solve instead of W
    coupled O(diameter) flood-fills.
    """
    _, top, bottom, left, right = _static_tables(spec.size)
    W, n = boards.shape
    player = jnp.broadcast_to(jnp.asarray(player, jnp.int8), (W,))
    stones = boards == player[:, None]
    labels = cc_labels_batch(stones, spec)
    is_black = (player == BLACK)[:, None]
    start = jnp.where(is_black, jnp.asarray(top)[None], jnp.asarray(left)[None])
    goal = jnp.where(is_black, jnp.asarray(bottom)[None],
                     jnp.asarray(right)[None])
    widx = jnp.arange(W, dtype=jnp.int32)[:, None]
    # mark the component roots touching the start edge, then test the goal
    src = stones & start
    mark = jnp.zeros((W, n + 1), dtype=bool).at[
        widx, jnp.where(src, labels, n)].set(True)[:, :n]
    reached = stones & goal & jnp.take_along_axis(mark, labels, axis=1)
    return reached.any(axis=1)


def winner_flood_batch(boards: jnp.ndarray, spec: HexSpec) -> jnp.ndarray:
    """Batched `winner` by gather-free frontier flood fill.

    Same filled-board contract as `winner`. One reach set for all W lanes,
    dilated with the six static shifts of ``_shift_tables`` per step and
    ONE convergence check for the whole batch — O(board diameter) steps of
    very cheap boolean work. On scalar-ish hardware (CPU) this beats the
    O(log n) pointer-doubling solve, whose per-round gathers cost more
    than a handful of extra boolean dilations; ``kernels.ops.hex_winner``
    therefore dispatches HERE off-TPU and to the pointer-doubling Pallas
    kernel on TPU (DESIGN.md §12; benchmarks/kernels_micro.py times both).
    """
    offs, masks = _shift_tables(spec.size)
    _, top, bottom, *_ = _static_tables(spec.size)
    masks = jnp.asarray(masks)
    mine = boards == BLACK
    reach0 = mine & jnp.asarray(top)[None, :]

    def body(st):
        reach, _ = st
        acc = reach
        for off, mk in zip(offs, masks):
            acc = acc | (jnp.roll(reach, -off, axis=1) & mk[None, :])
        new = acc & mine
        return new, (new != reach).any()

    reach, _ = jax.lax.while_loop(lambda st: st[1], body, (reach0, reach0.any()))
    black_wins = (reach & jnp.asarray(bottom)[None, :]).any(axis=1)
    return jnp.where(black_wins, BLACK, WHITE)


def winner_batch(boards: jnp.ndarray, spec: HexSpec) -> jnp.ndarray:
    """Batched `winner`: (W, n_cells) FILLED boards -> (W,) int8 in {1, 2}.

    Same contract as `winner` (boards must be filled). Dispatches through
    ``kernels.ops.hex_winner`` — the compiled Pallas pointer-doubling
    kernel on TPU, the jitted batched flood fill elsewhere (DESIGN.md §12).
    """
    from repro.kernels import ops  # function-level: kernels ref imports hex

    return ops.hex_winner(boards, spec.size)


def random_fill_batch(boards: jnp.ndarray, to_move, keys: jax.Array,
                      spec: HexSpec) -> jnp.ndarray:
    """Batched `random_fill`: fill W boards' empties in one fused pass.

    ``keys`` is a (W,) key batch; lane w consumes exactly the stream the
    scalar ``random_fill`` would with ``keys[w]`` (one uniform draw per
    cell), so this is bit-identical to ``jax.vmap(random_fill)``.

    The stone a cell receives depends only on the PARITY of its rank among
    the empty cells (random order), so instead of materializing the order
    with an argsort (XLA sorts are the slow path on every backend) the rank
    is counted directly: rank[i] = #{empty j : (noise_j, j) < (noise_i, i)}
    — one (W, n, n) boolean compare-and-count, with the same
    index-tie-break a stable argsort would apply. Bit-identical to the
    argsort formulation (ties included) and sort-free. The rank/color core
    is shared with every other registered game
    (``game.empty_fill_ranks`` / ``game.parity_fill_colors``).
    """
    empties = boards == EMPTY
    rank = game_mod.empty_fill_ranks(boards, keys)
    fill_color = game_mod.parity_fill_colors(rank, to_move)
    return jnp.where(empties, fill_color, boards)


def playout_batch(boards: jnp.ndarray, to_move, keys: jax.Array,
                  spec: HexSpec) -> jnp.ndarray:
    """W random playouts fused into one (W, cells) evaluation stage.

    fill (one sort-free parity pass) + winner (one batched connectivity
    solve via the per-backend ``ops.hex_winner`` dispatch). Bit-identical
    winners to ``jax.vmap(playout)`` under the same keys.
    """
    filled = random_fill_batch(boards, to_move, keys, spec)
    return winner_batch(filled, spec)


def random_fill(
    board: jnp.ndarray, to_move: jnp.ndarray, key: jax.Array, spec: HexSpec
) -> jnp.ndarray:
    """Fill every empty cell with alternating stones in a random order.

    Equivalent to playing uniformly-random legal moves to the end of the game
    (the paper's playout policy): assign a random rank to each empty cell; the
    cell with the k-th smallest rank receives the stone of the player who is
    k-th to move. The width-1 case of ``random_fill_batch`` (same noise
    stream, bit-identical board).
    """
    return random_fill_batch(board[None], to_move, key[None], spec)[0]


def playout(
    board: jnp.ndarray, to_move: jnp.ndarray, key: jax.Array, spec: HexSpec
) -> jnp.ndarray:
    """Run one random playout; return the winning player (int8 1|2).

    The width-1 case of ``playout_batch`` (same fill stream, same winner
    dispatch). The genuinely-scalar formulation — per-lane flood-fill
    winner — survives as ``HexGame.playout_scalar``, the oracle the
    bit-identity tests and the ``playout="scalar"`` search config use.
    """
    return playout_batch(board[None], to_move, key[None], spec)[0]


def playout_value(
    board: jnp.ndarray,
    to_move: jnp.ndarray,
    perspective: jnp.ndarray,
    key: jax.Array,
    spec: HexSpec,
) -> jnp.ndarray:
    """Playout result as 1.0 if `perspective` wins else 0.0 (width-1 over
    the batched path; Hex never draws, so the value is always 0 or 1)."""
    w = playout(board, to_move, key, spec)
    return (w == perspective.astype(jnp.int8)).astype(jnp.float32)


def replay_moves(
    moves: jnp.ndarray, n_moves: jnp.ndarray, first_player: jnp.ndarray, spec: HexSpec
) -> jnp.ndarray:
    """Reconstruct a board from a move list — the shared masked-scatter
    (``game.replay_moves``) at Hex's board length; see its contract."""
    return game_mod.replay_moves(moves, n_moves, first_player, spec.n_cells)


# ------------------------------------------------------- the Game protocol ----
class HexGame(NamedTuple):
    """Hex through the batched ``Game`` protocol (``core/game.py``).

    Every method delegates to the module functions above, so a search routed
    through the seam runs the exact computation (and RNG schedule) the
    pre-seam Hex-coupled search ran — bit-identical trees, pinned by
    tests/test_game_protocol.py. Hex never draws (Hex theorem), a game ends
    only when the board fills, and ``winner_batch`` keeps the per-backend
    pointer-doubling/flood dispatch of ``kernels.ops.hex_winner``
    (DESIGN.md §12).
    """

    size: int

    @property
    def n_cells(self) -> int:
        return self.size * self.size

    @property
    def n_actions(self) -> int:
        return self.n_cells  # a move is an empty cell

    @property
    def max_moves(self) -> int:
        return self.n_cells  # games end exactly when the board fills

    def init_board(self) -> jnp.ndarray:
        return empty_board(self)

    def place(self, board, move, player) -> jnp.ndarray:
        return place(board, move, player)

    def legal_mask(self, board) -> jnp.ndarray:
        return legal_mask(board)

    def terminal_batch(self, boards) -> jnp.ndarray:
        return ~(boards == EMPTY).any(axis=-1)

    def winner_batch(self, boards) -> jnp.ndarray:
        return winner_batch(boards, self)

    def playout_batch(self, boards, to_move, keys) -> jnp.ndarray:
        return playout_batch(boards, to_move, keys, self)

    def playout_scalar(self, board, to_move, key) -> jnp.ndarray:
        # the per-lane oracle: batched fill stream at width 1, but the
        # WINNER via the scalar O(diameter) flood fill — an independent
        # connectivity formulation to hold the fused path against
        filled = random_fill(board, to_move, key, self)
        return winner(filled, self)

    def replay_moves(self, moves, n_moves, first_player) -> jnp.ndarray:
        return replay_moves(moves, n_moves, first_player, self)

    def winner_probe(self, board) -> jnp.ndarray:
        # PARTIAL boards welcome: ``connected_batch`` only needs a chain to
        # exist, not a full board (unlike ``winner``'s full-board
        # contract). Hex never draws, so the outcomes are -1|1|2.
        c1 = connected_batch(board[None], BLACK, self)[0]
        c2 = connected_batch(board[None], WHITE, self)[0]
        return jnp.where(c1, jnp.int8(1),
                         jnp.where(c2, jnp.int8(2), jnp.int8(-1)))


game_mod.register_game("hex", HexGame)
