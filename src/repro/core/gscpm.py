"""GSCPM — Grain-Size Controlled Parallel MCTS (paper Fig 4), TPU-native.

The paper splits ``nPlayouts`` UCT iterations into ``nTasks`` tasks of grain
``m = nPlayouts / nTasks`` and schedules them on a thread pool against one
shared tree. Here (DESIGN.md §2):

- a *lane* (vmapped worker) plays the role of a hardware thread;
- a *task* is an ``m``-iteration chunk executed as a ``lax.fori_loop`` of
  batch-synchronous iterations;
- a *sync iteration* selects W leaves (in ``vl_rounds`` virtual-loss rounds)
  via a level-synchronous batched descent — all W lanes step down the tree
  in lockstep, one ``kernels.ops.uct_select`` (W, C) tile per level, the
  TPU twin of the paper's 512-bit VPU-vectorized UCT loop (DESIGN.md §11) —
  then dedup-expands the proposed (leaf, move) pairs with prefix-sum slot
  allocation (the paper's atomic child index), evaluates W playouts as ONE
  fused (W, cells) stage through the game's batched playout primitive
  (``game.playout_batch`` — for Hex one batched place, one sort-free
  parity fill, one connectivity solve via ``kernels.ops.hex_winner``,
  DESIGN.md §12) — and scatter-adds the results along the W paths (the
  paper's atomic w_j/n_j);
- per-task RNG streams come from ``fold_in`` (the paper's per-task MKL
  streams).

This module is game-agnostic (DESIGN.md §13): every game-specific
computation routes through the batched ``Game`` protocol
(``repro.core.game`` — ``GSCPMConfig.game`` names a registry entry), so the
same compiled machinery searches Hex, Gomoku, or any future registration.

Grain size trades scheduling overhead against parallel width exactly as in
the paper's Table I; the scheduling disciplines live in
``repro.core.scheduler``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import game as game_mod
from repro.core import scheduler as sched
from repro.core.game import EMPTY
from repro.core import uct as uct_mod
from repro.core.tree import (
    NO_NODE,
    Tree,
    add_vloss,
    backup_paths,
    best_child,
    child_stat_tile,
    init_tree,
    reset_vloss,
    root_value,
)
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class GSCPMConfig:
    """Knobs of the paper's experiment grid + the TPU-specific ones.

    Fields marked compare=False are excluded from the config's hash/eq:
    ``cp`` reaches the jitted chunks as a traced scalar operand, and
    ``n_playouts``/``n_tasks``/``scheduler`` only shape the host-side task
    schedule (the grain arrives as the traced ``m``), so configs differing
    only in those knobs share one compiled program ("knobs traced ⇒ zero
    recompiles" — the fig7/ablation sweeps pay one compile total). Traced
    code must never read a compare=False field — it would silently bake the
    first value seen into the cached program.
    """

    game: str = "hex"               # Game-registry name (core/game.py)
    board_size: int = 11
    # paper: 1,048,576 playouts (scaled for CPU harness)
    n_playouts: int = dataclasses.field(default=4096, compare=False)
    # the grain dial: m = n_playouts / n_tasks
    n_tasks: int = dataclasses.field(default=64, compare=False)
    n_workers: int = 16             # parallel lanes (hardware-thread analogue)
    vl_rounds: int = 1              # virtual-loss rounds per sync iteration
    virtual_loss: float = 1.0
    cp: float = dataclasses.field(default=1.0, compare=False)  # paper: Cp = 1.0
    select_noise: float = 1e-3      # per-lane UCT tie-break jitter
    tree_cap: int = 1 << 15
    # fifo | rebalance | one_per_core | sequential
    scheduler: str = dataclasses.field(default="fifo", compare=False)
    descent: str = "batched"        # batched (level-synchronous) | scalar (oracle)
    playout: str = "batched"        # batched (fused (W, cells)) | scalar (oracle)
    # device-plane observability (DESIGN.md §15): thread a SearchMetrics
    # accumulator through the compiled chunks. HASHED static flag: each
    # game class compiles exactly two programs (metrics on / off), and the
    # search results are bit-identical either way (tests/test_obsv.py).
    metrics: bool = False
    # root-parallel ensemble width when the config names a FOREST tenant
    # class (repro.serve.games): the forest's leading axis is a program
    # shape, so it is HASHED — each (game, E) pair is its own class with
    # its own compiled quantum, and the default E=1 keeps every existing
    # single-tree class key unchanged.
    n_trees: int = 1

    @property
    def game_obj(self):
        """The resolved Game instance (hashable; safe to close over in jit)."""
        return game_mod.make_game(self.game, self.board_size)

    @property
    def grain(self) -> int:
        return max(1, self.n_playouts // max(1, self.n_tasks))


# ------------------------------------------------------------- selection ----
def select_one(tree: Tree, root_board: jnp.ndarray, game, cp: float,
               noise_key: jax.Array, noise_scale: float):
    """Descend from the root to a not-fully-expanded (or terminal) node.

    Returns (path, depth, leaf, board_at_leaf, n_empty_at_leaf). ``path`` is
    (max_depth,) int32 padded with the tree's PAD row index. A node counts
    as fully expanded only when its children cover every EMPTY cell; games
    that end mid-board (e.g. a Gomoku five) never get there — their
    terminal nodes keep zero children because ``game.legal_mask`` is empty,
    so the descent stops at them without a per-level terminal test.
    """
    max_depth = game.max_moves + 1
    cap = tree.cap
    C = tree.max_children

    path0 = jnp.full((max_depth,), cap, dtype=jnp.int32).at[0].set(0)
    n_empty0 = (root_board == EMPTY).sum().astype(jnp.int32)

    def cond(st):
        node, board, depth, path, n_empty, done = st
        return ~done

    def body(st):
        node, board, depth, path, n_empty, _ = st
        n_kids = tree.n_children[node]
        terminal = n_empty == 0
        fully = (n_kids == n_empty) & ~terminal
        # score children
        slots = tree.children[node]  # (C,)
        valid = jnp.arange(C, dtype=jnp.int32) < n_kids
        safe = jnp.where(valid, slots, cap)
        scores = uct_mod.uct_scores(
            tree.wins[safe], tree.visits[safe], tree.vloss[safe],
            tree.visits[node] + tree.vloss[node], cp, valid)
        noise = None
        if noise_scale > 0.0:
            noise = noise_scale * jax.random.uniform(
                jax.random.fold_in(noise_key, depth), (C,))
        pick = uct_mod.select_child(scores, noise)
        child = safe[pick]
        mv = tree.move[child]
        new_board = game.place(board, mv, tree.to_move[node])
        nxt = (child, new_board, depth + 1,
               path.at[depth + 1].set(child), n_empty - 1, False)
        stay = (node, board, depth, path, n_empty, True)
        return jax.tree.map(
            lambda a, b: jnp.where(fully & (depth < max_depth - 2), a, b), nxt, stay)

    node, board, depth, path, n_empty, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), root_board, jnp.int32(0), path0, n_empty0, False))
    return path, depth, node, board, n_empty


def level_noise(noise_keys: jax.Array, depths: jnp.ndarray, n_slots: int,
                scale: float) -> jnp.ndarray:
    """(W, C) tie-break noise for one descent level.

    Lane w draws from ``fold_in(noise_keys[w], depths[w])`` — exactly the
    stream the scalar per-lane oracle consumes at that depth, which is what
    makes the lockstep descent bit-identical to it.
    """
    return scale * jax.vmap(
        lambda k, d: jax.random.uniform(jax.random.fold_in(k, d), (n_slots,))
    )(noise_keys, depths)


def advance_paths(paths: jnp.ndarray, depths: jnp.ndarray, child: jnp.ndarray,
                  step: jnp.ndarray) -> jnp.ndarray:
    """Write each stepping lane's chosen child at path level depth + 1."""
    D = paths.shape[1]
    return jnp.where(
        (jnp.arange(D)[None, :] == (depths + 1)[:, None]) & step[:, None],
        child[:, None], paths)


def select_batch(tree: Tree, root_board: jnp.ndarray, game, cp,
                 noise_keys: jax.Array, noise_scale: float):
    """Level-synchronous batched descent: all W lanes in lockstep.

    Each level gathers the lanes' child stats into one (W, C) tile
    (``tree.child_stat_tile``) and picks all W children with a single
    ``kernels.ops.uct_select`` call — the Pallas VPU kernel on TPU, the jnp
    reference elsewhere (DESIGN.md §11). Lanes that reached a
    not-fully-expanded or terminal node (or the depth cap) are masked out of
    the tile and held in place. Bit-identical to ``jax.vmap(select_one)``
    under the same RNG schedule (the per-lane oracle; pinned in
    tests/test_batched_descent.py).

    Returns (paths, depths, leaves, boards, n_empty), each batched over W.
    """
    max_depth = game.max_moves + 1
    cap = tree.cap
    C = tree.max_children
    W = noise_keys.shape[0]

    nodes0 = jnp.zeros((W,), jnp.int32)
    boards0 = jnp.tile(root_board[None, :], (W, 1))
    depths0 = jnp.zeros((W,), jnp.int32)
    paths0 = jnp.full((W, max_depth), cap, dtype=jnp.int32).at[:, 0].set(0)
    n_empty0 = jnp.broadcast_to(
        (root_board == EMPTY).sum().astype(jnp.int32), (W,))
    done0 = jnp.zeros((W,), bool)

    def cond(st):
        return ~st[-1].all()

    def body(st):
        nodes, boards, depths, paths, n_empty, done = st
        n_kids = tree.n_children[nodes]
        terminal = n_empty == 0
        fully = (n_kids == n_empty) & ~terminal
        safe, valid, wins, visits, vloss, ptot = child_stat_tile(tree, nodes)
        noise = (level_noise(noise_keys, depths, C, noise_scale)
                 if noise_scale > 0.0 else None)
        picks = ops.uct_select(wins, visits, vloss, ptot, valid, cp,
                               noise=noise, lane_mask=~done)
        child = safe[jnp.arange(W), picks]
        mv = tree.move[child]
        new_boards = jax.vmap(game.place)(boards, mv, tree.to_move[nodes])
        step = fully & (depths < max_depth - 2) & ~done
        nodes = jnp.where(step, child, nodes)
        boards = jnp.where(step[:, None], new_boards, boards)
        paths = advance_paths(paths, depths, child, step)
        depths = jnp.where(step, depths + 1, depths)
        n_empty = jnp.where(step, n_empty - 1, n_empty)
        return nodes, boards, depths, paths, n_empty, done | ~step

    nodes, boards, depths, paths, n_empty, _ = jax.lax.while_loop(
        cond, body, (nodes0, boards0, depths0, paths0, n_empty0, done0))
    return paths, depths, nodes, boards, n_empty


def propose_move(tree: Tree, leaf: jnp.ndarray, board: jnp.ndarray,
                 game, key: jax.Array) -> jnp.ndarray:
    """Sample a uniformly-random untried move at `leaf` (-1 if none).

    "Random unexplored child" of the paper's expansion step. -1 (no
    expansion) also covers TERMINAL leaves: ``game.legal_mask`` is all-False
    there, so won/drawn positions are evaluated in place, never grown.
    """
    n_cells = game.n_cells
    C = tree.max_children
    cap = tree.cap
    legal = game.legal_mask(board)
    slots = tree.children[leaf]
    valid = jnp.arange(C, dtype=jnp.int32) < tree.n_children[leaf]
    tried_moves = jnp.where(valid, tree.move[jnp.where(valid, slots, cap)], n_cells)
    tried = jnp.zeros((n_cells + 1,), dtype=bool).at[tried_moves].set(True)[:n_cells]
    untried = legal & ~tried
    # argmax of iid uniforms over the untried set IS a uniform choice — the
    # gumbel transform (two transcendental maps) buys nothing here
    u = jax.random.uniform(key, (n_cells,))
    mv = jnp.argmax(jnp.where(untried, u, -1.0)).astype(jnp.int32)
    return jnp.where(untried.any(), mv, jnp.int32(NO_NODE))


# -------------------------------------------------------- dedup expansion ----
def expand_batch(tree: Tree, leaves: jnp.ndarray, moves: jnp.ndarray,
                 active: jnp.ndarray):
    """Batch-insert unique (leaf, move) proposals; return per-worker node ids.

    The scatter/prefix-sum replacement for the paper's expansion-phase lock +
    atomic child index: proposals are sorted by (leaf, move) key, duplicates
    collapse onto their first occurrence, slots are rank-allocated.
    """
    W = leaves.shape[0]
    cap = tree.cap
    INVALID = jnp.int32(np.int32(2**30))

    valid = (moves >= 0) & active
    leaf_k = jnp.where(valid, leaves, INVALID)
    move_k = jnp.where(valid, moves, INVALID)
    idx = jnp.arange(W, dtype=jnp.int32)
    # lexicographic (leaf, move) sort — no key packing, so `move` may be any
    # int32 (Hex cell index or LM token id alike)
    leaf_s, move_s, order = jax.lax.sort(
        (leaf_k, move_k, idx), num_keys=2, is_stable=True)
    valid_s = leaf_s < INVALID
    first = jnp.concatenate(
        [jnp.ones((1,), bool),
         (leaf_s[1:] != leaf_s[:-1]) | (move_s[1:] != move_s[:-1])]) & valid_s
    uniq_rank = jnp.cumsum(first.astype(jnp.int32)) - 1  # dup shares first's rank
    can = (tree.n_nodes + uniq_rank < cap) & valid_s
    alloc = first & can
    new_id_s = jnp.where(can, tree.n_nodes + uniq_rank, cap)

    leaf_s = jnp.where(valid_s, leaf_s, cap)
    move_s = jnp.where(valid_s, move_s, NO_NODE)

    # child-slot = existing n_children[leaf] + rank of this unique within its
    # leaf group (uniques of one leaf are contiguous in sorted order)
    leaf_prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), leaf_s[:-1]])
    group_start = leaf_s != leaf_prev
    start_rank = jax.lax.cummax(jnp.where(group_start, uniq_rank, -1))
    within = uniq_rank - start_rank
    slot = jnp.clip(tree.n_children[leaf_s] + within, 0, tree.max_children - 1)

    tgt = jnp.where(alloc, new_id_s, cap)
    src_leaf = jnp.where(alloc, leaf_s, cap)
    parent = tree.parent.at[tgt].set(jnp.where(alloc, leaf_s, NO_NODE))
    move_arr = tree.move.at[tgt].set(jnp.where(alloc, move_s, NO_NODE))
    to_move = tree.to_move.at[tgt].set(
        jnp.where(alloc, 3 - tree.to_move[leaf_s], 0))
    children = tree.children.at[src_leaf, jnp.where(alloc, slot, 0)].set(
        jnp.where(alloc, new_id_s, tree.children[src_leaf, jnp.where(alloc, slot, 0)]))
    n_children = tree.n_children.at[src_leaf].add(alloc.astype(jnp.int32))
    n_new = alloc.sum().astype(jnp.int32)

    # hygiene: pad row never owns state
    parent = parent.at[cap].set(NO_NODE)
    move_arr = move_arr.at[cap].set(NO_NODE)
    n_children = n_children.at[cap].set(0)

    tree = tree._replace(parent=parent, move=move_arr, to_move=to_move,
                         children=children, n_children=n_children,
                         n_nodes=tree.n_nodes + n_new)
    # map back to worker order: duplicates get their first occurrence's id
    per_sorted = jnp.where(valid_s & can, new_id_s, cap)
    new_ids = jnp.zeros((W,), jnp.int32).at[order].set(per_sorted)
    return tree, new_ids


# ---------------------------------------------------------- sync iteration ----
def sync_iteration(tree: Tree, root_board: jnp.ndarray, cfg: GSCPMConfig,
                   cp, iter_keys: jnp.ndarray, active: jnp.ndarray,
                   metrics=None):
    """One batched GSCPM iteration of width W = cfg.n_workers.

    ``cp`` is the traced exploration constant (never read from cfg here —
    see GSCPMConfig). Selection runs the level-synchronous batched descent
    by default; ``cfg.descent == "scalar"`` keeps the per-lane while-loop
    oracle (same RNG schedule, bit-identical trees). Likewise the playout
    phase defaults to the fused (W, cells) ``game.playout_batch`` and
    ``cfg.playout == "scalar"`` keeps the per-lane ``game.playout_scalar``
    oracle (bit-identical values under the same RNG schedule).

    ``metrics`` (a ``repro.obsv.SearchMetrics`` accumulator, or None)
    selects the return shape: with an accumulator the call returns
    ``(tree, metrics)``; the metric updates are pure extra reductions over
    values this function computes anyway — no RNG consumed, nothing fed
    back — so the produced tree is bit-identical either way.
    """
    game = cfg.game_obj
    W = cfg.n_workers
    R = max(1, min(cfg.vl_rounds, W))
    while W % R != 0:  # static fixup; R is a python int
        R -= 1
    Wr = W // R

    def select_group(tree_r, keys_g):
        # identical RNG schedule on both paths: per-lane (noise, move,
        # playout) keys come from one split of the lane's iteration key
        ks = jax.vmap(lambda k: jax.random.split(k, 3))(keys_g)
        k_noise, k_move, k_po = ks[:, 0], ks[:, 1], ks[:, 2]
        if cfg.descent == "scalar":
            def one(kn, km):
                path, depth, leaf, board, n_empty = select_one(
                    tree_r, root_board, game, cp, kn, cfg.select_noise)
                mv = propose_move(tree_r, leaf, board, game, km)
                return path, depth, leaf, board, mv
            out = jax.vmap(one)(k_noise, k_move)
        else:
            paths, depths, leaves, boards, _ = select_batch(
                tree_r, root_board, game, cp, k_noise, cfg.select_noise)
            mvs = jax.vmap(
                lambda l, b, k: propose_move(tree_r, l, b, game, k)
            )(leaves, boards, k_move)
            out = (paths, depths, leaves, boards, mvs)
        return (*out, k_po)

    keys_r = iter_keys.reshape(R, Wr, *iter_keys.shape[1:])
    active_r = active.reshape(R, Wr)

    # virtual loss only influences the NEXT selection round of this
    # iteration; with a single round (R == 1) the add+reset pair is dead
    # weight — skipping it is bit-identical (no RNG is consumed)
    def round_body(tr, inp):
        keys_g, act_g = inp
        out = select_group(tr, keys_g)
        paths = out[0]
        if R > 1:
            tr = add_vloss(tr, paths, act_g.astype(jnp.float32),
                           cfg.virtual_loss)
        return tr, out

    tree, outs = jax.lax.scan(round_body, tree, (keys_r, active_r))
    if R > 1:
        tree = reset_vloss(tree)

    paths = outs[0].reshape(W, -1)
    depths = outs[1].reshape(W)
    leaves = outs[2].reshape(W)
    boards = outs[3].reshape(W, -1)
    moves = outs[4].reshape(W)
    po_keys = outs[5].reshape(W, *outs[5].shape[2:])

    n_nodes_before = tree.n_nodes
    tree, new_ids = expand_batch(tree, leaves, moves, active)

    expanded = new_ids < tree.cap
    # the new node joins the backup path
    paths = jnp.where(
        jnp.arange(paths.shape[1])[None, :] == (depths + 1)[:, None],
        jnp.where(expanded[:, None], new_ids[:, None], tree.cap),
        paths)

    # place each lane's proposed move (if any) — game-agnostic given the
    # shared board convention; lanes that proposed nothing evaluate the
    # leaf position itself (terminal leaves included)
    movers = tree.to_move[leaves]
    do = moves >= 0
    placed = jax.vmap(game.place)(boards, jnp.maximum(moves, 0), movers)
    b2 = jnp.where(do[:, None], placed, boards)
    nxt = jnp.where(do, 3 - movers, movers)
    if cfg.playout == "scalar":
        # per-lane oracle: W interleaved scalar playouts under vmap
        winners = jax.vmap(game.playout_scalar)(b2, nxt, po_keys)
    else:
        # fused leaf evaluation: ONE batched (W, cells) playout stage for
        # all W lanes (bit-identical values to the oracle above —
        # tests/test_game_protocol.py)
        winners = game.playout_batch(b2, nxt, po_keys)
    tree = backup_paths(tree, paths, winners, active.astype(jnp.float32))
    if metrics is None:
        return tree
    from repro.obsv.search_metrics import accumulate_iteration

    metrics = accumulate_iteration(
        metrics, depths_grouped=outs[1], active=active, leaves=leaves,
        moves=moves, eval_boards=b2, n_nodes_before=n_nodes_before,
        n_nodes_after=tree.n_nodes)
    return tree, metrics


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0,))
def run_chunk(tree: Tree, root_board: jnp.ndarray, cfg: GSCPMConfig,
              task_keys: jnp.ndarray, active: jnp.ndarray,
              m: jnp.ndarray, cp, metrics=None):
    """Run `m` sync iterations (one task-grain per lane) — jitted once per
    cfg; ``m`` and ``cp`` are traced, so grain/Cp sweeps never retrace.

    With ``cfg.metrics`` a ``SearchMetrics`` accumulator must ride along
    and the chunk returns ``(tree, metrics)`` — the flag is hashed, so a
    game class owns exactly TWO compiled programs: one per metrics arm.
    """
    if cfg.metrics != (metrics is not None):     # trace-time consistency
        raise ValueError(
            f"cfg.metrics={cfg.metrics} but metrics accumulator "
            f"{'missing' if metrics is None else 'provided'} — pass "
            "repro.obsv.init_search_metrics() iff the flag is set")

    def body(i, carry):
        tr, mx = carry
        iter_keys = jax.vmap(lambda tk: jax.random.fold_in(tk, i))(task_keys)
        if cfg.metrics:
            tr, mx = sync_iteration(tr, root_board, cfg, cp, iter_keys,
                                    active, mx)
        else:
            tr = sync_iteration(tr, root_board, cfg, cp, iter_keys, active)
        return tr, mx

    tree, metrics = jax.lax.fori_loop(0, m, body, (tree, metrics))
    return (tree, metrics) if cfg.metrics else tree


# ------------------------------------------------------------------ driver ----
@jax.jit
def fold_task_keys(key: jax.Array, task_ids: jnp.ndarray) -> jax.Array:
    """Per-task RNG streams (jitted: per-round key building is dispatch-only,
    not a re-traced eager vmap)."""
    return jax.vmap(lambda t: jax.random.fold_in(key, t))(task_ids)


def run_schedule_round(tree: Tree, board: jnp.ndarray, cfg: GSCPMConfig,
                       key: jax.Array, rnd: sched.Round, cp, metrics=None):
    """Advance one schedule ``Round``: the atomic dispatch unit of a search.

    Both the uninterrupted driver (``gscpm_search``) and the TPFIFO
    game-serving engine (``repro.serve.games``) run searches as a sequence
    of these calls — a round's RNG streams depend only on (``key``,
    ``rnd.task_ids``), never on wall-clock interleaving, so a search served
    in grain-sized quanta with preemptions in between is BIT-IDENTICAL to
    the same round sequence run back to back (pinned in
    tests/test_serve_games.py). With ``cfg.metrics`` the accumulator rides
    along and the round returns ``(tree, metrics)``.
    """
    task_keys = fold_task_keys(key, jnp.asarray(rnd.task_ids, dtype=jnp.int32))
    args = (tree, board, cfg, task_keys, jnp.asarray(rnd.active),
            jnp.asarray(rnd.m, dtype=jnp.int32), cp)
    if cfg.metrics:
        return run_chunk(*args, metrics)
    return run_chunk(*args)


def warm_tree_check(tree: Tree, to_move: int, cfg: GSCPMConfig) -> None:
    """Eagerly validate a warm-start tree against the config (DESIGN.md §16).

    A warm tree with the wrong capacity or children width would not crash —
    it would silently compile a SECOND program for the game class, defeating
    the zero-recompile serving discipline — so shape mismatches fail loudly
    here. The side-to-move must also match: a re-rooted tree already knows
    whose turn it is, and searching it for the other player would corrupt
    the retained statistics' meaning.
    """
    if tree.cap != cfg.tree_cap:
        raise ValueError(
            f"warm tree cap {tree.cap} != cfg.tree_cap {cfg.tree_cap}; "
            "re-root with new_cap=cfg.tree_cap to match the serving class")
    n_actions = cfg.game_obj.n_actions
    if tree.max_children != n_actions:
        raise ValueError(
            f"warm tree max_children {tree.max_children} != game n_actions "
            f"{n_actions} — tree built for a different game class")
    tm = int(tree.to_move[..., 0].reshape(-1)[0])
    if tm != to_move:
        raise ValueError(
            f"warm tree root to_move {tm} != requested to_move {to_move}")


def gscpm_search(board: jnp.ndarray, to_move: int, cfg: GSCPMConfig,
                 key: jax.Array, *, tree: Tree | None = None,
                 tracer=None) -> tuple[Tree, dict[str, Any]]:
    """Full GSCPM search (paper Fig 4): schedule tasks, return tree + stats.

    ``tree`` warm-starts the search from an existing tree — typically the
    output of ``reroot_tree`` after a move was played (DESIGN.md §16).
    The schedule is exactly ``cfg``'s either way, so a warm search from
    tree T is bit-identical to a cold search whose ``init_tree`` was
    hand-replaced by T: warm start changes the starting evidence, never
    the program. The caller keeps ownership semantics in mind: the passed
    tree's buffers are DONATED to the first chunk (``run_chunk``), so the
    input object must not be reused afterwards.

    ``cfg.metrics`` adds a device-plane ``SearchMetrics`` summary under
    ``stats["metrics"]`` (one host readback at the end of the search).
    ``tracer`` (a ``repro.obsv.TraceRecorder``) records one ``gscpm_round``
    span per schedule round, annotated with the round's work so
    ``obsv.profile`` can fit the measured dispatch burden; tracing blocks
    on the device after every round to attribute device time to its round
    — a profiling mode, not the fastest way to run a search.
    """
    reused_nodes = 0
    reused_visits = 0.0
    if tree is None:
        tree = init_tree(cfg.tree_cap, cfg.game_obj.n_actions, to_move)
    else:
        warm_tree_check(tree, to_move, cfg)
        reused_nodes = int(tree.n_nodes) - 1   # cold trees also own the root
        reused_visits = float(tree.visits[0])
    metrics = None
    if cfg.metrics:
        from repro.obsv.search_metrics import init_search_metrics
        metrics = init_search_metrics(tree_nodes_reused=reused_nodes)
    schedule = sched.make_schedule(
        cfg.n_playouts, cfg.n_tasks, cfg.n_workers, cfg.scheduler)

    cp = jnp.asarray(cfg.cp, jnp.float32)
    t0 = time.perf_counter()
    playouts = 0
    masked_lane_iters = 0
    for rnd in schedule:
        span = (tracer.span("gscpm_round", {
            "rounds": 1, "iterations": int(rnd.m),
            "lane_iterations": int(rnd.active.sum()) * rnd.m,
            "tasks": int(rnd.active.sum()), "workers": cfg.n_workers,
            "game": cfg.game}) if tracer else contextlib.nullcontext())
        with span:
            out = run_schedule_round(tree, board, cfg, key, rnd, cp, metrics)
            tree, metrics = out if cfg.metrics else (out, metrics)
            if tracer:
                jax.block_until_ready(tree.visits)
        if tracer:
            tracer.poll_compiles()
        playouts += int(rnd.active.sum()) * rnd.m
        masked_lane_iters += int((~rnd.active).sum()) * rnd.m
    jax.block_until_ready(tree.visits)
    dt = time.perf_counter() - t0

    stats = {
        "time_s": dt,
        "playouts": playouts,
        "playouts_per_s": playouts / max(dt, 1e-9),
        "rounds": len(schedule),
        "grain": cfg.grain,
        "masked_lane_fraction": masked_lane_iters
        / max(1, playouts + masked_lane_iters),
        "tree_nodes": int(tree.n_nodes),
        "root_value": float(root_value(tree)),
        "best_move": int(best_child(tree)),
    }
    if reused_nodes or reused_visits:
        stats["reused_nodes"] = reused_nodes
        stats["reused_visits"] = reused_visits
    if cfg.metrics:
        from repro.obsv.search_metrics import summarize_metrics
        stats["metrics"] = summarize_metrics(metrics)
    return tree, stats

