"""Task→lane scheduling disciplines (the paper's experimental axis).

The paper compares work-stealing runtimes (Cilk Plus, TBB) against a FIFO
work-sharing thread pool (TPFIFO) and finds FIFO equal-or-better for MCTS's
irregular tasks. On SPMD hardware there is no dynamic stealing — the
scheduling freedom left is how task grains map onto lanes between sync steps
(DESIGN.md §2). We implement:

- ``fifo``          static FIFO work-sharing: round r gives lane w task
                    ``r*W + w``; the last round has masked (idle) lanes when
                    W ∤ nTasks — the measurable load-imbalance cost.
- ``rebalance``     the stealing analogue: playouts are fungible, so remaining
                    work is re-split across ALL lanes every round (no lane
                    idles until the final sub-width round).
- ``one_per_core``  traditional tree parallelism (paper's baseline):
                    nTasks = nLanes, one monolithic task per lane.
- ``sequential``    W = 1 (paper Table II baseline).

A schedule is a list of Rounds; the GSCPM driver runs one jitted chunk per
round. Host-side dispatch per round is the spawn-overhead analogue: many tiny
rounds (fine grain) pay it often, exactly the paper's Table I lower row.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class Round:
    m: int                 # iterations every active lane runs this round
    task_ids: np.ndarray   # (W,) int32 RNG-stream ids per lane
    active: np.ndarray     # (W,) bool


def make_schedule(n_playouts: int, n_tasks: int, n_workers: int,
                  policy: str) -> list[Round]:
    W = n_workers
    if policy == "sequential":
        W = 1
        n_tasks = 1
    if policy == "one_per_core":
        n_tasks = W
    n_tasks = max(1, min(n_tasks, n_playouts))
    m = max(1, n_playouts // n_tasks)

    if policy in ("fifo", "one_per_core", "sequential"):
        rounds = []
        n_rounds = math.ceil(n_tasks / W)
        for r in range(n_rounds):
            ids = r * W + np.arange(W, dtype=np.int32)
            active = ids < n_tasks
            rounds.append(Round(m=m, task_ids=ids, active=active))
        return rounds

    if policy == "rebalance":
        total = n_tasks * m  # same playout budget as fifo
        rounds = []
        rem = total
        r = 0
        while rem >= W:
            mr = max(1, min(m, rem // W))
            ids = r * W + np.arange(W, dtype=np.int32)
            rounds.append(Round(m=mr, task_ids=ids,
                                active=np.ones(W, dtype=bool)))
            rem -= mr * W
            r += 1
        if rem > 0:
            ids = r * W + np.arange(W, dtype=np.int32)
            rounds.append(Round(m=1, task_ids=ids,
                                active=np.arange(W) < rem))
        return rounds

    raise ValueError(f"unknown scheduler policy: {policy!r}")


def quantum_plan(n_steps: int, grain: int, policy: str) -> list[int]:
    """One request's work split into grain-sized quanta (TPFIFO serving).

    The serving layer (`repro.serve.tpfifo`) treats each admitted request as
    the paper's "logical task of fungible iterations": ``n_steps`` micro-steps
    (decode ticks or MCTS commit rounds) dispatched as a sequence of quanta.
    The split reuses ``make_schedule`` with a single lane — the request itself
    is the worker — so the serving disciplines are literally the paper's:

    - ``fifo`` / ``rebalance``  uniform quanta of ~``grain`` steps; the
                                request yields the device at every boundary.
    - ``one_per_core``          one monolithic quantum (run-to-completion):
                                the paper's one-task-per-lane baseline.
    - ``sequential``            alias of ``one_per_core`` at W=1.

    ``make_schedule`` floors its budget to ``n_tasks * m``; a request is not
    fungible, so the last quantum is topped up to cover ``n_steps`` exactly.
    """
    n_steps = max(1, n_steps)
    n_tasks = max(1, math.ceil(n_steps / max(1, grain)))
    rounds = make_schedule(n_steps, n_tasks, 1, policy)
    plan = [r.m for r in rounds if bool(r.active.any())]
    short = n_steps - sum(plan)
    if short > 0:
        plan[-1] += short
    return plan


def schedule_stats(schedule: list[Round]) -> dict:
    """Lane-utilization accounting for a schedule (used by benchmarks)."""
    lane_iters = sum(int(r.active.sum()) * r.m for r in schedule)
    total_iters = sum(r.active.shape[0] * r.m for r in schedule)
    return {
        "rounds": len(schedule),
        "lane_iterations": lane_iters,
        "masked_lane_iterations": total_iters - lane_iters,
        "utilization": lane_iters / max(1, total_iters),
    }
