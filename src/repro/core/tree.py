"""Array-of-structs MCTS tree, fixed capacity, scatter-update friendly.

The paper keeps, per node, a preallocated vector of children plus atomic
counters (`w_j`, `n_j`, child-allocation index). The TPU-native equivalent is
a struct-of-arrays tree with one PAD row (index == capacity) that absorbs
masked scatter writes, and deterministic `.at[].add` scatter updates in place
of atomics (DESIGN.md §2).

All shapes are static; the tree is a pytree and can be carried through
`lax.fori_loop` / `lax.while_loop` and `jit`.

Every op indexes node axes from the RIGHT (``shape[-1]``), so a *forest* — E
independent trees stacked along a leading ensemble axis (DESIGN.md §3) — is
also a valid ``Tree`` whose per-member ops are recovered with ``jax.vmap``.
``init_forest`` / ``forest_member`` / ``forest_size`` are the ensemble
helpers; the root-parallel search layer lives in ``repro.core.root_parallel``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

NO_NODE = -1  # null child / parent sentinel


class Tree(NamedTuple):
    """MCTS tree with `cap` usable rows and one pad row at index `cap`.

    wins[j] is from the perspective of the player who MOVED INTO node j
    (i.e. ``3 - to_move[j]``), matching the UCT bookkeeping in the paper:
    X_j = w_j / n_j is the win rate child j offers its parent's mover.
    """

    parent: jnp.ndarray      # (cap+1,) i32
    move: jnp.ndarray        # (cap+1,) i32  move from parent that made this node
    to_move: jnp.ndarray     # (cap+1,) i32  player to move at this node (1|2)
    children: jnp.ndarray    # (cap+1, max_children) i32, NO_NODE padded
    n_children: jnp.ndarray  # (cap+1,) i32
    visits: jnp.ndarray      # (cap+1,) f32  n_j
    wins: jnp.ndarray        # (cap+1,) f32  w_j
    vloss: jnp.ndarray       # (cap+1,) f32  transient virtual-loss counts
    n_nodes: jnp.ndarray     # ()      i32  allocation counter (the paper's atomic index)

    @property
    def cap(self) -> int:
        # shape[-1], not shape[0]: a forest (leading ensemble axis) must
        # report the same per-member capacity as a single tree
        return self.parent.shape[-1] - 1

    @property
    def max_children(self) -> int:
        return self.children.shape[-1]


def init_tree(cap: int, max_children: int, root_to_move) -> Tree:
    """Fresh tree containing only the root (node 0)."""
    return Tree(
        parent=jnp.full((cap + 1,), NO_NODE, dtype=jnp.int32),
        move=jnp.full((cap + 1,), NO_NODE, dtype=jnp.int32),
        to_move=jnp.zeros((cap + 1,), dtype=jnp.int32)
        .at[0]
        .set(jnp.asarray(root_to_move, dtype=jnp.int32)),
        children=jnp.full((cap + 1, max_children), NO_NODE, dtype=jnp.int32),
        n_children=jnp.zeros((cap + 1,), dtype=jnp.int32),
        visits=jnp.zeros((cap + 1,), dtype=jnp.float32),
        wins=jnp.zeros((cap + 1,), dtype=jnp.float32),
        vloss=jnp.zeros((cap + 1,), dtype=jnp.float32),
        n_nodes=jnp.asarray(1, dtype=jnp.int32),
    )


def reset_vloss(tree: Tree) -> Tree:
    return tree._replace(vloss=jnp.zeros_like(tree.vloss))


def backup_paths(tree: Tree, paths: jnp.ndarray, values: jnp.ndarray,
                 weights: jnp.ndarray) -> Tree:
    """Batched backpropagation — the scatter-add analogue of atomic w_j/n_j.

    paths:   (W, max_depth) i32 node ids, PAD (== cap) where unused
    values:  (W,) playout outcomes: winning player (1|2) or 0 for a DRAW
    weights: (W,) f32 1.0 for active lanes, 0.0 for masked lanes
    """
    W, D = paths.shape
    flat = paths.reshape(-1)
    # credit: 1 if the player who moved into the node won the playout,
    # 0.5 on a draw (the value every player is indifferent to — keeps
    # X_j = w_j / n_j in [0, 1] with 0.5 as the draw point)
    mover = 3 - tree.to_move[flat]  # (W*D,)
    vals = jnp.repeat(values.astype(jnp.int32), D)
    win = jnp.where(vals == 0, 0.5, (mover == vals).astype(jnp.float32))
    w = jnp.repeat(weights, D) * (flat != tree.cap)  # mask pads & inactive lanes
    visits = tree.visits.at[flat].add(w)
    wins = tree.wins.at[flat].add(w * win)
    # pad row may have accumulated; zero it for hygiene
    visits = visits.at[tree.cap].set(0.0)
    wins = wins.at[tree.cap].set(0.0)
    return tree._replace(visits=visits, wins=wins)


def add_vloss(tree: Tree, paths: jnp.ndarray, weights: jnp.ndarray,
              amount: float = 1.0) -> Tree:
    """Scatter virtual loss along selected paths (diversifies later rounds)."""
    W, D = paths.shape
    flat = paths.reshape(-1)
    w = jnp.repeat(weights, D) * (flat != tree.cap) * amount
    vloss = tree.vloss.at[flat].add(w).at[tree.cap].set(0.0)
    return tree._replace(vloss=vloss)


def child_stat_tile(tree: Tree, nodes: jnp.ndarray):
    """Gather the child statistics of a (W,) node batch as (W, C) tiles.

    Returns ``(safe, valid, wins, visits, vloss, parent_total)``: ``safe``
    holds child ids with invalid slots redirected to the PAD row (whose
    stats are all zero), ``valid`` masks real slots, and ``parent_total`` is
    each node's visits + virtual loss. This is the gather feeding one
    level-synchronous ``kernels.ops.uct_select`` call — all W lanes of a
    descent score one tree level in a single (W, C) tile (DESIGN.md §11).
    """
    C = tree.max_children
    cap = tree.cap
    slots = tree.children[nodes]                                   # (W, C)
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < tree.n_children[nodes][:, None]
    safe = jnp.where(valid, slots, cap)
    parent_total = tree.visits[nodes] + tree.vloss[nodes]          # (W,)
    return (safe, valid, tree.wins[safe], tree.visits[safe],
            tree.vloss[safe], parent_total)


def best_child(tree: Tree) -> jnp.ndarray:
    """Most-visited root child's move (the paper's final move selection)."""
    slots = tree.children[0]  # (max_children,)
    valid = jnp.arange(slots.shape[0]) < tree.n_children[0]
    safe = jnp.where(valid, slots, tree.cap)
    counts = jnp.where(valid, tree.visits[safe], -jnp.inf)
    return tree.move[safe[jnp.argmax(counts)]]


def root_value(tree: Tree) -> jnp.ndarray:
    """Root win-rate estimate for the root's to-move player.

    wins[child] is from the mover-into-child = root's to-move perspective, so
    the root player's value is sum(child wins)/sum(child visits).
    """
    slots = tree.children[0]
    valid = jnp.arange(slots.shape[0]) < tree.n_children[0]
    safe = jnp.where(valid, slots, tree.cap)
    w = jnp.where(valid, tree.wins[safe], 0.0).sum()
    n = jnp.where(valid, tree.visits[safe], 0.0).sum()
    return w / jnp.maximum(n, 1.0)


# --------------------------------------------------------------- forests ----
def init_forest(n_trees: int, cap: int, max_children: int,
                root_to_move) -> Tree:
    """E fresh trees stacked along a leading ensemble axis (DESIGN.md §3).

    ``root_to_move`` is a scalar (shared by all members) or an (E,) vector
    (one independent root position per member, e.g. multi-request serving).
    """
    tm = jnp.broadcast_to(jnp.asarray(root_to_move, dtype=jnp.int32),
                          (n_trees,))
    return jax.vmap(lambda t: init_tree(cap, max_children, t))(tm)


def forest_size(forest: Tree) -> int:
    """Number of ensemble members E (leading axis of every leaf)."""
    return forest.parent.shape[0]


def forest_member(forest: Tree, e: int) -> Tree:
    """Extract member `e` as a plain single tree (host-side helper)."""
    return jax.tree.map(lambda x: x[e], forest)


def root_move_stats(tree: Tree, n_moves: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dense per-move (visits, wins) of the root's children.

    Returns two (n_moves,) f32 arrays indexed by move id; moves without a
    root child are zero. This is the merge currency of root parallelism:
    per-member child *slots* are in discovery order, but per-move dense
    vectors add across ensemble members (DESIGN.md §3).
    """
    slots = tree.children[0]
    valid = jnp.arange(slots.shape[0]) < tree.n_children[0]
    safe = jnp.where(valid, slots, tree.cap)
    mv = jnp.where(valid, tree.move[safe], n_moves)  # pad bucket == n_moves
    mv = jnp.clip(mv, 0, n_moves)
    visits = jnp.zeros((n_moves + 1,), jnp.float32).at[mv].add(
        jnp.where(valid, tree.visits[safe], 0.0))[:n_moves]
    wins = jnp.zeros((n_moves + 1,), jnp.float32).at[mv].add(
        jnp.where(valid, tree.wins[safe], 0.0))[:n_moves]
    return visits, wins


def root_summary(tree: Tree, n_moves: int) -> dict:
    """Host-side snapshot of the root decision — "whatever stats the tree
    has now".

    Dense per-move visit/win vectors (``root_move_stats``), the
    most-visited move, and the root value, pulled to numpy. This is the
    retire currency of game-search serving (``repro.serve.games``): a
    deadline-expired request ships this snapshot mid-search, a finished one
    ships it at budget exhaustion, and the serving-equivalence suite
    compares it bit-for-bit against an uninterrupted search's snapshot. A
    tree with no root children yet reports ``best_move == NO_NODE`` (-1).
    """
    visits, wins = root_move_stats(tree, n_moves)
    return {
        "root_visits": np.asarray(visits),
        "root_wins": np.asarray(wins),
        "best_move": int(best_child(tree)),
        "root_value": float(root_value(tree)),
        "tree_nodes": int(tree.n_nodes),
    }


def node_depths(tree: Tree) -> np.ndarray:
    """Host-side per-node depth (root = 0); unallocated slots report -1.

    Walks parent pointers in allocation order — ``expand_batch`` only ever
    attaches new nodes to existing ones, so ``parent[i] < i`` and a single
    forward pass resolves every depth. Used by the observability tests to
    cross-check the device-plane depth counters (``repro.obsv``) against
    the tree the search actually built.
    """
    parent = np.asarray(tree.parent)[:-1]      # drop the null slot
    n = int(tree.n_nodes)
    depth = np.full(parent.shape, -1, np.int64)
    if n > 0:
        depth[0] = 0
    for i in range(1, n):
        depth[i] = depth[parent[i]] + 1
    return depth


# ------------------------------------------------------------ invariants ----
def check_invariants(tree: Tree, *, discrete_credits: bool = True) -> None:
    """Host-side structural invariant checks (used by the property tests).

    ``discrete_credits=True`` (board-game trees) additionally asserts the
    draw-aware credit structure: backups add 0, 0.5 (draw) or 1 win per
    visit, so accumulated wins are half-integers. Token trees backed up
    with continuous values (``serve.mcts_decode.backup_values``) must pass
    ``discrete_credits=False``; the value-range check applies to both.
    """
    import numpy as np

    t = jax.tree.map(np.asarray, tree)
    n = int(t.n_nodes)
    cap = tree.cap
    assert 1 <= n <= cap
    for i in range(1, n):
        p = t.parent[i]
        assert 0 <= p < n, f"node {i}: bad parent {p}"
        assert t.to_move[i] == 3 - t.to_move[p], f"node {i}: to_move not alternating"
        kids = t.children[p][: t.n_children[p]]
        assert i in kids.tolist() or True  # membership checked below globally
    for i in range(n):
        k = int(t.n_children[i])
        kids = t.children[i][:k]
        assert (kids >= 0).all() and (kids < n).all(), f"node {i}: invalid child ids"
        moves = t.move[kids]
        assert len(set(moves.tolist())) == k, f"node {i}: duplicate child moves"
        assert (t.parent[kids] == i).all(), f"node {i}: child parent mismatch"
        assert (t.children[i][k:] == NO_NODE).all(), f"node {i}: stale child slots"
        # visits of children never exceed the parent's visits
        assert t.visits[kids].sum() <= t.visits[i] + 1e-6
        assert 0.0 <= t.wins[i] <= t.visits[i] + 1e-6
        # draw-aware value range: playout credits are 0, 0.5 (draw) or 1,
        # so accumulated wins are half-integers; 0 <= wins <= visits above
        # already bounds the signed value 2*(w/n) - 1 to [-1, 1] with 0
        # (all-draw) allowed
        if discrete_credits:
            assert abs(2.0 * t.wins[i] - round(2.0 * float(t.wins[i]))) < 1e-4, \
                f"node {i}: wins {t.wins[i]} not a half-integer credit sum"
    # every allocated non-root node is some node's child exactly once
    all_kids = []
    for i in range(n):
        all_kids += t.children[i][: int(t.n_children[i])].tolist()
    assert sorted(all_kids) == list(range(1, n)), "child lists != allocated nodes"
