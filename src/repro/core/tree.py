"""Array-of-structs MCTS tree, fixed capacity, scatter-update friendly.

The paper keeps, per node, a preallocated vector of children plus atomic
counters (`w_j`, `n_j`, child-allocation index). The TPU-native equivalent is
a struct-of-arrays tree with one PAD row (index == capacity) that absorbs
masked scatter writes, and deterministic `.at[].add` scatter updates in place
of atomics (DESIGN.md §2).

All shapes are static; the tree is a pytree and can be carried through
`lax.fori_loop` / `lax.while_loop` and `jit`.

Every op indexes node axes from the RIGHT (``shape[-1]``), so a *forest* — E
independent trees stacked along a leading ensemble axis (DESIGN.md §3) — is
also a valid ``Tree`` whose per-member ops are recovered with ``jax.vmap``.
``init_forest`` / ``forest_member`` / ``forest_size`` are the ensemble
helpers; the root-parallel search layer lives in ``repro.core.root_parallel``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

NO_NODE = -1  # null child / parent sentinel


class Tree(NamedTuple):
    """MCTS tree with `cap` usable rows and one pad row at index `cap`.

    wins[j] is from the perspective of the player who MOVED INTO node j
    (i.e. ``3 - to_move[j]``), matching the UCT bookkeeping in the paper:
    X_j = w_j / n_j is the win rate child j offers its parent's mover.
    """

    parent: jnp.ndarray      # (cap+1,) i32
    move: jnp.ndarray        # (cap+1,) i32  move from parent that made this node
    to_move: jnp.ndarray     # (cap+1,) i32  player to move at this node (1|2)
    children: jnp.ndarray    # (cap+1, max_children) i32, NO_NODE padded
    n_children: jnp.ndarray  # (cap+1,) i32
    visits: jnp.ndarray      # (cap+1,) f32  n_j
    wins: jnp.ndarray        # (cap+1,) f32  w_j
    vloss: jnp.ndarray       # (cap+1,) f32  transient virtual-loss counts
    n_nodes: jnp.ndarray     # ()      i32  allocation counter (the paper's atomic index)

    @property
    def cap(self) -> int:
        # shape[-1], not shape[0]: a forest (leading ensemble axis) must
        # report the same per-member capacity as a single tree
        return self.parent.shape[-1] - 1

    @property
    def max_children(self) -> int:
        return self.children.shape[-1]


def init_tree(cap: int, max_children: int, root_to_move) -> Tree:
    """Fresh tree containing only the root (node 0)."""
    return Tree(
        parent=jnp.full((cap + 1,), NO_NODE, dtype=jnp.int32),
        move=jnp.full((cap + 1,), NO_NODE, dtype=jnp.int32),
        to_move=jnp.zeros((cap + 1,), dtype=jnp.int32)
        .at[0]
        .set(jnp.asarray(root_to_move, dtype=jnp.int32)),
        children=jnp.full((cap + 1, max_children), NO_NODE, dtype=jnp.int32),
        n_children=jnp.zeros((cap + 1,), dtype=jnp.int32),
        visits=jnp.zeros((cap + 1,), dtype=jnp.float32),
        wins=jnp.zeros((cap + 1,), dtype=jnp.float32),
        vloss=jnp.zeros((cap + 1,), dtype=jnp.float32),
        n_nodes=jnp.asarray(1, dtype=jnp.int32),
    )


def reset_vloss(tree: Tree) -> Tree:
    return tree._replace(vloss=jnp.zeros_like(tree.vloss))


def backup_paths(tree: Tree, paths: jnp.ndarray, values: jnp.ndarray,
                 weights: jnp.ndarray) -> Tree:
    """Batched backpropagation — the scatter-add analogue of atomic w_j/n_j.

    paths:   (W, max_depth) i32 node ids, PAD (== cap) where unused
    values:  (W,) playout outcomes: winning player (1|2) or 0 for a DRAW
    weights: (W,) f32 1.0 for active lanes, 0.0 for masked lanes
    """
    W, D = paths.shape
    flat = paths.reshape(-1)
    # credit: 1 if the player who moved into the node won the playout,
    # 0.5 on a draw (the value every player is indifferent to — keeps
    # X_j = w_j / n_j in [0, 1] with 0.5 as the draw point)
    mover = 3 - tree.to_move[flat]  # (W*D,)
    vals = jnp.repeat(values.astype(jnp.int32), D)
    win = jnp.where(vals == 0, 0.5, (mover == vals).astype(jnp.float32))
    w = jnp.repeat(weights, D) * (flat != tree.cap)  # mask pads & inactive lanes
    visits = tree.visits.at[flat].add(w)
    wins = tree.wins.at[flat].add(w * win)
    # pad row may have accumulated; zero it for hygiene
    visits = visits.at[tree.cap].set(0.0)
    wins = wins.at[tree.cap].set(0.0)
    return tree._replace(visits=visits, wins=wins)


def add_vloss(tree: Tree, paths: jnp.ndarray, weights: jnp.ndarray,
              amount: float = 1.0) -> Tree:
    """Scatter virtual loss along selected paths (diversifies later rounds)."""
    W, D = paths.shape
    flat = paths.reshape(-1)
    w = jnp.repeat(weights, D) * (flat != tree.cap) * amount
    vloss = tree.vloss.at[flat].add(w).at[tree.cap].set(0.0)
    return tree._replace(vloss=vloss)


def child_stat_tile(tree: Tree, nodes: jnp.ndarray):
    """Gather the child statistics of a (W,) node batch as (W, C) tiles.

    Returns ``(safe, valid, wins, visits, vloss, parent_total)``: ``safe``
    holds child ids with invalid slots redirected to the PAD row (whose
    stats are all zero), ``valid`` masks real slots, and ``parent_total`` is
    each node's visits + virtual loss. This is the gather feeding one
    level-synchronous ``kernels.ops.uct_select`` call — all W lanes of a
    descent score one tree level in a single (W, C) tile (DESIGN.md §11).
    """
    C = tree.max_children
    cap = tree.cap
    slots = tree.children[nodes]                                   # (W, C)
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < tree.n_children[nodes][:, None]
    safe = jnp.where(valid, slots, cap)
    parent_total = tree.visits[nodes] + tree.vloss[nodes]          # (W,)
    return (safe, valid, tree.wins[safe], tree.visits[safe],
            tree.vloss[safe], parent_total)


def best_child(tree: Tree) -> jnp.ndarray:
    """Most-visited root child's move (the paper's final move selection)."""
    slots = tree.children[0]  # (max_children,)
    valid = jnp.arange(slots.shape[0]) < tree.n_children[0]
    safe = jnp.where(valid, slots, tree.cap)
    counts = jnp.where(valid, tree.visits[safe], -jnp.inf)
    return tree.move[safe[jnp.argmax(counts)]]


def root_value(tree: Tree) -> jnp.ndarray:
    """Root win-rate estimate for the root's to-move player.

    wins[child] is from the mover-into-child = root's to-move perspective, so
    the root player's value is sum(child wins)/sum(child visits).
    """
    slots = tree.children[0]
    valid = jnp.arange(slots.shape[0]) < tree.n_children[0]
    safe = jnp.where(valid, slots, tree.cap)
    w = jnp.where(valid, tree.wins[safe], 0.0).sum()
    n = jnp.where(valid, tree.visits[safe], 0.0).sum()
    return w / jnp.maximum(n, 1.0)


# --------------------------------------------------------------- forests ----
def init_forest(n_trees: int, cap: int, max_children: int,
                root_to_move) -> Tree:
    """E fresh trees stacked along a leading ensemble axis (DESIGN.md §3).

    ``root_to_move`` is a scalar (shared by all members) or an (E,) vector
    (one independent root position per member, e.g. multi-request serving).
    """
    tm = jnp.broadcast_to(jnp.asarray(root_to_move, dtype=jnp.int32),
                          (n_trees,))
    return jax.vmap(lambda t: init_tree(cap, max_children, t))(tm)


def forest_size(forest: Tree) -> int:
    """Number of ensemble members E (leading axis of every leaf)."""
    return forest.parent.shape[0]


def forest_member(forest: Tree, e: int) -> Tree:
    """Extract member `e` as a plain single tree (host-side helper)."""
    return jax.tree.map(lambda x: x[e], forest)


def root_move_stats(tree: Tree, n_moves: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dense per-move (visits, wins) of the root's children.

    Returns two (n_moves,) f32 arrays indexed by move id; moves without a
    root child are zero. This is the merge currency of root parallelism:
    per-member child *slots* are in discovery order, but per-move dense
    vectors add across ensemble members (DESIGN.md §3).
    """
    slots = tree.children[0]
    valid = jnp.arange(slots.shape[0]) < tree.n_children[0]
    safe = jnp.where(valid, slots, tree.cap)
    mv = jnp.where(valid, tree.move[safe], n_moves)  # pad bucket == n_moves
    mv = jnp.clip(mv, 0, n_moves)
    visits = jnp.zeros((n_moves + 1,), jnp.float32).at[mv].add(
        jnp.where(valid, tree.visits[safe], 0.0))[:n_moves]
    wins = jnp.zeros((n_moves + 1,), jnp.float32).at[mv].add(
        jnp.where(valid, tree.wins[safe], 0.0))[:n_moves]
    return visits, wins


def root_summary(tree: Tree, n_moves: int,
                 reused_visits: int | None = None) -> dict:
    """Host-side snapshot of the root decision — "whatever stats the tree
    has now".

    Dense per-move visit/win vectors (``root_move_stats``), the
    most-visited move, and the root value, pulled to numpy. This is the
    retire currency of game-search serving (``repro.serve.games``): a
    deadline-expired request ships this snapshot mid-search, a finished one
    ships it at budget exhaustion, and the serving-equivalence suite
    compares it bit-for-bit against an uninterrupted search's snapshot. A
    tree with no root children yet reports ``best_move == NO_NODE`` (-1).

    Works unchanged on RE-ROOTED trees (``reroot_tree``), whose root
    carries nonzero visits before the first fresh playout — the snapshot is
    always "retained + new" evidence. Pass ``reused_visits`` (the root
    visit count the search started from; warm sessions know it) to expose
    how much of the evidence was inherited; it is reported only when
    present so cold-search snapshots stay bit-comparable across versions.
    """
    visits, wins = root_move_stats(tree, n_moves)
    out = {
        "root_visits": np.asarray(visits),
        "root_wins": np.asarray(wins),
        "best_move": int(best_child(tree)),
        "root_value": float(root_value(tree)),
        "tree_nodes": int(tree.n_nodes),
    }
    if reused_visits is not None:
        out["reused_visits"] = int(reused_visits)
    return out


@functools.partial(jax.jit, static_argnames=("n_moves",))
def root_summary_device(tree: Tree, n_moves: int) -> dict:
    """Device-side twin of ``root_summary``: the same reductions as ONE
    async-dispatched jitted program, nothing pulled to host.

    The pipelined serving engine (``repro.serve.games``, DESIGN.md §18)
    dispatches this when it detects retirement and materializes the result
    a tick later, so the host readback overlaps the next tick's quanta
    instead of stalling the whole pool on one finished search.
    """
    visits, wins = root_move_stats(tree, n_moves)
    return {"root_visits": visits, "root_wins": wins,
            "best_move": best_child(tree), "root_value": root_value(tree),
            "tree_nodes": tree.n_nodes}


def materialize_root_summary(dev: dict,
                             reused_visits: int | None = None) -> dict:
    """Pull a ``root_summary_device`` dict to the exact host types
    ``root_summary`` ships — the deferred half of the pipelined retire."""
    out = {
        "root_visits": np.asarray(dev["root_visits"]),
        "root_wins": np.asarray(dev["root_wins"]),
        "best_move": int(dev["best_move"]),
        "root_value": float(dev["root_value"]),
        "tree_nodes": int(dev["tree_nodes"]),
    }
    if reused_visits is not None:
        out["reused_visits"] = int(reused_visits)
    return out


# -------------------------------------------------------------- re-rooting ----
def _reroot_impl(tree: Tree, move: jnp.ndarray, new_cap: int) -> Tree:
    """Traced body of ``reroot_tree`` (see its docstring for the contract).

    Everything is masked scatter/gather over static shapes:

    1. locate the root child that carries ``move`` (may not exist);
    2. subtree membership by pointer doubling on the parent array — the
       old root and the played child become self-loops, so every allocated
       node's ancestor pointer converges to one of the two in
       ``ceil(log2(cap))`` gather rounds;
    3. per-node depth by the companion (ancestor, distance) doubling;
    4. BFS renumbering = one two-key ``lax.sort`` by (depth, old id):
       parents sort strictly before children, so the new ids satisfy the
       ``parent[i] < i`` allocation-order invariant every host-side walk
       (``node_depths``, ``check_invariants``) relies on;
    5. one gather per field copies the retained rows into a fresh layout;
       non-retained rows source the old PAD row, whose fields are exactly
       the ``init_tree`` values — so the compacted tree is bit-identical
       to a freshly grown one node-for-node.
    """
    cap = tree.cap
    C = tree.max_children
    n = tree.n_nodes
    idx = jnp.arange(cap + 1, dtype=jnp.int32)
    alloc = idx < n

    # 1. the played child (old pad row when the move was never expanded)
    slots = tree.children[0]
    valid = jnp.arange(C, dtype=jnp.int32) < tree.n_children[0]
    safe = jnp.where(valid, slots, cap)
    hit = valid & (tree.move[safe] == move)
    exists = hit.any()
    child = jnp.where(exists, safe[jnp.argmax(hit)], cap)

    # 2. membership: ancestor pointers converge to a self-loop at the old
    # root (non-members) or at the played child (members)
    rounds = max(1, int(cap + 1).bit_length())
    anc = jnp.where(alloc, tree.parent, idx)   # unallocated/pad: self-loop
    anc = jnp.where(idx == 0, 0, anc)
    anc = jnp.where(idx == child, idx, anc)
    # 3. distance-to-root rides the same doubling (root contributes 0)
    par = jnp.where(alloc, tree.parent, idx)
    par = jnp.where(idx == 0, 0, par)
    dist = ((idx != 0) & alloc).astype(jnp.int32)

    def _double(_, s):
        anc, dist, par = s
        return anc[anc], dist + dist[par], par[par]

    # fori_loop, not a Python loop: unrolling ~14 gather rounds makes the
    # XLA:CPU compile take minutes at tree_cap=16k
    anc, dist, par = jax.lax.fori_loop(0, rounds, _double, (anc, dist, par))
    member = alloc & (anc == child)
    n_sub = member.sum().astype(jnp.int32)

    # 4. BFS order: members sorted by (depth, old id); non-members sink
    BIG = jnp.int32(2**30)
    key_depth = jnp.where(member, dist, BIG)
    _, order = jax.lax.sort((key_depth, idx), num_keys=2)
    rank = jnp.arange(cap + 1, dtype=jnp.int32)
    is_m = rank < n_sub
    # old id -> new id; everything outside the subtree maps to the new PAD
    new_of_old = jnp.full((cap + 1,), new_cap, jnp.int32).at[
        jnp.where(is_m, order, cap)].set(
        jnp.where(is_m, rank, new_cap))

    # 5. gather rows into the fresh layout (new row k copies old row
    # order[k]; rows past the subtree copy the old PAD row == init state)
    kk = jnp.arange(new_cap + 1, dtype=jnp.int32)
    take = kk < n_sub
    src = jnp.where(take, order[jnp.minimum(kk, cap)], cap)

    parent = jnp.where(take, new_of_old[jnp.clip(tree.parent[src], 0, cap)],
                       NO_NODE).at[0].set(NO_NODE)
    mv_arr = jnp.where(take, tree.move[src], NO_NODE).at[0].set(NO_NODE)
    to_move = jnp.where(take, tree.to_move[src], 0).at[0].set(
        3 - tree.to_move[0])
    ch_old = tree.children[src]                          # (new_cap+1, C)
    children = jnp.where((ch_old >= 0) & take[:, None],
                         new_of_old[jnp.clip(ch_old, 0, cap)],
                         NO_NODE).astype(jnp.int32)
    return Tree(
        parent=parent.astype(jnp.int32),
        move=mv_arr.astype(jnp.int32),
        to_move=to_move.astype(jnp.int32),
        children=children,
        n_children=jnp.where(take, tree.n_children[src], 0),
        visits=jnp.where(take, tree.visits[src], 0.0),
        wins=jnp.where(take, tree.wins[src], 0.0),
        vloss=jnp.zeros((new_cap + 1,), jnp.float32),
        n_nodes=jnp.maximum(n_sub, 1),
    )


_reroot_jit = jax.jit(_reroot_impl, static_argnames=("new_cap",))


@functools.partial(jax.jit, static_argnames=("new_cap",))
def _reroot_forest_jit(forest: Tree, moves: jnp.ndarray, new_cap: int) -> Tree:
    return jax.vmap(lambda t, m: _reroot_impl(t, m, new_cap))(forest, moves)


def _check_reroot_cap(cap: int, new_cap: int | None) -> int:
    if new_cap is None:
        return cap
    if new_cap < cap:
        # the retained subtree holds at most cap-1 nodes, so new_cap >= cap
        # always fits; anything smaller cannot be proven to fit from traced
        # shapes alone — refuse loudly instead of silently truncating the
        # subtree (the stats-retention contract would be broken)
        raise ValueError(
            f"reroot capacity overflow risk: new_cap={new_cap} < "
            f"source cap={cap}; a re-rooted subtree can hold up to cap-1 "
            "nodes, so the fresh budget must be >= the source capacity "
            "(shrinking a tree would silently drop retained statistics)")
    return new_cap


def reroot_tree(tree: Tree, move, new_cap: int | None = None) -> Tree:
    """Re-root the tree at the root child carrying ``move`` (compaction).

    The played child's whole subtree is BFS-renumbered into a fresh
    fixed-capacity tree whose node 0 is that child: the warm start of the
    NEXT move's search (DESIGN.md §16). Jittable — ``move`` is traced, the
    pass is one compiled program per (cap, max_children, new_cap) shape.

    Retention contract (asserted by ``check_reroot_retention`` and the
    test suite): every retained node's ``visits``/``wins``/``to_move``/
    ``move``, its child COUNT and child set, and its depth (shifted by
    exactly -1) are bit-identical to the corresponding node of the source
    tree. Rows outside the subtree are indistinguishable from a fresh
    ``init_tree``'s, so a search continuing from the result behaves exactly
    like one hand-seeded with the retained statistics. Virtual loss is
    transient per-search state and is cleared.

    Re-rooting onto a move the root never expanded (or an unvisited child)
    yields a valid 1-node tree: root ``to_move`` flipped, zero statistics —
    a cold start in warm clothing. ``new_cap`` (default: source capacity)
    must be >= the source capacity; smaller budgets raise ``ValueError`` at
    trace time rather than silently truncating the subtree.
    """
    new_cap = _check_reroot_cap(tree.cap, new_cap)
    return _reroot_jit(tree, jnp.asarray(move, jnp.int32), new_cap=new_cap)


def reroot_forest(forest: Tree, moves, new_cap: int | None = None) -> Tree:
    """``reroot_tree`` for all E members in ONE vmapped call.

    ``moves`` is a scalar (every member re-roots at the same played move —
    the ensemble self-play case) or an (E,) vector (independent positions).
    Each member keeps its own subtree; members that never expanded the move
    come back as 1-node trees (the partial-merge twin of root parallelism's
    "a member cannot host stats for a move it never discovered").
    """
    new_cap = _check_reroot_cap(forest.cap, new_cap)
    E = forest_size(forest)
    mv = jnp.broadcast_to(jnp.asarray(moves, jnp.int32), (E,))
    return _reroot_forest_jit(forest, mv, new_cap=new_cap)


def check_reroot_retention(src: Tree, dst: Tree, move: int) -> int:
    """Host-side assertion of the re-root retention contract; returns the
    number of retained nodes.

    Walks the source subtree under the played child and checks every node
    against its image in ``dst``: bit-identical ``visits``/``wins``,
    matching ``to_move``/``move``/child count, child moves as a set, and
    depth shifted by exactly one. Used by the tests and available to
    drivers as a debugging probe (it is O(subtree), host-side, eager).
    """
    s = jax.tree.map(np.asarray, src)
    d = jax.tree.map(np.asarray, dst)
    kids0 = s.children[0][: int(s.n_children[0])]
    hits = [int(k) for k in kids0 if int(s.move[k]) == int(move)]
    if not hits:
        assert int(d.n_nodes) == 1, "unexpanded move must yield 1-node tree"
        assert d.visits[0] == 0.0 and d.wins[0] == 0.0
        assert int(d.to_move[0]) == 3 - int(s.to_move[0])
        return 0
    root = hits[0]
    sdep = node_depths(src)
    ddep = node_depths(dst)
    # BFS pairing: source subtree nodes in (depth, old id) order ARE the
    # destination nodes 0..n_sub-1 in id order (the renumbering's contract)
    members = []
    stack = [root]
    while stack:
        u = stack.pop()
        members.append(u)
        stack.extend(int(c) for c in s.children[u][: int(s.n_children[u])])
    members.sort(key=lambda u: (int(sdep[u]), u))
    n_sub = len(members)
    assert int(d.n_nodes) == n_sub, \
        f"retained {int(d.n_nodes)} nodes, subtree has {n_sub}"
    new_of_old = {u: k for k, u in enumerate(members)}
    for u, k in new_of_old.items():
        assert s.visits[u] == d.visits[k], f"visits differ at node {u}->{k}"
        assert s.wins[u] == d.wins[k], f"wins differ at node {u}->{k}"
        assert int(s.to_move[u]) == int(d.to_move[k])
        if k != 0:
            assert int(s.move[u]) == int(d.move[k])
            assert new_of_old[int(s.parent[u])] == int(d.parent[k])
        assert int(s.n_children[u]) == int(d.n_children[k])
        su = {int(new_of_old[int(c)])
              for c in s.children[u][: int(s.n_children[u])]}
        du = set(d.children[k][: int(d.n_children[k])].tolist())
        assert su == du, f"child set differs at node {u}->{k}"
        assert int(sdep[u]) == int(ddep[k]) + 1, "depth must shift by one"
    return n_sub


def node_depths(tree: Tree) -> np.ndarray:
    """Host-side per-node depth (root = 0); unallocated slots report -1.

    Walks parent pointers in allocation order — ``expand_batch`` only ever
    attaches new nodes to existing ones, so ``parent[i] < i`` and a single
    forward pass resolves every depth. Used by the observability tests to
    cross-check the device-plane depth counters (``repro.obsv``) against
    the tree the search actually built.
    """
    parent = np.asarray(tree.parent)[:-1]      # drop the null slot
    n = int(tree.n_nodes)
    depth = np.full(parent.shape, -1, np.int64)
    if n > 0:
        depth[0] = 0
    for i in range(1, n):
        depth[i] = depth[parent[i]] + 1
    return depth


# ------------------------------------------------------------ invariants ----
def check_invariants(tree: Tree, *, discrete_credits: bool = True) -> None:
    """Host-side structural invariant checks (used by the property tests).

    ``discrete_credits=True`` (board-game trees) additionally asserts the
    draw-aware credit structure: backups add 0, 0.5 (draw) or 1 win per
    visit, so accumulated wins are half-integers. Token trees backed up
    with continuous values (``serve.mcts_decode.backup_values``) must pass
    ``discrete_credits=False``; the value-range check applies to both.

    Every check here holds for RE-ROOTED trees (``reroot_tree``) too, by
    design: the root of a warm tree may start with nonzero visits/wins
    (retained evidence), which is fine because no invariant equates root
    visits with the playout count — only the one-sided "children's visits
    never exceed the parent's" bound is asserted, and the retention
    contract carries both sides of that inequality bit-exactly. The
    ``parent[i] < i`` allocation-order assumption (see ``node_depths``) is
    preserved by the BFS renumbering's (depth, old id) sort key.
    """
    import numpy as np

    t = jax.tree.map(np.asarray, tree)
    n = int(t.n_nodes)
    cap = tree.cap
    assert 1 <= n <= cap
    for i in range(1, n):
        p = t.parent[i]
        assert 0 <= p < n, f"node {i}: bad parent {p}"
        assert t.to_move[i] == 3 - t.to_move[p], f"node {i}: to_move not alternating"
        kids = t.children[p][: t.n_children[p]]
        assert i in kids.tolist() or True  # membership checked below globally
    for i in range(n):
        k = int(t.n_children[i])
        kids = t.children[i][:k]
        assert (kids >= 0).all() and (kids < n).all(), f"node {i}: invalid child ids"
        moves = t.move[kids]
        assert len(set(moves.tolist())) == k, f"node {i}: duplicate child moves"
        assert (t.parent[kids] == i).all(), f"node {i}: child parent mismatch"
        assert (t.children[i][k:] == NO_NODE).all(), f"node {i}: stale child slots"
        # visits of children never exceed the parent's visits
        assert t.visits[kids].sum() <= t.visits[i] + 1e-6
        assert 0.0 <= t.wins[i] <= t.visits[i] + 1e-6
        # draw-aware value range: playout credits are 0, 0.5 (draw) or 1,
        # so accumulated wins are half-integers; 0 <= wins <= visits above
        # already bounds the signed value 2*(w/n) - 1 to [-1, 1] with 0
        # (all-draw) allowed
        if discrete_credits:
            assert abs(2.0 * t.wins[i] - round(2.0 * float(t.wins[i]))) < 1e-4, \
                f"node {i}: wins {t.wins[i]} not a half-integer credit sum"
    # every allocated non-root node is some node's child exactly once
    all_kids = []
    for i in range(n):
        all_kids += t.children[i][: int(t.n_children[i])].tolist()
    assert sorted(all_kids) == list(range(1, n)), "child lists != allocated nodes"
