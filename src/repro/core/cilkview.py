"""Analytic work/span scaling model (paper Fig 5 / Fig 9 analogue).

Cilkview reports burdened-dag lower bounds on speedup from a serial
instrumented run: speedup(P) <= min(P, T1 / T_inf). For GSCPM with nTasks
tasks of grain m on P lanes the dag is a fork-join of nTasks serial chains:

    T1     = nTasks * m * t_iter                 (total work)
    T_inf  = m * t_iter + nTasks * t_spawn       (longest chain + spawn chain)
    T_P   >= max(T1 / P, T_inf) + burden

so available parallelism = T1 / T_inf → nTasks as m grows, capped by spawn
overhead as m shrinks — the two regimes of the paper's Table I. The burden
term models per-task scheduling cost (the paper's "spawn and scheduling
overhead"); on our harness it is the per-round dispatch cost, measured by
benchmarks/fig7_speedup.py and fed back into Fig 9's overlay.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DagModel:
    t_iter: float = 1.0       # cost of one UCT iteration (arbitrary units)
    t_spawn: float = 0.002    # per-task spawn/schedule burden, in t_iter units
    t_round: float = 0.0      # per-round dispatch burden (host), t_iter units


def work(n_tasks: int, grain: int, m: DagModel) -> float:
    return n_tasks * grain * m.t_iter


def span(n_tasks: int, grain: int, m: DagModel) -> float:
    return grain * m.t_iter + n_tasks * m.t_spawn


def parallelism(n_tasks: int, grain: int, m: DagModel) -> float:
    return work(n_tasks, grain, m) / span(n_tasks, grain, m)


def speedup_bound(n_tasks: int, grain: int, n_cores: int, m: DagModel) -> float:
    """Cilkview-style lower-bound estimate of achievable speedup on P cores."""
    t1 = work(n_tasks, grain, m)
    tinf = span(n_tasks, grain, m)
    rounds = int(np.ceil(n_tasks / n_cores))
    tp = max(t1 / n_cores, tinf) + rounds * m.t_round
    return t1 / tp


def burdened_span(n_tasks: int, grain: int, n_cores: int,
                  m: DagModel) -> float:
    """Span plus the serial dispatch burden the P-core execution pays: each
    of the ceil(nTasks/P) rounds costs one host dispatch (``t_round``)."""
    rounds = int(np.ceil(n_tasks / n_cores))
    return span(n_tasks, grain, m) + rounds * m.t_round


def burdened_parallelism(n_tasks: int, grain: int, n_cores: int,
                         m: DagModel) -> float:
    """Cilkview's *burdened parallelism*: T1 over the burdened span — the
    parallelism estimate that survives scheduling overhead, which is what a
    MEASURED dag model (``repro.obsv.profile.measured_dag_model``) makes
    honest for the Fig 9 overlay."""
    return work(n_tasks, grain, m) / burdened_span(n_tasks, grain, n_cores, m)


def profile(n_playouts: int, task_counts: list[int], core_counts: list[int],
            m: DagModel | None = None) -> dict[int, list[float]]:
    """speedup_bound curves: {n_tasks: [bound per core count]} (paper Fig 5)."""
    m = m or DagModel()
    out: dict[int, list[float]] = {}
    for t in task_counts:
        grain = max(1, n_playouts // t)
        out[t] = [speedup_bound(t, grain, p, m) for p in core_counts]
    return out
