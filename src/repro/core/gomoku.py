"""Pure-JAX free-style Gomoku (five-in-a-row) — the second `Game` workload.

Board cells are indexed row-major on an n x n square; a *move* is the flat
index of an empty cell; a player wins by owning five (or more — free-style)
consecutive cells along a row, column, or either diagonal, and a full board
with no five is a DRAW — the protocol's first non-win outcome, exercising
the draw path through backup (credit 0.5), UCT, and root merging.

Everything a search consumes is batched over a (W, n_cells) tile with NO
per-lane loops (DESIGN.md §13):

- the win test is four directional 5-window scans built from STATIC flat
  ``roll`` shifts + per-cell window-validity masks (the same gather-free
  trick as Hex's ``_shift_tables``): window(i, dir) is monochrome iff the
  AND of 5 shifted stone masks holds at i;
- the fused ``playout_batch`` never steps move-by-move. It draws the same
  parity fill as Hex (``game.empty_fill_ranks``: rank k among the empties
  = the k-th playout move) and resolves the outcome by COMPLETION TIME:
  a window monochrome in the fully-filled board was completed exactly when
  its last cell was placed (stones are never removed), so its completion
  time is the max fill rank over its 5 cells (pre-existing stones count as
  rank -1). The playout's winner is the color of the window with minimal
  completion time — the truncated random game and the full fill agree on
  every completed window, so this is bit-identical to playing the fill
  order move-by-move and stopping at the first five
  (``playout_scalar`` below IS that sequential oracle, same RNG stream;
  pinned in tests/test_game_protocol.py). No five anywhere -> draw (0).

Two windows of different colors cannot complete at the same time (a window
completes on its own color's placement), so the min-time comparison needs no
tie-break; on illegal boards where BOTH colors already contain a five
(unreachable through the search: ``legal_mask`` is empty at won positions)
the evaluation returns a draw.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import game as game_mod

EMPTY = jnp.int8(0)
BLACK = jnp.int8(1)
WHITE = jnp.int8(2)

WIN_RUN = 5  # free-style five-in-a-row

# the four scan directions as (row, col) steps: E, S, SE, SW
_DIRS = ((0, 1), (1, 0), (1, 1), (1, -1))


class GomokuSpec(NamedTuple):
    """Static board description (python ints; safe to close over in jit)."""

    size: int

    @property
    def n_cells(self) -> int:
        return self.size * self.size


@functools.lru_cache(maxsize=None)
def _window_tables(size: int):
    """Per direction: flat shift offset + bool mask of valid window starts.

    Cell i starts a 5-window in direction (dr, dc) iff all of
    i, i+off, ..., i+4*off stay on the board along that line; ``roll``
    wrap-around artifacts land only on masked-out starts.
    """
    n = size * size
    offs, masks = [], []
    for dr, dc in _DIRS:
        m = np.zeros(n, dtype=bool)
        for r in range(size):
            for c in range(size):
                rr, cc = r + (WIN_RUN - 1) * dr, c + (WIN_RUN - 1) * dc
                if 0 <= rr < size and 0 <= cc < size:
                    m[r * size + c] = True
        offs.append(dr * size + dc)
        masks.append(m)
    return tuple(offs), np.stack(masks)


def empty_board(spec: GomokuSpec) -> jnp.ndarray:
    return jnp.zeros(spec.n_cells, dtype=jnp.int8)


def place(board: jnp.ndarray, move: jnp.ndarray, player: jnp.ndarray) -> jnp.ndarray:
    """Place `player`'s stone at flat index `move` (no legality check)."""
    return board.at[move].set(player.astype(jnp.int8))


# ------------------------------------------------- batched (W, cells) ops ----
def five_windows_batch(stones: jnp.ndarray, spec: GomokuSpec) -> jnp.ndarray:
    """(W, n) bool -> (W, 4, n): window at start i (dir d) is all-stones.

    Four directional run scans, each the AND of five statically-shifted
    copies of the stone mask — no gathers, no per-lane loops.
    """
    offs, masks = _window_tables(spec.size)
    outs = []
    for off, mk in zip(offs, jnp.asarray(masks)):
        acc = stones
        for k in range(1, WIN_RUN):
            acc = acc & jnp.roll(stones, -k * off, axis=1)
        outs.append(acc & mk[None, :])
    return jnp.stack(outs, axis=1)


def has_five_batch(boards: jnp.ndarray, player, spec: GomokuSpec) -> jnp.ndarray:
    """(W, n) boards -> (W,) bool: does `player` own a completed five?"""
    W = boards.shape[0]
    player = jnp.broadcast_to(jnp.asarray(player, jnp.int8), (W,))
    stones = boards == player[:, None]
    return five_windows_batch(stones, spec).any(axis=(1, 2))


def terminal_batch(boards: jnp.ndarray, spec: GomokuSpec) -> jnp.ndarray:
    """(W, n) -> (W,) bool: a five exists, or the board is full (draw)."""
    full = ~(boards == EMPTY).any(axis=1)
    return (full | has_five_batch(boards, BLACK, spec)
            | has_five_batch(boards, WHITE, spec))


def winner_scan_batch(boards: jnp.ndarray, spec: GomokuSpec) -> jnp.ndarray:
    """Winner of TERMINAL boards: {1, 2} for a five, 0 for a full-board draw.

    CONTRACT: boards must be terminal (the search only evaluates positions
    the game has ended on); on a non-terminal board this returns 0, which is
    NOT "drawn" but "no five yet". Reached through the per-game eval
    dispatch ``kernels.ops.gomoku_winner``.
    """
    fb = has_five_batch(boards, BLACK, spec)
    fw = has_five_batch(boards, WHITE, spec)
    return jnp.where(fb, BLACK, jnp.where(fw, WHITE, EMPTY)).astype(jnp.int8)


def first_completion_winner(filled: jnp.ndarray, times: jnp.ndarray,
                            spec: GomokuSpec) -> jnp.ndarray:
    """Outcome of a random fill by completion time (module docstring).

    filled: (W, n) int8 fully-filled boards; times: (W, n) int32 fill rank
    per cell, -1 for stones predating the playout. Returns (W,) int8 in
    {0 draw, 1, 2}.
    """
    n = spec.n_cells
    big = jnp.int32(n)  # > any completion time
    offs, _ = _window_tables(spec.size)

    def win_time(player):
        mono = five_windows_batch(filled == player, spec)     # (W, 4, n)
        best = big
        for d, off in enumerate(offs):
            wt = times
            for k in range(1, WIN_RUN):
                wt = jnp.maximum(wt, jnp.roll(times, -k * off, axis=1))
            cand = jnp.where(mono[:, d], wt, big)
            best = jnp.minimum(best, cand.min(axis=1))        # (W,)
        return best

    tb, tw = win_time(BLACK), win_time(WHITE)
    return jnp.where(tb < tw, BLACK,
                     jnp.where(tw < tb, WHITE, EMPTY)).astype(jnp.int8)


def playout_batch(boards: jnp.ndarray, to_move, keys: jax.Array,
                  spec: GomokuSpec) -> jnp.ndarray:
    """W random playouts fused into one (W, cells) evaluation stage.

    Same fill stream as Hex (one uniform (n,) draw per lane), outcome by
    completion time through the per-game dispatch
    ``kernels.ops.gomoku_first_winner`` — no move-by-move loop.
    """
    from repro.kernels import ops  # function-level: ops imports games' refs

    empties = boards == EMPTY
    ranks = game_mod.empty_fill_ranks(boards, keys)
    colors = game_mod.parity_fill_colors(ranks, to_move)
    filled = jnp.where(empties, colors, boards)
    times = jnp.where(empties, ranks, -1)
    return ops.gomoku_first_winner(filled, times, spec.size)


def playout_scalar(board: jnp.ndarray, to_move, key: jax.Array,
                   spec: GomokuSpec) -> jnp.ndarray:
    """Sequential per-lane playout oracle: place stones one at a time in the
    fill's rank order (argmin of the SAME uniform draw over the remaining
    empties, index tie-break matching ``empty_fill_ranks``), checking the
    placer's five after each move. Bit-identical to one lane of
    ``playout_batch`` — an independent incremental check of the
    completion-time formulation."""
    n = spec.n_cells
    u = jax.random.uniform(key, (n,))

    def five(b, p):
        return has_five_batch(b[None], p, spec)[0]

    fb, fw = five(board, BLACK), five(board, WHITE)
    w0 = jnp.where(fb & fw, EMPTY, jnp.where(fb, BLACK,
                                             jnp.where(fw, WHITE, EMPTY)))
    done0 = fb | fw | ~(board == EMPTY).any()
    player0 = jnp.asarray(to_move, jnp.int32)

    def cond(st):
        return ~st[3]

    def body(st):
        b, p, w, _ = st
        empt = b == EMPTY
        pick = jnp.argmin(jnp.where(empt, u, jnp.inf)).astype(jnp.int32)
        b2 = place(b, pick, p)
        won = five(b2, p.astype(jnp.int8))
        full = ~(b2 == EMPTY).any()
        return b2, 3 - p, jnp.where(won, p.astype(jnp.int8), w), won | full

    _, _, w, _ = jax.lax.while_loop(
        cond, body, (board, player0, w0.astype(jnp.int8), done0))
    return w


# ------------------------------------------------------- the Game protocol ----
class GomokuGame(NamedTuple):
    """Free-style Gomoku through the batched ``Game`` protocol.

    Differs from Hex in everything the protocol abstracts: the terminal
    test (first five ends the game mid-board), the legal-move set (empty at
    won positions, which is what stops the search expanding past a win),
    and the outcome range (draws). Sizes below 5 are legal but all-draw.
    """

    size: int

    @property
    def n_cells(self) -> int:
        return self.size * self.size

    @property
    def n_actions(self) -> int:
        return self.n_cells

    @property
    def max_moves(self) -> int:
        return self.n_cells

    @property
    def _spec(self) -> GomokuSpec:
        return GomokuSpec(self.size)

    def init_board(self) -> jnp.ndarray:
        return empty_board(self._spec)

    def place(self, board, move, player) -> jnp.ndarray:
        return place(board, move, player)

    def legal_mask(self, board) -> jnp.ndarray:
        # no legal moves once a five exists: expansion stops, and the
        # playout of the (terminal) leaf returns the pre-existing winner
        # (its completion time -1 beats every fill rank)
        won = (has_five_batch(board[None], BLACK, self._spec)
               | has_five_batch(board[None], WHITE, self._spec))[0]
        return (board == EMPTY) & ~won

    def terminal_batch(self, boards) -> jnp.ndarray:
        return terminal_batch(boards, self._spec)

    def winner_batch(self, boards) -> jnp.ndarray:
        from repro.kernels import ops

        return ops.gomoku_winner(boards, self.size)

    def playout_batch(self, boards, to_move, keys) -> jnp.ndarray:
        return playout_batch(boards, to_move, keys, self._spec)

    def playout_scalar(self, board, to_move, key) -> jnp.ndarray:
        return playout_scalar(board, to_move, key, self._spec)

    def replay_moves(self, moves, n_moves, first_player) -> jnp.ndarray:
        return game_mod.replay_moves(moves, n_moves, first_player,
                                     self.n_cells)

    def winner_probe(self, board) -> jnp.ndarray:
        # PARTIAL boards welcome (unlike winner_batch's terminal-only
        # contract): a five decides regardless of remaining space, a full
        # board without one is the draw, anything else is ongoing
        fb = has_five_batch(board[None], BLACK, self._spec)[0]
        fw = has_five_batch(board[None], WHITE, self._spec)[0]
        full = ~(board == EMPTY).any()
        return jnp.where(
            fb, jnp.int8(1),
            jnp.where(fw, jnp.int8(2),
                      jnp.where(full, jnp.int8(0),
                                jnp.int8(-1))))


game_mod.register_game("gomoku", GomokuGame)
