"""UCT scoring and child selection (paper eq. 1).

    UCT(j) = X_j + Cp * sqrt( ln(n) / n_j ),   X_j = w_j / n_j

Virtual loss enters as extra visits with zero wins (lowers X_j and the
exploration bonus), diversifying simultaneous selections — the batched
analogue of the lock contention the paper's threads experience.

This is the pure-jnp reference; `repro.kernels.uct_select` is the Pallas twin
used on TPU (validated against this module in tests/test_kernels.py). The
search hot path reaches both through ``repro.kernels.ops.uct_select``, which
scores a whole (W, C) level tile at once (DESIGN.md §11); ``cp`` may be a
traced scalar everywhere in this module, so sweeping it never recompiles.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -jnp.inf


def uct_scores(wins: jnp.ndarray, visits: jnp.ndarray, vloss: jnp.ndarray,
               parent_visits: jnp.ndarray, cp,
               valid: jnp.ndarray) -> jnp.ndarray:
    """Vectorized UCT over child slots.

    wins/visits/vloss: (..., C) child stats; parent_visits: (...,) scalar per
    row; valid: (..., C) bool; cp: python float or traced 0-d array.
    Unvisited children get +inf (explored first), invalid slots get -inf.
    """
    n_j = visits + vloss
    x_j = wins / jnp.maximum(n_j, 1.0)
    n_p = jnp.maximum(parent_visits, 1.0)
    explore = cp * jnp.sqrt(jnp.log(n_p)[..., None] / jnp.maximum(n_j, 1.0))
    score = x_j + explore
    score = jnp.where(n_j <= 0.0, jnp.inf, score)
    return jnp.where(valid, score, NEG_INF)


def select_child(scores: jnp.ndarray, noise: jnp.ndarray | None = None) -> jnp.ndarray:
    """Argmax child slot, with optional per-slot tie-break noise.

    noise is bounded jitter (e.g. eps * uniform) — with noise=None ties break
    toward the lowest slot, matching the sequential reference.
    """
    if noise is not None:
        # preserve +inf (unvisited-first) and -inf (invalid) semantics
        finite = jnp.isfinite(scores)
        scores = jnp.where(finite, scores + noise, scores)
        # unvisited children: tie-break among them with noise too
        unv = scores == jnp.inf
        scores = jnp.where(unv, 1e30 + noise, scores)
    return jnp.argmax(scores, axis=-1)
