"""Batched ``Game`` protocol + registry — the game-agnostic seam (DESIGN.md §13).

The paper's contribution — Grain Size Controlled Parallel MCTS on a
work-sharing FIFO — is game-independent, and the group's follow-up work
(arXiv:1704.00325, arXiv:1605.04447) frames the parallel pattern explicitly
as a reusable structure over a pluggable game. This module is that seam: the
search layers (``core/gscpm.py``, ``core/mcts.py``, ``core/root_parallel.py``)
consume ONLY the protocol below and never import a game module directly.

A game is a small hashable NamedTuple (python-int fields only, so it is safe
to close over in ``jit`` and to carry through a static config) exposing the
vectorized primitives the fused pipeline consumes:

===================  ========================================================
``n_cells``          board length; boards are ``(n_cells,)`` int8 arrays
``n_actions``        distinct move ids (== ``n_cells``: a move is a cell)
``max_moves``        longest possible game (bounds the descent path length)
``init_board()``     the empty root position
``place(b, mv, p)``  set cell ``mv`` to player ``p`` (no legality check)
``legal_mask(b)``    bool ``(n_cells,)`` — all-False at TERMINAL positions,
                     which is what stops the search expanding past the end
                     of a game (Hex: empties; Gomoku: empties unless a five
                     exists)
``terminal_batch``   ``(W, n_cells) -> (W,) bool`` — no legal move remains
``playout_batch``    ``(boards, to_move, keys) -> (W,) int8`` values — one
                     fused (W, cells) evaluation of W random playouts
``playout_scalar``   the per-lane oracle twin (same RNG stream per lane;
                     bit-identical to one lane of ``playout_batch``)
``winner_batch``     terminal boards -> ``(W,)`` int8 outcomes
``replay_moves``     masked-scatter board reconstruction from a move list
``winner_probe``     ONE possibly-PARTIAL board -> int8 status: -1 ongoing,
                     0 draw, 1|2 the winner — the game-over test session
                     drivers poll between moves (unlike ``winner_batch``,
                     which assumes terminal boards)
===================  ========================================================

Conventions shared by every game (the search machinery assumes them):

- cells hold ``EMPTY`` (0) or a player id (1 | 2); players alternate
  ``p -> 3 - p``;
- playout/winner values are int8 in ``{0, 1, 2}``: the winning player id, or
  ``DRAW`` (0) for a drawn game. Hex never draws; Gomoku's full-board draw
  is the first non-win outcome through backup (credit 0.5), UCT (X_j = 0.5)
  and root merging — ``core/tree.backup_paths`` handles all three values;
- ``playout_batch`` consumes exactly one ``(n_cells,)`` uniform draw per
  lane key (the rank stream below), so scalar/batched paths and the Hex
  pre-seam RNG schedule are all bit-identical.

The conformance property suite (tests/test_game_protocol.py) runs every
registered game against these contracts.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

EMPTY = jnp.int8(0)
P1 = jnp.int8(1)
P2 = jnp.int8(2)
DRAW = jnp.int8(0)  # playout value of a drawn game


# --------------------------------------------------------------- registry ----
_REGISTRY: dict[str, Callable[[int], Any]] = {}


def stamp_game_identity(cls):
    """Make a Game NamedTuple compare/hash by TYPE as well as fields.

    Plain NamedTuples compare as tuples, so ``HexGame(7) == GomokuGame(7)``
    would be True — and a jitted function taking the game as a STATIC
    argument (e.g. ``mcts._run``) would silently reuse one game's compiled
    program for the other. Every registered game class gets stamped.
    """
    def __eq__(self, other):
        return type(other) is type(self) and tuple(self) == tuple(other)

    def __hash__(self):
        return hash((type(self).__qualname__, *self))

    cls.__eq__ = __eq__
    cls.__ne__ = lambda self, other: not __eq__(self, other)
    cls.__hash__ = __hash__
    return cls


def register_game(name: str, factory: Callable[[int], Any]) -> None:
    """Register ``factory(board_size) -> Game`` under ``name``."""
    if isinstance(factory, type) and issubclass(factory, tuple):
        stamp_game_identity(factory)
    _REGISTRY[name] = factory


def _ensure_builtin_games() -> None:
    # games self-register at import; lazy so game.py itself stays dep-free
    from repro.core import gomoku, hex  # noqa: F401


def available_games() -> tuple[str, ...]:
    _ensure_builtin_games()
    return tuple(sorted(_REGISTRY))


def make_game(name: str, board_size: int):
    """Resolve a registered game — the ``--game`` flag's single entry point."""
    _ensure_builtin_games()
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown game {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name](board_size)


# ------------------------------------------------------ shared batched ops ----
def empty_fill_ranks(boards: jnp.ndarray, keys: jax.Array) -> jnp.ndarray:
    """(W, n) rank of each cell among the lane's empties in random fill order.

    The shared core of every game's batched playout: lane w draws ONE
    ``(n,)`` uniform vector from ``keys[w]`` and the k-th smallest value
    over the empty cells marks the k-th playout move. The rank is counted
    directly — rank[i] = #{empty j : (noise_j, j) < (noise_i, i)} — one
    (W, n, n) boolean compare-and-count with the index tie-break a stable
    argsort would apply, bit-identical to the argsort formulation and
    sort-free (XLA sorts are the slow path on every backend). Non-empty
    cells get a meaningless rank; callers mask them.
    """
    W, n = boards.shape
    empties = boards == EMPTY
    noise = jax.vmap(lambda k: jax.random.uniform(k, (n,)))(keys)
    idx = jnp.arange(n, dtype=jnp.int32)
    nj, ni = noise[:, None, :], noise[:, :, None]
    earlier = (nj < ni) | ((nj == ni)
                           & (idx[None, None, :] < idx[None, :, None]))
    return jnp.sum(earlier & empties[:, None, :], axis=2)


def parity_fill_colors(ranks: jnp.ndarray, to_move) -> jnp.ndarray:
    """Stone colors of a random fill: rank parity alternates from ``to_move``."""
    W = ranks.shape[0]
    tm = jnp.broadcast_to(jnp.asarray(to_move, jnp.int32), (W,))[:, None]
    other = jnp.int32(3) - tm
    return jnp.where((ranks % 2) == 0, tm, other).astype(jnp.int8)


def replay_moves(moves: jnp.ndarray, n_moves: jnp.ndarray, first_player,
                 n_cells: int) -> jnp.ndarray:
    """Reconstruct a board from a move list (fixed-length, masked by n_moves).

    One masked scatter instead of a per-move ``fori_loop``: move i places
    the (i-even ? first : other) player's stone; moves at or past
    ``n_moves`` land on a pad cell and are dropped. Moves must target
    distinct cells (every legal game's move list does — a move is an empty
    cell); the caller is responsible for the list not running past the
    game's end.
    """
    L = moves.shape[0]
    idx = jnp.arange(L, dtype=jnp.int32)
    first_player = jnp.asarray(first_player, jnp.int32)
    players = jnp.where((idx % 2) == 0, first_player,
                        3 - first_player).astype(jnp.int8)
    tgt = jnp.where(idx < n_moves, moves, n_cells)
    board = jnp.zeros((n_cells + 1,), dtype=jnp.int8).at[tgt].set(players)
    return board[:n_cells]
