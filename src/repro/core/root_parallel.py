"""Root-parallel batched GSCPM: many trees, one jitted program (DESIGN.md §3).

The source paper scales ONE shared tree across 244 threads (tree
parallelism); its companion studies (arXiv:1409.4297, arXiv:1704.00325) use
the orthogonal axis — *root parallelism*: E independent trees search the same
(or different) root positions and their root statistics are merged. On SPMD
hardware the ensemble axis is free parallel width: the E trees are stacked
into one forest pytree (leading axis on every `Tree` leaf) and a whole GSCPM
round advances ALL of them in a single jitted dispatch — `jax.vmap` over the
single-tree chunk, sharded across devices along the ensemble axis when more
than one device is visible.

Three merge disciplines:

- **visit-sum** (``ensemble_best_move``): per-move root-child visits are
  summed across members; play the argmax. The classic root-parallel merge.
- **majority vote** (``majority_vote_move``): each member votes its own
  most-visited move; play the mode.
- **periodic sync** (``sync_root_stats``): every ``merge_every`` rounds each
  member's root-child statistics are refreshed with the *sum of every other
  member's own contribution*, so later selection is ensemble-informed.
  Contributions are tracked as deltas (``RootSyncState``), which makes the
  merge exact — repeated syncs never double-count, and after a final sync
  every member's root visit count equals the total playouts of the whole
  ensemble (tested in tests/test_root_parallel.py).

The same batching serves two workloads: an ensemble on one position
(stronger move choice) and one tree per position (multi-request serving —
see ``repro.serve.mcts_decode.mcts_decode_search_batch`` for the LM twin).
"""

from __future__ import annotations

import contextlib
import functools
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import scheduler as sched
from repro.core.gscpm import GSCPMConfig, fold_task_keys, sync_iteration
from repro.core.tree import (
    Tree,
    best_child,
    forest_member,
    forest_size,
    init_forest,
    root_move_stats,
    root_value,
)


# ----------------------------------------------------------- forest chunk ----
def _forest_chunk(forest: Tree, boards: jnp.ndarray, cfg: GSCPMConfig,
                  task_keys: jnp.ndarray, active: jnp.ndarray,
                  m: jnp.ndarray, cp, metrics=None):
    """`gscpm.run_chunk` vmapped over the ensemble axis — one program for E
    trees. All members share the round's grain `m` and traced ``cp``;
    per-member RNG streams keep their searches decorrelated. The batched
    descent's ``ops.uct_select`` tile composes with this vmap (a leading E
    axis on the (W, C) tiles — one fused (E·W, C) selection per level), and
    so does the fused playout stage: the whole forest's leaf evaluations
    become one (E·W, cells) batched ``game.playout_batch`` under vmap
    (DESIGN.md §12/§13 — for Hex a single fill + connectivity solve with
    one convergence loop) instead of E·W interleaved scalar while-loops.
    ``cfg.metrics`` threads a per-member ``SearchMetrics`` accumulator
    ((E,)-leaf pytree, ``init_search_metrics_forest``) through the same
    vmap and returns ``(forest, metrics)``."""
    if cfg.metrics != (metrics is not None):
        raise ValueError(
            "cfg.metrics and the metrics accumulator must agree: "
            f"cfg.metrics={cfg.metrics}, metrics "
            f"{'passed' if metrics is not None else 'omitted'}")

    def one_tree(tree, board, keys, act, mx):
        def body(i, carry):
            tr, acc = carry
            iter_keys = jax.vmap(lambda tk: jax.random.fold_in(tk, i))(keys)
            if cfg.metrics:
                tr, acc = sync_iteration(tr, board, cfg, cp, iter_keys,
                                         act, acc)
            else:
                tr = sync_iteration(tr, board, cfg, cp, iter_keys, act)
            return tr, acc

        return jax.lax.fori_loop(0, m, body, (tree, mx))

    if cfg.metrics:
        return jax.vmap(one_tree)(forest, boards, task_keys, active, metrics)
    forest, _ = jax.vmap(
        lambda t, b, k, a: one_tree(t, b, k, a, 0))(
            forest, boards, task_keys, active)
    return forest


run_chunk_forest = jax.jit(_forest_chunk, static_argnames=("cfg",),
                           donate_argnums=(0,))


def ensemble_mesh(devices=None):
    """The 1-D ensemble mesh over all visible devices (None on one device).

    Built through ``launch.mesh.make_ensemble_mesh`` — the same
    ``compat.make_auto_mesh`` path as the LM production meshes, with the
    ``"ens"`` axis the ``sharding/rules.py`` "ensemble" rule maps onto.
    """
    from repro.launch.mesh import make_ensemble_mesh

    devices = list(jax.devices() if devices is None else devices)
    if len(devices) <= 1:
        return None
    return make_ensemble_mesh(devices)


def ensemble_spec(mesh):
    """``P("ens")`` for the forest's leading member axis, derived through
    the logical-axis rules rather than spelled by hand."""
    from repro.sharding.rules import DEFAULT_RULES, logical_to_spec

    return logical_to_spec(("ensemble",), DEFAULT_RULES, mesh)


def ensemble_sharding(n_trees: int, mesh=None):
    """(NamedSharding over the ensemble axis, padded member count).

    vmap batching is embarrassingly parallel, so placing the forest with its
    leading axis sharded lets XLA partition the whole chunk — the multi-chip
    analogue of the paper's per-thread trees (DESIGN.md §3/§9). Returns
    ``(None, n_trees)`` with fewer than two devices. A member count that
    does not divide the mesh is PADDED up to the next multiple (the second
    return value) instead of the old silent fall-back to unsharded: pad
    members only ever run under all-False ``active`` masks, which leaves
    their trees bit-identical to init and their contribution to every merge
    exactly zero, so real members match the unpadded, unsharded run bit for
    bit (pinned in tests/test_forest_sharding.py).
    """
    mesh = ensemble_mesh() if mesh is None else mesh
    if mesh is None:
        return None, n_trees
    n_dev = int(np.prod(mesh.devices.shape))
    padded = ((n_trees + n_dev - 1) // n_dev) * n_dev
    return jax.sharding.NamedSharding(mesh, ensemble_spec(mesh)), padded


def pad_forest_members(forest: Tree, boards: jnp.ndarray, n_padded: int,
                       cfg: GSCPMConfig, to_move) -> tuple[Tree, jnp.ndarray]:
    """Append inert members until the ensemble axis has ``n_padded`` rows.

    Pad members get fresh init trees and a copy of member 0's board; they
    only ever run with all-False ``active`` masks, so they allocate nothing
    and back up nothing. Callers slice results back to the real count.
    """
    extra = n_padded - forest_size(forest)
    if extra <= 0:
        return forest, boards
    tm = int(np.asarray(to_move).reshape(-1)[0])
    pad = init_forest(extra, cfg.tree_cap, cfg.game_obj.n_actions, tm)
    forest = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), forest, pad)
    boards = jnp.concatenate([boards, jnp.tile(boards[:1], (extra, 1))])
    return forest, boards


@functools.partial(jax.jit, static_argnames=("cfg", "mesh"),
                   donate_argnums=(0,))
def _sharded_chunk(forest, boards, task_keys, active, m, cp, *, cfg, mesh):
    """``shard_map``-partitioned forest chunk: each device runs the vmapped
    per-round body (``_forest_chunk``, unchanged) on its own members with
    ZERO collectives — ``sync_root_stats``, dispatched outside this
    program, stays the only cross-shard exchange. Per-shard RNG is free:
    ``task_keys`` ride in pre-folded and sharded along the ensemble axis,
    so a member's stream is identical no matter which shard hosts it — the
    bit-identity pin of tests/test_forest_sharding.py."""
    spec, rep = ensemble_spec(mesh), jax.sharding.PartitionSpec()
    body = compat.shard_map(
        lambda f, b, k, a, mm, c: _forest_chunk(f, b, cfg, k, a, mm, c),
        mesh=mesh, in_specs=(spec, spec, spec, spec, rep, rep),
        out_specs=spec)
    return body(forest, boards, task_keys, active, m, cp)


@functools.partial(jax.jit, static_argnames=("cfg", "mesh"),
                   donate_argnums=(0,))
def _sharded_chunk_metrics(forest, boards, task_keys, active, m, cp, metrics,
                           *, cfg, mesh):
    """``_sharded_chunk`` with the (E,)-leaf ``SearchMetrics`` accumulator
    riding the same ensemble sharding (pad members see only masked-out
    work; callers slice summaries to the real members)."""
    spec, rep = ensemble_spec(mesh), jax.sharding.PartitionSpec()
    body = compat.shard_map(
        lambda f, b, k, a, mm, c, mx: _forest_chunk(
            f, b, cfg, k, a, mm, c, mx),
        mesh=mesh, in_specs=(spec, spec, spec, spec, rep, rep, spec),
        out_specs=(spec, spec))
    return body(forest, boards, task_keys, active, m, cp, metrics)


@jax.jit
def fold_member_task_keys(member_keys: jax.Array,
                          task_ids: jnp.ndarray) -> jax.Array:
    """(E,) member streams × (W,) task ids -> (E, W) per-lane streams
    (jitted so per-round key building is dispatch-only)."""
    return jax.vmap(lambda mk: jax.vmap(
        lambda t: jax.random.fold_in(mk, t))(task_ids))(member_keys)


def run_schedule_round_forest(forest: Tree, boards: jnp.ndarray,
                              cfg: GSCPMConfig, member_keys: jax.Array,
                              rnd: sched.Round, cp, metrics=None, *,
                              n_real: int | None = None, mesh=None):
    """Forest twin of ``gscpm.run_schedule_round``: one schedule ``Round``
    for all E members in ONE dispatch — the atomic quantum unit shared by
    the batch driver (``gscpm_search_batch``) and the serving engine
    (``repro.serve.games`` forest tenants), which makes the serving-
    equivalence argument structural: both call the same function with the
    same operands. Round RNG depends only on (member key, task id,
    iteration), never on sharding, padding, or wall-clock interleaving.

    ``n_real`` masks sharding pad members (rows ``>= n_real`` run with
    all-False ``active`` — bitwise inert); ``mesh`` dispatches the
    ``shard_map``-partitioned chunk instead of the single-device one.
    With ``cfg.metrics`` returns ``(forest, metrics)``.
    """
    Ep = forest_size(forest)
    task_keys = fold_member_task_keys(
        member_keys, jnp.asarray(rnd.task_ids, dtype=jnp.int32))
    act = np.tile(np.asarray(rnd.active)[None, :], (Ep, 1))
    if n_real is not None and n_real < Ep:
        act[n_real:] = False
    active = jnp.asarray(act)
    m = jnp.asarray(rnd.m, dtype=jnp.int32)
    if mesh is not None:
        if cfg.metrics:
            return _sharded_chunk_metrics(forest, boards, task_keys, active,
                                          m, cp, metrics, cfg=cfg, mesh=mesh)
        return _sharded_chunk(forest, boards, task_keys, active, m, cp,
                              cfg=cfg, mesh=mesh)
    return run_chunk_forest(forest, boards, cfg, task_keys, active, m, cp,
                            metrics)


# ----------------------------------------------------------------- merges ----
@functools.partial(jax.jit, static_argnames=("n_moves",))
def merged_root_stats(forest: Tree, n_moves: int):
    """Summed per-move root (visits, wins) across members: (n_moves,) each."""
    v, w = jax.vmap(lambda t: root_move_stats(t, n_moves))(forest)
    return v.sum(axis=0), w.sum(axis=0)


def ensemble_best_move(forest: Tree, n_moves: int) -> jnp.ndarray:
    """Visit-sum merge: argmax of summed root-child visits."""
    visits, _ = merged_root_stats(forest, n_moves)
    return jnp.argmax(visits).astype(jnp.int32)


def majority_vote_move(forest: Tree, n_moves: int) -> jnp.ndarray:
    """Mode of the per-member most-visited moves (ties -> lowest move id)."""
    votes = jax.vmap(best_child)(forest)  # (E,)
    counts = jnp.zeros((n_moves,), jnp.int32).at[
        jnp.clip(votes, 0, n_moves - 1)].add(1)
    return jnp.argmax(counts).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_moves",))
def forest_summary(forest: Tree, n_moves: int) -> dict[str, jnp.ndarray]:
    """All end-of-search reductions in one jitted program (a driver that
    computes them eagerly pays several vmap re-traces per search)."""
    visits, _ = merged_root_stats(forest, n_moves)
    return {
        "member_best_moves": jax.vmap(best_child)(forest),
        "member_root_values": jax.vmap(root_value)(forest),
        "best_move_sum": jnp.argmax(visits).astype(jnp.int32),
        "best_move_vote": majority_vote_move(forest, n_moves),
    }


@functools.partial(jax.jit, static_argnames=("n_moves",))
def forest_retire_summary(forest: Tree, n_moves: int) -> dict:
    """Device-side merged root snapshot of a forest in ONE jitted program.

    The forest twin of ``tree.root_summary_device``: the pipelined serving
    engine dispatches this at retirement detection (async) and materializes
    the result a tick later, so the readback overlaps the next tick's
    quanta (DESIGN.md §18). Merged ``best_move`` follows the single-tree
    contract: ``-1`` when no member has expanded a root child yet.
    """
    visits, wins = merged_root_stats(forest, n_moves)
    rv = forest.visits[:, 0].sum()
    rw = forest.wins[:, 0].sum()
    return {
        "root_visits": visits,
        "root_wins": wins,
        "best_move": jnp.where(visits.sum() > 0, jnp.argmax(visits),
                               -1).astype(jnp.int32),
        "best_move_vote": majority_vote_move(forest, n_moves),
        "member_best_moves": jax.vmap(best_child)(forest),
        "root_value": jnp.where(rv > 0, rw / jnp.maximum(rv, 1.0), 0.0),
        "tree_nodes": forest.n_nodes.sum(),
    }


def forest_root_summary(forest: Tree, n_moves: int,
                        n_real: int | None = None) -> dict:
    """Host-side merged root snapshot — the retire currency of forest
    tenants (``repro.serve.games``), shaped like ``core/tree.root_summary``
    so the result guard and clients read both identically, plus ensemble
    extras (vote move, per-member best moves). ``n_real`` slices off
    sharding pad members first."""
    if n_real is not None and n_real < forest_size(forest):
        forest = jax.tree.map(lambda x: x[:n_real], forest)
    dev = jax.device_get(forest_retire_summary(forest, n_moves))
    return materialize_forest_summary(dev, forest_size(forest))


def materialize_forest_summary(dev: dict, n_trees: int) -> dict:
    """Pull a ``forest_retire_summary`` device dict to plain host types
    (split out so the pipelined engine can defer exactly this step)."""
    return {
        "root_visits": np.asarray(dev["root_visits"]),
        "root_wins": np.asarray(dev["root_wins"]),
        "best_move": int(dev["best_move"]),
        "root_value": float(dev["root_value"]),
        "tree_nodes": int(dev["tree_nodes"]),
        "n_trees": n_trees,
        "best_move_vote": int(dev["best_move_vote"]),
        "member_best_moves": np.asarray(dev["member_best_moves"]).tolist(),
    }


# ---------------------------------------------------------- periodic sync ----
class RootSyncState(NamedTuple):
    """Foreign (other-member) statistics already injected into each tree.

    Tracking what was injected lets ``sync_root_stats`` recover each member's
    OWN contribution exactly (own = in-tree − injected), so the merge never
    double-counts across repeated syncs.
    """

    visits: jnp.ndarray       # (E, n_moves) f32 injected per-move visits
    wins: jnp.ndarray         # (E, n_moves) f32 injected per-move wins
    root_visits: jnp.ndarray  # (E,) f32 injected root-node visits
    root_wins: jnp.ndarray    # (E,) f32 injected root-node wins


def init_sync_state(n_trees: int, n_moves: int) -> RootSyncState:
    z = jnp.zeros((n_trees, n_moves), jnp.float32)
    z1 = jnp.zeros((n_trees,), jnp.float32)
    return RootSyncState(visits=z, wins=z, root_visits=z1, root_wins=z1)


@functools.partial(jax.jit, static_argnames=("n_moves",))
def sync_root_stats(forest: Tree, state: RootSyncState, n_moves: int
                    ) -> tuple[Tree, RootSyncState]:
    """Refresh every member's root stats with the other members' own work.

    After the call, member e's root child for move a holds
    ``own_e(a) + Σ_{e'≠e} own_e'(a)`` — for the moves e has expanded; moves a
    member has not discovered receive nothing (it cannot host a child row
    for them), which is the standard root-parallel partial-merge semantics.
    """
    dense_v, dense_w = jax.vmap(lambda t: root_move_stats(t, n_moves))(forest)
    own_v = dense_v - state.visits            # (E, M) each member's own work
    own_w = dense_w - state.wins
    new_f_v = own_v.sum(axis=0)[None, :] - own_v   # Σ others' own
    new_f_w = own_w.sum(axis=0)[None, :] - own_w
    own_rv = forest.visits[:, 0] - state.root_visits
    own_rw = forest.wins[:, 0] - state.root_wins
    new_f_rv = own_rv.sum() - own_rv
    new_f_rw = own_rw.sum() - own_rw

    def write(tree, old_fv, old_fw, nfv, nfw, d_rv, d_rw):
        cap = tree.cap
        slots = tree.children[0]
        valid = jnp.arange(slots.shape[0]) < tree.n_children[0]
        safe = jnp.where(valid, slots, cap)
        mv = jnp.clip(jnp.where(valid, tree.move[safe], 0), 0, n_moves - 1)
        visits = tree.visits.at[safe].add(
            jnp.where(valid, nfv[mv] - old_fv[mv], 0.0))
        wins = tree.wins.at[safe].add(
            jnp.where(valid, nfw[mv] - old_fw[mv], 0.0))
        visits = visits.at[cap].set(0.0).at[0].add(d_rv)
        wins = wins.at[cap].set(0.0).at[0].add(d_rw)
        # record only what was actually injected (moves with a child row)
        has = jnp.zeros((n_moves + 1,), bool).at[
            jnp.where(valid, mv, n_moves)].set(True)[:n_moves]
        rec_v = jnp.where(has, nfv, 0.0)
        rec_w = jnp.where(has, nfw, 0.0)
        return tree._replace(visits=visits, wins=wins), rec_v, rec_w

    forest, rec_v, rec_w = jax.vmap(write)(
        forest, state.visits, state.wins, new_f_v, new_f_w,
        new_f_rv - state.root_visits, new_f_rw - state.root_wins)
    return forest, RootSyncState(visits=rec_v, wins=rec_w,
                                 root_visits=new_f_rv, root_wins=new_f_rw)


# ------------------------------------------------------------------ driver ----
def gscpm_search_batch(boards: jnp.ndarray, to_move, cfg: GSCPMConfig,
                       key: jax.Array, *, n_trees: int | None = None,
                       merge_every: int = 0, forest: Tree | None = None,
                       shard: str = "auto",
                       tracer=None) -> tuple[Tree, dict[str, Any]]:
    """Root-parallel GSCPM over E trees in one jitted program per round.

    boards: (E, n_cells) — one root position per member (multi-request
    search), or (n_cells,) with ``n_trees=E`` — an E-member ensemble on one
    position. ``to_move`` is scalar or (E,). ``merge_every > 0`` enables
    periodic root synchronization (plus a final sync before move selection).

    ``forest`` warm-starts all E members from an existing forest — typically
    ``reroot_forest``'s output after a move (DESIGN.md §16). The member
    count must match the boards batch; as with the single-tree warm start
    the schedule stays exactly ``cfg``'s and the forest's buffers are
    donated to the first chunk.

    Per-round work is ONE dispatch of ``run_schedule_round_forest`` — no
    per-tree Python loop. ``shard`` controls the multi-device path:
    ``"auto"`` partitions the ensemble axis over the ``shard_map`` forest
    step whenever more than one device is visible (padding E up to the
    device count when it does not divide — pad members are bitwise inert),
    ``"off"`` forces the single-device dispatch, ``"require"`` raises
    unless a real mesh is available (CI uses it to assert the sharded path
    actually ran sharded). The sharded search is bit-identical to the
    unsharded one: per-member RNG and compute never depend on placement,
    and the only cross-shard exchange is ``sync_root_stats``' exact
    delta-tracked merge, whose integer/half-integer float32 sums are
    order-independent. ``cfg.metrics`` adds a whole-ensemble
    ``stats["metrics"]`` summary; ``tracer`` records per-round
    ``gscpm_round`` spans (blocking per round, a profiling mode — see
    ``gscpm.gscpm_search``).
    """
    boards = jnp.asarray(boards)
    if boards.ndim == 1:
        if n_trees is None and forest is not None:
            n_trees = forest_size(forest)   # warm restart implies E
        boards = jnp.tile(boards[None, :], (n_trees or 1, 1))
    E = boards.shape[0]
    if n_trees is not None and n_trees != E:
        raise ValueError(f"n_trees={n_trees} != boards.shape[0]={E}")
    if shard not in ("auto", "off", "require"):
        raise ValueError(f"shard must be 'auto'|'off'|'require', "
                         f"got {shard!r}")
    n_moves = cfg.game_obj.n_actions  # the Game seam's move-id space

    reused_nodes = 0
    if forest is None:
        forest = init_forest(E, cfg.tree_cap, n_moves, to_move)
    else:
        if forest_size(forest) != E:
            raise ValueError(
                f"warm forest has {forest_size(forest)} members, "
                f"boards batch has {E}")
        from repro.core.gscpm import warm_tree_check
        tm = int(np.asarray(to_move).reshape(-1)[0])
        warm_tree_check(forest, tm, cfg)
        reused_nodes = int(np.asarray(forest.n_nodes).sum()) - E
    mesh = ensemble_mesh() if shard != "off" else None
    if shard == "require" and mesh is None:
        raise RuntimeError(
            "shard='require' but fewer than two devices are visible — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 BEFORE "
            "importing jax (README 'Scaling out')")
    padded_members = 0
    Ep = E
    if mesh is not None:
        sharding, Ep = ensemble_sharding(E, mesh)
        padded_members = Ep - E
        forest, boards = pad_forest_members(forest, boards, Ep, cfg, to_move)
        member_keys = fold_task_keys(key, jnp.arange(Ep, dtype=jnp.int32))
        forest, boards, member_keys = jax.device_put(
            (forest, boards, member_keys), sharding)
    else:
        member_keys = fold_task_keys(key, jnp.arange(E, dtype=jnp.int32))
    schedule = sched.make_schedule(
        cfg.n_playouts, cfg.n_tasks, cfg.n_workers, cfg.scheduler)
    state = init_sync_state(Ep, n_moves) if merge_every > 0 else None
    metrics = None
    if cfg.metrics:
        from repro.obsv.search_metrics import init_search_metrics_forest
        metrics = init_search_metrics_forest(Ep)
        if reused_nodes:
            # per-member retention gauge (summed in the ensemble summary;
            # pad members report 0 — their forests are fresh inits)
            metrics = metrics._replace(
                tree_nodes_reused=(forest.n_nodes - 1).astype(jnp.int32))

    cp = jnp.asarray(cfg.cp, jnp.float32)
    t0 = time.perf_counter()
    playouts_per_tree = 0
    n_syncs = 0
    for r, rnd in enumerate(schedule):
        span_args = {"rounds": 1, "iterations": int(rnd.m),
                     "lane_iterations": E * int(rnd.active.sum()) * rnd.m,
                     "tasks": E * int(rnd.active.sum()),
                     "workers": E * cfg.n_workers, "game": cfg.game}
        with (tracer.span("gscpm_round", span_args) if tracer
              else contextlib.nullcontext()):
            out = run_schedule_round_forest(forest, boards, cfg, member_keys,
                                            rnd, cp, metrics, n_real=E,
                                            mesh=mesh)
            forest, metrics = out if cfg.metrics else (out, metrics)
            if tracer:
                jax.block_until_ready(forest.visits)
        playouts_per_tree += int(rnd.active.sum()) * rnd.m
        if merge_every > 0 and ((r + 1) % merge_every == 0
                                or r == len(schedule) - 1):
            forest, state = sync_root_stats(forest, state, n_moves)
            n_syncs += 1
    jax.block_until_ready(forest.visits)
    dt = time.perf_counter() - t0

    if padded_members:
        forest = jax.tree.map(lambda x: x[:E], forest)
        if cfg.metrics:
            metrics = jax.tree.map(lambda x: x[:E], metrics)
    playouts = E * playouts_per_tree
    summary = jax.device_get(forest_summary(forest, n_moves))
    stats = {
        "time_s": dt,
        "n_trees": E,
        "playouts": playouts,
        "playouts_per_tree": playouts_per_tree,
        "playouts_per_s": playouts / max(dt, 1e-9),
        "rounds": len(schedule),
        "grain": cfg.grain,
        "n_syncs": n_syncs,
        "sharded": mesh is not None,
        "n_devices": (1 if mesh is None
                      else int(np.prod(mesh.devices.shape))),
        "mesh_shape": (None if mesh is None
                       else dict(zip(mesh.axis_names,
                                     (int(d) for d in mesh.devices.shape)))),
        "padded_members": padded_members,
        "tree_nodes": [int(n) for n in np.asarray(forest.n_nodes)],
        "member_best_moves": summary["member_best_moves"].tolist(),
        "member_root_values": summary["member_root_values"].tolist(),
        "best_move_sum": int(summary["best_move_sum"]),
        "best_move_vote": int(summary["best_move_vote"]),
    }
    if reused_nodes:
        stats["reused_nodes"] = reused_nodes
    if cfg.metrics:
        from repro.obsv.search_metrics import summarize_metrics
        stats["metrics"] = summarize_metrics(metrics)
    return forest, stats


def check_forest_invariants(forest: Tree, *,
                            discrete_credits: bool = True) -> None:
    """Per-member structural invariants (host-side, used by tests).

    ``discrete_credits=False`` for token-tree forests backed up with
    continuous values (see ``tree.check_invariants``).
    """
    from repro.core.tree import check_invariants

    for e in range(forest_size(forest)):
        check_invariants(forest_member(forest, e),
                         discrete_credits=discrete_credits)
