"""Root-parallel batched GSCPM: many trees, one jitted program (DESIGN.md §3).

The source paper scales ONE shared tree across 244 threads (tree
parallelism); its companion studies (arXiv:1409.4297, arXiv:1704.00325) use
the orthogonal axis — *root parallelism*: E independent trees search the same
(or different) root positions and their root statistics are merged. On SPMD
hardware the ensemble axis is free parallel width: the E trees are stacked
into one forest pytree (leading axis on every `Tree` leaf) and a whole GSCPM
round advances ALL of them in a single jitted dispatch — `jax.vmap` over the
single-tree chunk, sharded across devices along the ensemble axis when more
than one device is visible.

Three merge disciplines:

- **visit-sum** (``ensemble_best_move``): per-move root-child visits are
  summed across members; play the argmax. The classic root-parallel merge.
- **majority vote** (``majority_vote_move``): each member votes its own
  most-visited move; play the mode.
- **periodic sync** (``sync_root_stats``): every ``merge_every`` rounds each
  member's root-child statistics are refreshed with the *sum of every other
  member's own contribution*, so later selection is ensemble-informed.
  Contributions are tracked as deltas (``RootSyncState``), which makes the
  merge exact — repeated syncs never double-count, and after a final sync
  every member's root visit count equals the total playouts of the whole
  ensemble (tested in tests/test_root_parallel.py).

The same batching serves two workloads: an ensemble on one position
(stronger move choice) and one tree per position (multi-request serving —
see ``repro.serve.mcts_decode.mcts_decode_search_batch`` for the LM twin).
"""

from __future__ import annotations

import contextlib
import functools
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sched
from repro.core.gscpm import GSCPMConfig, fold_task_keys, sync_iteration
from repro.core.tree import (
    Tree,
    best_child,
    forest_member,
    forest_size,
    init_forest,
    root_move_stats,
    root_value,
)


# ----------------------------------------------------------- forest chunk ----
def _forest_chunk(forest: Tree, boards: jnp.ndarray, cfg: GSCPMConfig,
                  task_keys: jnp.ndarray, active: jnp.ndarray,
                  m: jnp.ndarray, cp, metrics=None):
    """`gscpm.run_chunk` vmapped over the ensemble axis — one program for E
    trees. All members share the round's grain `m` and traced ``cp``;
    per-member RNG streams keep their searches decorrelated. The batched
    descent's ``ops.uct_select`` tile composes with this vmap (a leading E
    axis on the (W, C) tiles — one fused (E·W, C) selection per level), and
    so does the fused playout stage: the whole forest's leaf evaluations
    become one (E·W, cells) batched ``game.playout_batch`` under vmap
    (DESIGN.md §12/§13 — for Hex a single fill + connectivity solve with
    one convergence loop) instead of E·W interleaved scalar while-loops.
    ``cfg.metrics`` threads a per-member ``SearchMetrics`` accumulator
    ((E,)-leaf pytree, ``init_search_metrics_forest``) through the same
    vmap and returns ``(forest, metrics)``."""
    if cfg.metrics != (metrics is not None):
        raise ValueError(
            "cfg.metrics and the metrics accumulator must agree: "
            f"cfg.metrics={cfg.metrics}, metrics "
            f"{'passed' if metrics is not None else 'omitted'}")

    def one_tree(tree, board, keys, act, mx):
        def body(i, carry):
            tr, acc = carry
            iter_keys = jax.vmap(lambda tk: jax.random.fold_in(tk, i))(keys)
            if cfg.metrics:
                tr, acc = sync_iteration(tr, board, cfg, cp, iter_keys,
                                         act, acc)
            else:
                tr = sync_iteration(tr, board, cfg, cp, iter_keys, act)
            return tr, acc

        return jax.lax.fori_loop(0, m, body, (tree, mx))

    if cfg.metrics:
        return jax.vmap(one_tree)(forest, boards, task_keys, active, metrics)
    forest, _ = jax.vmap(
        lambda t, b, k, a: one_tree(t, b, k, a, 0))(
            forest, boards, task_keys, active)
    return forest


run_chunk_forest = jax.jit(_forest_chunk, static_argnames=("cfg",),
                           donate_argnums=(0,))


def ensemble_sharding(n_trees: int):
    """NamedSharding splitting the ensemble axis over devices (or None).

    vmap batching is embarrassingly parallel, so placing the forest with its
    leading axis sharded lets XLA partition the whole chunk — the multi-chip
    analogue of the paper's per-thread trees (DESIGN.md §3/§9).
    """
    devices = jax.devices()
    if len(devices) <= 1 or n_trees % len(devices) != 0:
        return None
    mesh = jax.sharding.Mesh(np.asarray(devices), ("ens",))
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("ens"))


@jax.jit
def fold_member_task_keys(member_keys: jax.Array,
                          task_ids: jnp.ndarray) -> jax.Array:
    """(E,) member streams × (W,) task ids -> (E, W) per-lane streams
    (jitted so per-round key building is dispatch-only)."""
    return jax.vmap(lambda mk: jax.vmap(
        lambda t: jax.random.fold_in(mk, t))(task_ids))(member_keys)


# ----------------------------------------------------------------- merges ----
@functools.partial(jax.jit, static_argnames=("n_moves",))
def merged_root_stats(forest: Tree, n_moves: int):
    """Summed per-move root (visits, wins) across members: (n_moves,) each."""
    v, w = jax.vmap(lambda t: root_move_stats(t, n_moves))(forest)
    return v.sum(axis=0), w.sum(axis=0)


def ensemble_best_move(forest: Tree, n_moves: int) -> jnp.ndarray:
    """Visit-sum merge: argmax of summed root-child visits."""
    visits, _ = merged_root_stats(forest, n_moves)
    return jnp.argmax(visits).astype(jnp.int32)


def majority_vote_move(forest: Tree, n_moves: int) -> jnp.ndarray:
    """Mode of the per-member most-visited moves (ties -> lowest move id)."""
    votes = jax.vmap(best_child)(forest)  # (E,)
    counts = jnp.zeros((n_moves,), jnp.int32).at[
        jnp.clip(votes, 0, n_moves - 1)].add(1)
    return jnp.argmax(counts).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_moves",))
def forest_summary(forest: Tree, n_moves: int) -> dict[str, jnp.ndarray]:
    """All end-of-search reductions in one jitted program (a driver that
    computes them eagerly pays several vmap re-traces per search)."""
    visits, _ = merged_root_stats(forest, n_moves)
    return {
        "member_best_moves": jax.vmap(best_child)(forest),
        "member_root_values": jax.vmap(root_value)(forest),
        "best_move_sum": jnp.argmax(visits).astype(jnp.int32),
        "best_move_vote": majority_vote_move(forest, n_moves),
    }


# ---------------------------------------------------------- periodic sync ----
class RootSyncState(NamedTuple):
    """Foreign (other-member) statistics already injected into each tree.

    Tracking what was injected lets ``sync_root_stats`` recover each member's
    OWN contribution exactly (own = in-tree − injected), so the merge never
    double-counts across repeated syncs.
    """

    visits: jnp.ndarray       # (E, n_moves) f32 injected per-move visits
    wins: jnp.ndarray         # (E, n_moves) f32 injected per-move wins
    root_visits: jnp.ndarray  # (E,) f32 injected root-node visits
    root_wins: jnp.ndarray    # (E,) f32 injected root-node wins


def init_sync_state(n_trees: int, n_moves: int) -> RootSyncState:
    z = jnp.zeros((n_trees, n_moves), jnp.float32)
    z1 = jnp.zeros((n_trees,), jnp.float32)
    return RootSyncState(visits=z, wins=z, root_visits=z1, root_wins=z1)


@functools.partial(jax.jit, static_argnames=("n_moves",))
def sync_root_stats(forest: Tree, state: RootSyncState, n_moves: int
                    ) -> tuple[Tree, RootSyncState]:
    """Refresh every member's root stats with the other members' own work.

    After the call, member e's root child for move a holds
    ``own_e(a) + Σ_{e'≠e} own_e'(a)`` — for the moves e has expanded; moves a
    member has not discovered receive nothing (it cannot host a child row
    for them), which is the standard root-parallel partial-merge semantics.
    """
    dense_v, dense_w = jax.vmap(lambda t: root_move_stats(t, n_moves))(forest)
    own_v = dense_v - state.visits            # (E, M) each member's own work
    own_w = dense_w - state.wins
    new_f_v = own_v.sum(axis=0)[None, :] - own_v   # Σ others' own
    new_f_w = own_w.sum(axis=0)[None, :] - own_w
    own_rv = forest.visits[:, 0] - state.root_visits
    own_rw = forest.wins[:, 0] - state.root_wins
    new_f_rv = own_rv.sum() - own_rv
    new_f_rw = own_rw.sum() - own_rw

    def write(tree, old_fv, old_fw, nfv, nfw, d_rv, d_rw):
        cap = tree.cap
        slots = tree.children[0]
        valid = jnp.arange(slots.shape[0]) < tree.n_children[0]
        safe = jnp.where(valid, slots, cap)
        mv = jnp.clip(jnp.where(valid, tree.move[safe], 0), 0, n_moves - 1)
        visits = tree.visits.at[safe].add(
            jnp.where(valid, nfv[mv] - old_fv[mv], 0.0))
        wins = tree.wins.at[safe].add(
            jnp.where(valid, nfw[mv] - old_fw[mv], 0.0))
        visits = visits.at[cap].set(0.0).at[0].add(d_rv)
        wins = wins.at[cap].set(0.0).at[0].add(d_rw)
        # record only what was actually injected (moves with a child row)
        has = jnp.zeros((n_moves + 1,), bool).at[
            jnp.where(valid, mv, n_moves)].set(True)[:n_moves]
        rec_v = jnp.where(has, nfv, 0.0)
        rec_w = jnp.where(has, nfw, 0.0)
        return tree._replace(visits=visits, wins=wins), rec_v, rec_w

    forest, rec_v, rec_w = jax.vmap(write)(
        forest, state.visits, state.wins, new_f_v, new_f_w,
        new_f_rv - state.root_visits, new_f_rw - state.root_wins)
    return forest, RootSyncState(visits=rec_v, wins=rec_w,
                                 root_visits=new_f_rv, root_wins=new_f_rw)


# ------------------------------------------------------------------ driver ----
def gscpm_search_batch(boards: jnp.ndarray, to_move, cfg: GSCPMConfig,
                       key: jax.Array, *, n_trees: int | None = None,
                       merge_every: int = 0, forest: Tree | None = None,
                       tracer=None) -> tuple[Tree, dict[str, Any]]:
    """Root-parallel GSCPM over E trees in one jitted program per round.

    boards: (E, n_cells) — one root position per member (multi-request
    search), or (n_cells,) with ``n_trees=E`` — an E-member ensemble on one
    position. ``to_move`` is scalar or (E,). ``merge_every > 0`` enables
    periodic root synchronization (plus a final sync before move selection).

    ``forest`` warm-starts all E members from an existing forest — typically
    ``reroot_forest``'s output after a move (DESIGN.md §16). The member
    count must match the boards batch; as with the single-tree warm start
    the schedule stays exactly ``cfg``'s and the forest's buffers are
    donated to the first chunk.

    Per-round work is ONE dispatch of ``run_chunk_forest`` — no per-tree
    Python loop; with multiple devices the ensemble axis is sharded.
    ``cfg.metrics`` adds a whole-ensemble ``stats["metrics"]`` summary;
    ``tracer`` records per-round ``gscpm_round`` spans (blocking per round,
    a profiling mode — see ``gscpm.gscpm_search``).
    """
    boards = jnp.asarray(boards)
    if boards.ndim == 1:
        if n_trees is None and forest is not None:
            n_trees = forest_size(forest)   # warm restart implies E
        boards = jnp.tile(boards[None, :], (n_trees or 1, 1))
    E = boards.shape[0]
    if n_trees is not None and n_trees != E:
        raise ValueError(f"n_trees={n_trees} != boards.shape[0]={E}")
    n_moves = cfg.game_obj.n_actions  # the Game seam's move-id space

    reused_nodes = 0
    if forest is None:
        forest = init_forest(E, cfg.tree_cap, n_moves, to_move)
    else:
        if forest_size(forest) != E:
            raise ValueError(
                f"warm forest has {forest_size(forest)} members, "
                f"boards batch has {E}")
        from repro.core.gscpm import warm_tree_check
        tm = int(np.asarray(to_move).reshape(-1)[0])
        warm_tree_check(forest, tm, cfg)
        reused_nodes = int(np.asarray(forest.n_nodes).sum()) - E
    member_keys = fold_task_keys(key, jnp.arange(E, dtype=jnp.int32))
    sharding = ensemble_sharding(E)
    if sharding is not None:
        forest, boards, member_keys = jax.device_put(
            (forest, boards, member_keys), sharding)
    schedule = sched.make_schedule(
        cfg.n_playouts, cfg.n_tasks, cfg.n_workers, cfg.scheduler)
    state = init_sync_state(E, n_moves) if merge_every > 0 else None
    metrics = None
    if cfg.metrics:
        from repro.obsv.search_metrics import init_search_metrics_forest
        metrics = init_search_metrics_forest(E)
        if reused_nodes:
            # per-member retention gauge (summed in the ensemble summary)
            metrics = metrics._replace(
                tree_nodes_reused=(forest.n_nodes - 1).astype(jnp.int32))

    cp = jnp.asarray(cfg.cp, jnp.float32)
    t0 = time.perf_counter()
    playouts_per_tree = 0
    n_syncs = 0
    for r, rnd in enumerate(schedule):
        task_keys = fold_member_task_keys(
            member_keys, jnp.asarray(rnd.task_ids, dtype=jnp.int32))
        active = jnp.tile(jnp.asarray(rnd.active)[None, :], (E, 1))
        span_args = {"rounds": 1, "iterations": int(rnd.m),
                     "lane_iterations": E * int(rnd.active.sum()) * rnd.m,
                     "tasks": E * int(rnd.active.sum()),
                     "workers": E * cfg.n_workers, "game": cfg.game}
        with (tracer.span("gscpm_round", span_args) if tracer
              else contextlib.nullcontext()):
            out = run_chunk_forest(forest, boards, cfg, task_keys, active,
                                   jnp.asarray(rnd.m, dtype=jnp.int32), cp,
                                   metrics)
            forest, metrics = out if cfg.metrics else (out, metrics)
            if tracer:
                jax.block_until_ready(forest.visits)
        playouts_per_tree += int(rnd.active.sum()) * rnd.m
        if merge_every > 0 and ((r + 1) % merge_every == 0
                                or r == len(schedule) - 1):
            forest, state = sync_root_stats(forest, state, n_moves)
            n_syncs += 1
    jax.block_until_ready(forest.visits)
    dt = time.perf_counter() - t0

    playouts = E * playouts_per_tree
    summary = jax.device_get(forest_summary(forest, n_moves))
    stats = {
        "time_s": dt,
        "n_trees": E,
        "playouts": playouts,
        "playouts_per_tree": playouts_per_tree,
        "playouts_per_s": playouts / max(dt, 1e-9),
        "rounds": len(schedule),
        "grain": cfg.grain,
        "n_syncs": n_syncs,
        "sharded": sharding is not None,
        "tree_nodes": [int(n) for n in np.asarray(forest.n_nodes)],
        "member_best_moves": summary["member_best_moves"].tolist(),
        "member_root_values": summary["member_root_values"].tolist(),
        "best_move_sum": int(summary["best_move_sum"]),
        "best_move_vote": int(summary["best_move_vote"]),
    }
    if reused_nodes:
        stats["reused_nodes"] = reused_nodes
    if cfg.metrics:
        from repro.obsv.search_metrics import summarize_metrics
        stats["metrics"] = summarize_metrics(metrics)
    return forest, stats


def check_forest_invariants(forest: Tree, *,
                            discrete_credits: bool = True) -> None:
    """Per-member structural invariants (host-side, used by tests).

    ``discrete_credits=False`` for token-tree forests backed up with
    continuous values (see ``tree.check_invariants``).
    """
    from repro.core.tree import check_invariants

    for e in range(forest_size(forest)):
        check_invariants(forest_member(forest, e),
                         discrete_credits=discrete_credits)
