"""Roofline terms from a compiled dry-run cell (EXPERIMENTS.md §Roofline).

    compute    = flops_per_chip / PEAK_FLOPS_BF16
    memory     = hbm_bytes_per_chip / HBM_BW
    collective = wire_bytes_per_chip / ICI_LINK_BW

All inputs are PER-CHIP because ``compiled.cost_analysis()`` and the parsed
HLO describe one device's SPMD program — dividing global quantities by chip
count (the spec formula) and using per-chip numbers directly are the same
thing for a balanced SPMD program.

MODEL_FLOPS is the analytic useful work:
    train:   6 * N_active * tokens   (fwd 2ND + bwd 4ND)
    prefill: 2 * N_active * tokens
    decode:  2 * N_active * batch    (one token per slot)
(+ the attention S^2 term, reported separately since 6ND ignores it).
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.models.common import ModelConfig
from repro.roofline import hw


def active_params(cfg: ModelConfig, n_params: int) -> int:
    """Parameters touched per token (MoE: shared + top-k routed only)."""
    if cfg.family != "moe":
        return n_params
    ff = cfg.moe_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * ff
    routed_total = cfg.n_experts * per_expert
    moe_layers = cfg.n_layers - cfg.n_dense_layers
    active_routed = cfg.n_experts_per_tok * per_expert
    return n_params - moe_layers * (routed_total - active_routed)


def model_flops(cfg: ModelConfig, n_params: int, kind: str, seq_len: int,
                global_batch: int) -> float:
    n_act = active_params(cfg, n_params)
    tokens = seq_len * global_batch
    if kind == "train":
        return 6.0 * n_act * tokens
    if kind == "prefill":
        return 2.0 * n_act * tokens
    return 2.0 * n_act * global_batch        # decode: one new token per slot


def attn_flops(cfg: ModelConfig, kind: str, seq_len: int,
               global_batch: int) -> float:
    """Quadratic-attention FLOPs (causal, counted as the full masked matmul
    XLA actually executes; 2 matmuls QK^T + PV)."""
    if cfg.family in ("ssm", "xlstm"):
        return 0.0
    H = cfg.n_heads
    hd = cfg.hd
    if cfg.use_mla:
        hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
    elif cfg.family == "encdec":
        n_attn = cfg.n_enc_layers + 2 * cfg.n_layers   # self + cross
    else:
        n_attn = cfg.n_layers
    if kind == "decode":
        per = 2 * 2 * H * hd * seq_len                 # one query vs S keys
        f = global_batch * n_attn * per
    else:
        per = 2 * 2 * H * hd * seq_len * seq_len
        f = global_batch * n_attn * per
    return (3.0 if kind == "train" else 1.0) * f


@dataclasses.dataclass
class RooflineTerms:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    chips: int
    model_flops_global: float
    attn_flops_global: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_chip / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_chip / hw.ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        total = self.flops_per_chip * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips * peak * roofline step time)."""
        denom = self.chips * hw.PEAK_FLOPS_BF16 * self.step_time_s
        return self.model_flops_global / denom if denom else 0.0

    def to_dict(self) -> dict[str, Any]:
        return {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "chips": self.chips,
            "model_flops_global": self.model_flops_global,
            "attn_flops_global": self.attn_flops_global,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_ratio": self.useful_ratio,
            "mfu": self.mfu,
        }
