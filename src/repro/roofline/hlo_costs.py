"""Per-computation cost analysis over post-SPMD compiled HLO text.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits a while
body ONCE, so every scanned structure (layer stacks, q-chunks, CE chunks)
is undercounted by its trip count — for a 94-layer scanned model that is a
~94x error in the roofline's compute term. Fully unrolling instead makes
XLA:CPU codegen take ~12 min/cell (measured), infeasible for 80 cells.

This module reimplements the flat cost model per computation and rolls the
call graph up with while TRIP COUNTS parsed from loop-condition constants:

    total(comp) = own(comp)
                + Σ_while  trip * (total(body) + total(cond))
                + Σ_fusion total(called)          (flops only: the fusion
                                                   call site already counts
                                                   its operand/result bytes)
                + Σ_cond   max over branches

Costs per instruction (mirroring HloCostAnalysis conventions):
    flops:  dot = 2 * result_elems * contracted_dim_product
            convolution = 2 * result_elems * window_size (depthwise)
            elementwise/reduce = result_elems
    bytes:  result + Σ operands, with gather/dynamic-slice/dus counted at
            slice size (NOT the full operand — stacked scan params would
            otherwise overcount by n_layers^2)
    collectives: result bytes + replica group size -> per-device wire bytes
            (ring model, see roofline.collectives)

Everything is per-device: the compiled module is one device's SPMD program,
so replicated (unshardable) compute is honestly charged to every chip.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.roofline.collectives import (Collective, _COLL_RE,
                                        _GROUPS_IOTA_RE, _GROUPS_LIST_RE,
                                        _DTYPE_BYTES)

_SHAPE_COMPONENT = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                    r"((?:\(.*?\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
                    r"([\w\-]+)\(")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_OP_NAME = re.compile(r"%([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_WINDOW = re.compile(r"window=\{size=([0-9x]+)")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONSTANT = re.compile(r"constant\((\d+)\)")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id", "iota",
             "while", "conditional", "call", "custom-call", "rng",
             "rng-bit-generator", "opt-barrier", "domain", "infeed",
             "outfeed", "copy-start", "copy-done"}

_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "exponential-minus-one",
                   "log-plus-one", "cbrt", "erf", "atan2"}


def _shape_elems_and_bytes(shape_str: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_COMPONENT.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


# ops whose bytes a TPU pays for even under perfect fusion (data movement
# or kernel boundaries); standalone elementwise/convert/broadcast/copy are
# charged only in bytes_raw (CPU-fusion-granularity upper bound)
_MAJOR_BYTES_OPS = {"dot", "convolution", "gather", "scatter",
                    "dynamic-slice", "dynamic-update-slice", "concatenate",
                    "pad", "reduce", "reduce-window", "sort", "fusion",
                    "cholesky", "triangular-solve", "fft"}


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    bytes_major: float = 0.0
    coll_wire: float = 0.0
    coll_operand: float = 0.0
    coll_count: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    # edges
    whiles: list = dataclasses.field(default_factory=list)   # (body, cond, trip)
    fusions: list = dataclasses.field(default_factory=list)  # [name]
    branches: list = dataclasses.field(default_factory=list)  # [[names]]
    max_const: int = 1            # biggest int constant (trip-count probe)


def _operand_names(line: str, start: int) -> list[str]:
    """Names inside the operand parens beginning at `start` (balanced scan —
    the result shape itself may be a parenthesized tuple)."""
    depth = 0
    for i in range(start, len(line)):
        ch = line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return _OP_NAME.findall(line[start + 1:i])
    return _OP_NAME.findall(line[start + 1:])


def parse_computations(hlo: str) -> dict[str, CompCost]:
    comps: dict[str, CompCost] = {}
    cur: Optional[CompCost] = None
    shapes: dict[str, str] = {}
    reduce_bodies: set[str] = set()

    for raw in hlo.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        hdr = (_COMP_HEADER.match(line)
               if line.endswith("{") and ") -> " in line else None)
        if hdr:
            cur = CompCost()
            comps[hdr.group(1)] = cur
            shapes = {}
            continue
        if cur is None:
            continue

        # while/conditional first: their tuple result shapes contain
        # /*index=k*/ comments that defeat the generic instruction regex
        if " while(" in line:
            mb, mcond = _BODY.search(line), _COND.search(line)
            if mb and mcond:
                cur.whiles.append((mb.group(1), mcond.group(1)))
            continue
        if " conditional(" in line:
            mbr = _BRANCHES.search(line)
            if mbr:
                cur.branches.append(_OP_NAME.findall(mbr.group(1)))
            else:
                tb = re.search(r"true_computation=%?([\w\.\-]+)", line)
                fb = re.search(r"false_computation=%?([\w\.\-]+)", line)
                if tb and fb:
                    cur.branches.append([tb.group(1), fb.group(1)])
            continue

        m = _INSTR.match(line)
        if not m:
            mc = _CONSTANT.search(line)
            if mc:
                cur.max_const = max(cur.max_const, int(mc.group(1)))
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        shapes[name] = shape_str
        elems, nbytes = _shape_elems_and_bytes(shape_str)

        mc = _CONSTANT.search(line)
        if mc:
            cur.max_const = max(cur.max_const, int(mc.group(1)))
        for ta in _TO_APPLY.findall(line):
            reduce_bodies.add(ta)
        mcall = _CALLS.search(line)
        if op == "fusion" and mcall:
            cur.fusions.append(mcall.group(1))
            # fall through: bytes counted at call site

        # collectives
        cm = _COLL_RE.search(line)
        if cm and cm.group("start") != "-done":
            g = 1
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
            else:
                gl = _GROUPS_LIST_RE.search(line)
                if gl:
                    g = len(gl.group(1).split(","))
                elif cm.group("op") == "collective-permute":
                    g = 2
            c = Collective(cm.group("op"), nbytes, g)
            cur.coll_wire += c.wire_bytes
            cur.coll_operand += c.operand_bytes
            cur.coll_count += 1
            e = cur.coll_by_op.setdefault(c.op, [0, 0.0])
            e[0] += 1
            e[1] += c.wire_bytes
            cur.bytes += 2 * nbytes
            cur.bytes_major += 2 * nbytes
            continue

        if op in _SKIP_OPS and op != "fusion":
            continue

        # ---- bytes ----
        ops_names = _operand_names(line, m.end() - 1)
        major = op in _MAJOR_BYTES_OPS
        if op in ("dynamic-slice", "gather"):
            b = 2 * nbytes
        elif op == "dynamic-update-slice":
            upd = shapes.get(ops_names[1]) if len(ops_names) > 1 else None
            _, ub = _shape_elems_and_bytes(upd) if upd else (0, nbytes)
            b = 2 * ub
        elif op == "scatter":
            upd = shapes.get(ops_names[-1]) if ops_names else None
            _, ub = _shape_elems_and_bytes(upd) if upd else (0, nbytes)
            b = nbytes + 2 * ub
        else:
            b = nbytes
            for on in ops_names:
                if on in shapes:
                    b += _shape_elems_and_bytes(shapes[on])[1]
        cur.bytes += b
        if major:
            cur.bytes_major += b

        # ---- flops ----
        if op == "dot":
            contract = 1
            mcon = _CONTRACT.search(line)
            if mcon and ops_names and ops_names[0] in shapes:
                lhs_dims = []
                for dt, dims in _SHAPE_COMPONENT.findall(shapes[ops_names[0]]):
                    lhs_dims = [int(d) for d in dims.split(",") if d]
                    break
                for ci in mcon.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        contract *= lhs_dims[int(ci)]
            cur.flops += 2.0 * elems * contract
        elif op == "convolution":
            win = 1
            mw = _WINDOW.search(line)
            if mw:
                for s in mw.group(1).split("x"):
                    win *= int(s)
            cur.flops += 2.0 * elems * win
        elif op in _TRANSCENDENTAL:
            cur.transcendentals += elems
            cur.flops += elems
        elif op != "fusion":
            cur.flops += elems          # elementwise/reduce: 1 flop/elem

    for rb in reduce_bodies:
        comps.pop(rb, None)
    return comps


@dataclasses.dataclass
class ModuleCosts:
    flops: float
    transcendentals: float
    bytes: float          # CPU-fusion-granularity upper bound
    bytes_major: float    # fusion-aware (TPU-realistic) HBM traffic
    coll_wire: float
    coll_operand: float
    coll_count: float
    coll_by_op: dict
    while_trips: list

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def rollup(hlo: str, entry_hint: str | None = None) -> ModuleCosts:
    comps = parse_computations(hlo)
    entry_m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    entry = entry_hint or (entry_m.group(1) if entry_m else None)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda k: comps[k].bytes)

    trips: list = []
    memo: dict[str, tuple] = {}

    def merge_coll(dst: dict, src: dict, mult: float = 1.0):
        for k, (cnt, wire) in src.items():
            e = dst.setdefault(k, [0, 0.0])
            e[0] += cnt * mult
            e[1] += wire * mult

    def total(name: str, depth: int = 0) -> tuple:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 12:
            return (0.0,) * 7 + ({},)
        f, t, b, bm, w, o, n = (c.flops, c.transcendentals, c.bytes,
                                c.bytes_major, c.coll_wire, c.coll_operand,
                                c.coll_count)
        byop = {k: list(v) for k, v in c.coll_by_op.items()}
        for fus in c.fusions:
            sf, st, sb, sbm, sw, so, sn, sby = total(fus, depth + 1)
            f += sf
            t += st
            w += sw
            o += so
            n += sn            # bytes intentionally NOT added (fusion = one kernel)
            merge_coll(byop, sby)
        for body, cond in c.whiles:
            trip = max(comps.get(cond, CompCost()).max_const, 1)
            trips.append({"body": body, "trip": trip})
            for sub in (body, cond):
                sf, st, sb, sbm, sw, so, sn, sby = total(sub, depth + 1)
                f += trip * sf
                t += trip * st
                b += trip * sb
                bm += trip * sbm
                w += trip * sw
                o += trip * so
                n += trip * sn
                merge_coll(byop, sby, trip)
        for branch_set in c.branches:
            if branch_set:
                subs = [total(bn, depth + 1) for bn in branch_set]
                best = max(subs, key=lambda s: s[2])
                f, t, b, bm = (f + best[0], t + best[1], b + best[2],
                               bm + best[3])
                w, o, n = w + best[4], o + best[5], n + best[6]
                merge_coll(byop, best[7])
        memo[name] = (f, t, b, bm, w, o, n, byop)
        return memo[name]

    f, t, b, bm, w, o, n, byop = total(entry)
    return ModuleCosts(flops=f, transcendentals=t, bytes=b, bytes_major=bm,
                       coll_wire=w, coll_operand=o, coll_count=n,
                       coll_by_op=byop, while_trips=trips)
