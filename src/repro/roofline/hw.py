"""TPU v5e hardware constants (the TARGET machine of this framework)."""

PEAK_FLOPS_BF16 = 197e12       # per chip, bf16
HBM_BW = 819e9                 # bytes/s per chip
ICI_LINK_BW = 50e9             # bytes/s per ICI link (~4 links/chip)
HBM_PER_CHIP = 16 * 1024**3    # 16 GiB
