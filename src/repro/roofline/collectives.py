"""Parse collective ops out of post-SPMD compiled HLO text.

``compiled.cost_analysis()`` has no collective accounting, so we regex the
optimized module (one device's SPMD program): every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
instruction's RESULT shape and replica group size, from which per-device
operand bytes and modeled wire bytes follow:

    op                  operand_bytes        wire_bytes (ring model)
    all-reduce          result               2 (G-1)/G * result
    all-gather          result / G           (G-1)/G * result
    reduce-scatter      result * G           (G-1)/G * result * G
    all-to-all          result               (G-1)/G * result
    collective-permute  result               result

Async pairs (-start/-done) are counted once (on -start). While-loop bodies
appear once in the module; the dry-run lowers with ``unroll_loops`` so
structural loops are already explicit (DESIGN.md §Roofline).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Collective:
    op: str
    result_bytes: int
    group_size: int

    @property
    def operand_bytes(self) -> int:
        if self.op == "all-gather":
            return self.result_bytes // max(self.group_size, 1)
        if self.op == "reduce-scatter":
            return self.result_bytes * self.group_size
        return self.result_bytes

    @property
    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        if self.op == "all-reduce":
            return 2.0 * (g - 1) / g * self.result_bytes
        if self.op == "all-gather":
            return (g - 1) / g * self.result_bytes
        if self.op == "reduce-scatter":
            return (g - 1) / g * self.result_bytes * g
        if self.op == "all-to-all":
            return (g - 1) / g * self.result_bytes
        return float(self.result_bytes)  # collective-permute


def parse_collectives(hlo_text: str) -> list[Collective]:
    out: list[Collective] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or m.group("start") == "-done":
            continue
        shape_bytes = _shape_bytes(m.group("shape"))
        g = 1
        gi = _GROUPS_IOTA_RE.search(line)
        if gi:
            g = int(gi.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                g = len(gl.group(1).split(","))
            elif m.group("op") == "collective-permute":
                g = 2
        out.append(Collective(m.group("op"), shape_bytes, g))
    return out


def summarize(colls: list[Collective]) -> dict:
    by_op: dict[str, dict] = defaultdict(
        lambda: {"count": 0, "operand_bytes": 0, "wire_bytes": 0.0})
    for c in colls:
        e = by_op[c.op]
        e["count"] += 1
        e["operand_bytes"] += c.operand_bytes
        e["wire_bytes"] += c.wire_bytes
    return {
        "by_op": dict(by_op),
        "count": len(colls),
        "operand_bytes": sum(c.operand_bytes for c in colls),
        "wire_bytes": sum(c.wire_bytes for c in colls),
    }
