"""Search observatory — two-plane observability (DESIGN.md §15).

Device plane (``search_metrics``): a ``SearchMetrics`` pytree of traced
per-round counters carried through the jitted search chunks as an optional
accumulator — the search results stay bit-identical with metrics on or
off, and the host reads one small pytree per chunk.

Host plane (``trace`` / ``metrics``): a Chrome/Perfetto trace-event span
recorder for scheduler events (admission, quanta, preemption, deadline
expiry, device sync, jit compiles) plus a counter/gauge registry with JSON
snapshots and a Prometheus-style text exposition.

``profile`` closes the loop: it fits the measured per-round dispatch cost
and per-task burden from recorded spans and feeds them into the analytic
``core/cilkview.py`` DagModel — measured, not guessed, burden terms for
the Fig 9 overlay.
"""

from repro.obsv.search_metrics import (  # noqa: F401
    SearchMetrics,
    accumulate_iteration,
    init_search_metrics,
    init_search_metrics_forest,
    merge_metrics,
    summarize_metrics,
)
from repro.obsv.trace import TraceRecorder, validate_trace  # noqa: F401
from repro.obsv.metrics import MetricsRegistry  # noqa: F401
