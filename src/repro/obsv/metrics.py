"""Host-plane counter/gauge registry with JSON + Prometheus output.

The trace plane (``obsv.trace``) answers "where did the time go"; this
registry answers "how much happened": monotonically increasing counters
(requests admitted, quanta dispatched, playouts committed, preemptions)
and point-in-time gauges (queue depth, active slots). Snapshots serialize
to JSON for artifacts, and ``exposition()`` renders the Prometheus text
format for scrape-style consumption.

The serving drivers update a registry when one is attached
(``TPFIFODriver(..., registry=...)``); attaching costs two dict lookups
per event, detaching costs nothing.
"""

from __future__ import annotations

import dataclasses
import json
import time


@dataclasses.dataclass
class Metric:
    name: str
    kind: str           # "counter" | "gauge"
    help: str = ""
    value: float = 0.0

    def inc(self, amount: float = 1.0):
        self.value += amount

    def set(self, value: float):
        self.value = value


class MetricsRegistry:
    """Flat name -> Metric map; create-on-first-use accessors."""

    def __init__(self):
        self._metrics: dict[str, Metric] = {}
        self._t0 = time.time()

    def counter(self, name: str, help: str = "") -> Metric:
        return self._get(name, "counter", help)

    def gauge(self, name: str, help: str = "") -> Metric:
        return self._get(name, "gauge", help)

    def _get(self, name: str, kind: str, help: str) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Metric(name=name, kind=kind, help=help)
        elif m.kind != kind:
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}")
        if help and not m.help:
            m.help = help
        return m

    # -- output -----------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "uptime_s": time.time() - self._t0,
            "metrics": {m.name: {"type": m.kind, "help": m.help,
                                 "value": m.value}
                        for m in sorted(self._metrics.values(),
                                        key=lambda m: m.name)},
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
        return path

    def exposition(self) -> str:
        """Prometheus text format (one HELP/TYPE/sample block per metric)."""
        lines = []
        for m in sorted(self._metrics.values(), key=lambda m: m.name):
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            v = m.value
            lines.append(f"{m.name} {int(v) if v == int(v) else v}")
        return "\n".join(lines) + "\n"
