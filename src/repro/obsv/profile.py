"""Measured work/span profile: fit dispatch burden from trace spans.

``core/cilkview.py`` models GSCPM as a burdened fork-join dag whose
burden terms (``t_spawn`` per task, ``t_round`` per dispatch) were, until
this module, *guessed*. The paper measures them (Table I attributes the
grain-size cliff to spawn/scheduling overhead); so do we: every traced
round/quantum span records how many schedule rounds and sync iterations
it covered, so its duration decomposes as

    dur ≈ t_round · rounds + t_sync_iter · iterations

and a least-squares fit over spans of *different grains* separates the
per-dispatch burden (``t_round``) from the per-iteration device work
(``t_sync_iter``). One sync iteration advances ``W`` lanes, so the
per-playout unit cost is ``t_sync_iter / W`` — which converts the fitted
seconds into the DagModel's ``t_iter`` units and yields a *measured*
Fig 9 overlay (``benchmarks/fig9_mapping.py``).

Span vocabulary consumed here (recorded by ``gscpm_search(tracer=...)``
and ``serve/games.TPFIFOGameEngine``): any ``X`` event whose ``args``
carry ``rounds`` and ``iterations``; ``lane_iterations`` and ``workers``
ride along for bookkeeping.
"""

from __future__ import annotations

import json

import numpy as np

from repro.core.cilkview import (
    DagModel,
    burdened_parallelism,
    parallelism,
    speedup_bound,
)

PROFILE_SPAN_NAMES = ("gscpm_round", "quantum")


def load_events(obj) -> list[dict]:
    """Accept a TraceRecorder, trace dict, event list, or file path."""
    if hasattr(obj, "events"):
        return list(obj.events)
    if isinstance(obj, str):
        with open(obj) as f:
            obj = json.load(f)
    if isinstance(obj, dict):
        return list(obj.get("traceEvents", []))
    return list(obj)


def dispatch_spans(events, names=PROFILE_SPAN_NAMES) -> list[dict]:
    """The ``X`` spans carrying a (rounds, iterations) work annotation."""
    out = []
    for ev in events:
        args = ev.get("args") or {}
        if (ev.get("ph") == "X" and ev.get("name") in names
                and "rounds" in args and "iterations" in args
                and args["rounds"] > 0):
            out.append(ev)
    return out


def fit_dispatch_profile(trace, n_workers: int | None = None) -> dict:
    """Least-squares (t_round_s, t_sync_iter_s) from dispatch spans.

    Needs spans at more than one grain (rounds:iterations ratio) to
    separate the two terms; with a rank-deficient design the whole
    duration is attributed to iterations and ``t_round_s`` reports 0 —
    flagged by ``identifiable: False``. Negative solutions (host noise)
    are clamped to 0 and the other term refit. Spans that overlap a
    ``jit_compile`` instant are excluded — a compile stall inside a span
    is setup cost, not dispatch burden, and one such span can dwarf every
    honest measurement (``n_excluded_compile`` reports how many).
    """
    events = load_events(trace)
    spans = dispatch_spans(events)
    if not spans:
        raise ValueError("trace contains no dispatch spans with "
                         "rounds/iterations args (record one with "
                         "gscpm_search(tracer=...) or a traced engine)")
    compile_ts = [ev["ts"] for ev in events
                  if ev.get("ph") == "i" and ev.get("name") == "jit_compile"]
    n_excluded = 0
    if compile_ts:
        # a compile stall lands inside the span that triggered it, but the
        # watch only OBSERVES it at the next poll — so blame any span
        # containing the instant, else the span that ended most recently
        # before it
        ok = [True] * len(spans)
        for c in compile_ts:
            inside = [i for i, s in enumerate(spans)
                      if s["ts"] <= c <= s["ts"] + s["dur"]]
            if inside:
                for i in inside:
                    ok[i] = False
            else:
                before = [(s["ts"] + s["dur"], i)
                          for i, s in enumerate(spans)
                          if s["ts"] + s["dur"] <= c]
                if before:
                    ok[max(before)[1]] = False
        clean = [s for s, k in zip(spans, ok) if k]
        if clean:
            n_excluded = len(spans) - len(clean)
            spans = clean
    rounds = np.asarray([s["args"]["rounds"] for s in spans], float)
    iters = np.asarray([s["args"]["iterations"] for s in spans], float)
    dur_s = np.asarray([s["dur"] for s in spans], float) * 1e-6
    if n_workers is None:
        ws = {s["args"].get("workers") for s in spans} - {None}
        n_workers = int(max(ws)) if ws else 1

    a = np.stack([rounds, iters], axis=1)
    identifiable = bool(np.linalg.matrix_rank(a) >= 2)
    t_round = t_sync = -1.0
    if identifiable:
        sol, *_ = np.linalg.lstsq(a, dur_s, rcond=None)
        t_round, t_sync = float(sol[0]), float(sol[1])
    if t_sync <= 0.0:
        # degenerate: rank-deficient design, or dispatch noise swamped the
        # device term. Calibrate t_sync on the coarsest-grain span (where
        # per-iteration work dominates its duration — an upper bound, the
        # classic single-point calibration) and refit the round burden on
        # the residual. Keeps t_iter_s > 0 so the unit conversion the
        # DagModel consumes stays meaningful.
        identifiable = False
        k = int(np.argmax(iters / np.maximum(rounds, 1.0)))
        t_sync = float(dur_s[k] / max(iters[k], 1.0))
        r = dur_s - t_sync * iters
        t_round = float(np.sum(rounds * r) / max(np.sum(rounds**2), 1e-12))
    elif t_round < 0.0:
        t_round = 0.0
        t_sync = float(np.sum(iters * dur_s)
                       / max(np.sum(iters * iters), 1e-12))
    t_round, t_sync = float(max(0.0, t_round)), float(max(0.0, t_sync))

    t_iter_s = t_sync / max(1, n_workers)    # per-playout unit cost
    resid = dur_s - (t_round * rounds + t_sync * iters)
    return {
        "n_spans": len(spans),
        "n_excluded_compile": n_excluded,
        "n_workers": n_workers,
        "identifiable": bool(identifiable),
        "t_round_s": t_round,
        "t_sync_iter_s": t_sync,
        "t_iter_s": t_iter_s,
        # burden terms in t_iter units — what DagModel consumes
        "t_round_units": t_round / max(t_iter_s, 1e-12),
        "t_spawn_units": t_round / max(t_iter_s, 1e-12) / max(1, n_workers),
        "fit_rms_rel": float(np.sqrt(np.mean(resid ** 2))
                             / max(np.mean(dur_s), 1e-12)),
    }


def measured_dag_model(profile: dict) -> DagModel:
    """The cilkview model with MEASURED burden terms (t_iter-normalized).

    ``t_spawn`` is the per-task share of the round dispatch burden — each
    round spawns up to W lane-tasks, so the burden a single task carries
    is ``t_round / W``.
    """
    return DagModel(t_iter=1.0,
                    t_spawn=profile["t_spawn_units"],
                    t_round=profile["t_round_units"])


def measured_vs_analytic(profile: dict, n_playouts: int,
                         task_counts, n_cores: int) -> list[dict]:
    """Per-grain table: analytic (guessed-burden) vs measured-burden
    parallelism and speedup bounds — the Fig 9 comparison as rows."""
    analytic = DagModel()
    measured = measured_dag_model(profile)
    rows = []
    for t in task_counts:
        g = max(1, n_playouts // t)
        rows.append({
            "n_tasks": int(t),
            "grain": int(g),
            "parallelism_analytic": parallelism(t, g, analytic),
            "parallelism_measured": parallelism(t, g, measured),
            "burdened_parallelism_measured":
                burdened_parallelism(t, g, n_cores, measured),
            "bound_analytic": speedup_bound(t, g, n_cores, analytic),
            "bound_measured": speedup_bound(t, g, n_cores, measured),
        })
    return rows


def format_table(rows: list[dict]) -> str:
    """Console rendering of ``measured_vs_analytic`` rows."""
    hdr = (f"{'tasks':>6} {'grain':>6} {'par(analytic)':>14} "
           f"{'par(measured)':>14} {'bound(a)':>9} {'bound(m)':>9}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['n_tasks']:>6} {r['grain']:>6} "
            f"{r['parallelism_analytic']:>14.1f} "
            f"{r['parallelism_measured']:>14.1f} "
            f"{r['bound_analytic']:>9.2f} {r['bound_measured']:>9.2f}")
    return "\n".join(lines)
