"""Host-plane span recorder: Chrome/Perfetto trace-event JSON (DESIGN.md §15).

The serving and search drivers are host-side loops dispatching jitted
quanta; their time structure (admission waits, quantum dispatch, device
sync, preemption churn, compile stalls) is exactly what the paper's
profiling chapters measure. ``TraceRecorder`` records that structure as
trace-event JSON — open ``chrome://tracing`` or https://ui.perfetto.dev
and load the file.

Event vocabulary (the ``ph`` field of the trace-event format):

- ``X`` *complete* spans with a duration — quanta, rounds, device syncs
  (``TraceRecorder.span`` context manager);
- ``B``/``E`` nested begin/end pairs for open-ended phases;
- ``i`` *instant* events — admission, preemption, retirement, deadline
  expiry, jit compiles;
- ``C`` counter tracks — queue depth, active slots;
- ``M`` metadata naming the process/thread tracks.

Timestamps are microseconds from the recorder's creation
(``time.perf_counter`` based, so spans compose with the drivers' own
telemetry clocks). Recording never raises into the traced code path: a
``None`` recorder is the off switch and every driver hook guards on it.

``CompileWatch`` turns jit-cache growth into trace events: it snapshots
``fn._cache_size()`` for registered jitted callables and, on each
``poll()``, emits an instant event per callable whose cache grew — the
compile-counting context the serving engines poll once per tick.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Callable


class CompileWatch:
    """Cache-size probe for one jitted callable (see module docstring)."""

    def __init__(self, name: str, fn: Any):
        self.name = name
        self.fn = fn
        self.last = int(fn._cache_size())
        self.total_new = 0

    def poll(self) -> int:
        """New cache entries since the previous poll."""
        cur = int(self.fn._cache_size())
        delta = cur - self.last
        self.last = cur
        if delta > 0:
            self.total_new += delta
        return delta


class TraceRecorder:
    """Append-only trace-event buffer with span/instant/counter helpers."""

    def __init__(self, process_name: str = "repro-search",
                 clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self.events: list[dict] = []
        self._watches: list[CompileWatch] = []
        self._open: dict[int, list[str]] = {}   # tid -> begin-stack
        self.metadata("process_name", {"name": process_name})

    # -- clock ------------------------------------------------------------
    def ts_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    # -- raw emitters -----------------------------------------------------
    def _emit(self, ph: str, name: str, *, ts: float | None = None,
              tid: int = 0, **extra) -> dict:
        ev = {"name": name, "ph": ph, "pid": 0, "tid": tid,
              "ts": self.ts_us() if ts is None else ts}
        ev.update({k: v for k, v in extra.items() if v is not None})
        self.events.append(ev)
        return ev

    def metadata(self, name: str, args: dict, tid: int = 0):
        self._emit("M", name, ts=0.0, tid=tid, args=args)

    def name_thread(self, tid: int, name: str):
        self.metadata("thread_name", {"name": name}, tid=tid)

    def instant(self, name: str, args: dict | None = None, tid: int = 0):
        self._emit("i", name, tid=tid, s="t", args=args)

    def begin(self, name: str, args: dict | None = None, tid: int = 0):
        self._open.setdefault(tid, []).append(name)
        self._emit("B", name, tid=tid, args=args)

    def end(self, tid: int = 0, args: dict | None = None):
        stack = self._open.get(tid, [])
        name = stack.pop() if stack else "?"
        self._emit("E", name, tid=tid, args=args)

    def complete(self, name: str, ts_us: float, dur_us: float,
                 args: dict | None = None, tid: int = 0):
        self._emit("X", name, ts=ts_us, tid=tid, dur=max(0.0, dur_us),
                   args=args)

    def counter(self, name: str, values: dict, tid: int = 0):
        self._emit("C", name, tid=tid, args=values)

    @contextlib.contextmanager
    def span(self, name: str, args: dict | None = None, tid: int = 0):
        """Complete-event context: ``with tracer.span("quantum", {...}):``.

        ``args`` may be mutated inside the block (e.g. to record how many
        rounds actually ran) — the event is emitted at exit.
        """
        t0 = self.ts_us()
        try:
            yield args
        finally:
            self.complete(name, t0, self.ts_us() - t0, args=args, tid=tid)

    # -- compile counting -------------------------------------------------
    def watch_compiles(self, name: str, fn: Any) -> CompileWatch:
        """Track a jitted callable's cache; ``poll_compiles`` emits an
        instant ``jit_compile`` event whenever it grew."""
        w = CompileWatch(name, fn)
        self._watches.append(w)
        return w

    def poll_compiles(self):
        for w in self._watches:
            d = w.poll()
            if d > 0:
                self.instant("jit_compile", {"fn": w.name, "new_programs": d,
                                             "total": w.total_new})

    def compile_counts(self) -> dict[str, int]:
        self.poll_compiles()
        return {w.name: w.total_new for w in self._watches}

    # -- output -----------------------------------------------------------
    def to_dict(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path


def validate_trace(obj: dict | str) -> int:
    """Structural check of a trace (dict or file path) -> event count.

    Raises ``ValueError`` on malformed traces: missing ``traceEvents``,
    events without name/ph/ts, ``X`` events without ``dur``, or unbalanced
    ``B``/``E`` pairs per (pid, tid) track. Used by the CI trace smoke and
    by tests.
    """
    if isinstance(obj, str):
        with open(obj) as f:
            obj = json.load(f)
    events = obj.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("trace has no traceEvents list")
    depth: dict[tuple, int] = {}
    for i, ev in enumerate(events):
        for field in ("name", "ph", "ts"):
            if field not in ev:
                raise ValueError(f"event {i} missing {field!r}: {ev}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"complete event {i} missing dur: {ev}")
        track = (ev.get("pid", 0), ev.get("tid", 0))
        if ev["ph"] == "B":
            depth[track] = depth.get(track, 0) + 1
        elif ev["ph"] == "E":
            depth[track] = depth.get(track, 0) - 1
            if depth[track] < 0:
                raise ValueError(f"unbalanced E at event {i} on {track}")
    bad = {t: d for t, d in depth.items() if d != 0}
    if bad:
        raise ValueError(f"unclosed B spans: {bad}")
    json.dumps(events[: min(len(events), 64)])   # must be JSON-serializable
    return len(events)
