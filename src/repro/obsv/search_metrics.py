"""Device-plane search metrics: traced per-round counters (DESIGN.md §15).

The paper's companion study (arXiv:1409.4297) is pure measurement — where
does a thread's time go, how deep do descents run, how contended is the
shared tree. Its TPU twin cannot poll the device mid-round, so the
counters ride *inside* the compiled program: ``SearchMetrics`` is a small
pytree of scalar accumulators threaded through ``gscpm.sync_iteration`` /
``run_chunk`` / ``run_chunk_forest`` exactly like the tree itself. The
host hands the accumulator in with a quantum dispatch and reads one small
pytree back per chunk — never per round, never mid-program.

Two contracts, both pinned by tests/test_obsv.py:

- **bit-identity**: metric updates are pure extra reductions over values
  the search already computes; they consume no RNG and feed nothing back,
  so a search with metrics on is bit-identical to the same search with
  metrics off.
- **two programs**: ``GSCPMConfig.metrics`` is a *hashed static* flag, so
  each game class compiles exactly two quantum programs — one with the
  accumulator threaded, one without — and Cp/grain/budget sweeps still
  recompile neither.

All counters are int32: at this harness's budgets (<=1e6 playouts,
boards <= a few hundred cells) every counter stays far below 2^31; a
float32 accumulator would silently lose integer precision past 2^24.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SearchMetrics(NamedTuple):
    """Per-search (or per-quantum-stream) counter accumulator.

    Scalars for a single tree; a forest carries the same pytree with a
    leading (E,) member axis (``init_search_metrics_forest``).
    """

    sync_iterations: jnp.ndarray        # batched GSCPM iterations run
    lane_playouts: jnp.ndarray          # active lane-iterations == playouts
    masked_lane_iterations: jnp.ndarray  # idle-lane slots (schedule masking)
    depth_sum: jnp.ndarray              # Σ descent depth over active lanes
    depth_max: jnp.ndarray              # deepest descent seen
    held_levels: jnp.ndarray            # lane-levels idled while peers descended
    expand_proposals: jnp.ndarray       # (leaf, move) expansion proposals
    expansions: jnp.ndarray             # nodes actually allocated
    expand_collisions: jnp.ndarray      # duplicate proposals collapsed
    leaf_collisions: jnp.ndarray        # lanes sharing a leaf (vloss collisions)
    playout_moves: jnp.ndarray          # Σ cells filled by playout evaluation
    playout_len_max: jnp.ndarray        # longest single playout
    tree_nodes_peak: jnp.ndarray        # max node occupancy observed
    # nodes inherited from a re-rooted tree at search start (DESIGN.md §16)
    # — seeded once by the warm-start entry points, carried through
    # iterations unchanged, summed across merged streams/members so the
    # retention rate shows up in traces next to the growth counters
    tree_nodes_reused: jnp.ndarray


def init_search_metrics(tree_nodes_reused: int = 0) -> SearchMetrics:
    """Fresh all-zero accumulator (scalar leaves).

    ``tree_nodes_reused`` seeds the retention gauge for warm-started
    searches (``gscpm_search(tree=...)``): the node count inherited from a
    re-rooted tree, minus the trivial root (a cold tree also starts with 1
    node, so a cold search reports 0).
    """
    z = jnp.zeros((), jnp.int32)
    m = SearchMetrics(*([z] * len(SearchMetrics._fields)))
    if tree_nodes_reused:
        m = m._replace(
            tree_nodes_reused=jnp.asarray(tree_nodes_reused, jnp.int32))
    return m


def init_search_metrics_forest(n_trees: int) -> SearchMetrics:
    """Per-member accumulator for an E-tree forest: every leaf is (E,)."""
    return jax.tree.map(
        lambda x: jnp.zeros((n_trees,) + x.shape, x.dtype),
        init_search_metrics())


def _sorted_dup_count(keys: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """How many masked-in entries duplicate an earlier equal entry.

    Masked-out lanes get a per-lane-unique sentinel so they can never
    count as duplicates of each other.
    """
    n = keys.shape[0]
    lane = jnp.arange(n, dtype=jnp.int32)
    a = jnp.where(mask, keys, jnp.int32(-1))
    b = jnp.where(mask, jnp.zeros((n,), jnp.int32), lane)
    a_s, b_s = jax.lax.sort((a, b), num_keys=2)
    dup = (a_s[1:] == a_s[:-1]) & (b_s[1:] == b_s[:-1]) & (a_s[1:] >= 0)
    return dup.sum().astype(jnp.int32)


def _pair_dup_count(leaves: jnp.ndarray, moves: jnp.ndarray,
                    mask: jnp.ndarray) -> jnp.ndarray:
    """Duplicate (leaf, move) pairs among masked-in proposals — the same
    lexicographic two-key sort ``gscpm.expand_batch`` allocates with, so
    no key packing (and no int32 overflow) for any cap × cell count."""
    n = leaves.shape[0]
    lane = jnp.arange(n, dtype=jnp.int32)
    lf = jnp.where(mask, leaves, -1 - lane)   # unique negative sentinels
    mv = jnp.where(mask, moves, jnp.int32(0))
    lf_s, mv_s = jax.lax.sort((lf, mv), num_keys=2)
    dup = (lf_s[1:] == lf_s[:-1]) & (mv_s[1:] == mv_s[:-1]) & (lf_s[1:] >= 0)
    return dup.sum().astype(jnp.int32)


def accumulate_iteration(m: SearchMetrics, *, depths_grouped: jnp.ndarray,
                         active: jnp.ndarray, leaves: jnp.ndarray,
                         moves: jnp.ndarray, eval_boards: jnp.ndarray,
                         n_nodes_before: jnp.ndarray,
                         n_nodes_after: jnp.ndarray) -> SearchMetrics:
    """Fold one ``sync_iteration``'s observations into the accumulator.

    Every input is a value the iteration computed anyway:

    - ``depths_grouped``: (R, Wr) descent depths, grouped by virtual-loss
      round — held levels are counted against each group's own deepest
      lane, because that is the lockstep descent the group actually ran;
    - ``leaves``/``moves``: (W,) selected leaves and proposed expansion
      moves (−1 = no proposal);
    - ``eval_boards``: (W, n_cells) positions handed to the playout stage
      (its empty count IS the playout length — the fill stage plays until
      the board is full);
    - ``n_nodes_before/after``: allocation counter around ``expand_batch``.
    """
    from repro.core.game import EMPTY

    depths = depths_grouped.reshape(-1)
    act_i = active.astype(jnp.int32)
    w_active = act_i.sum()

    group_max = depths_grouped.max(axis=1, keepdims=True)
    held = (group_max - depths_grouped).sum().astype(jnp.int32)

    proposals = ((moves >= 0) & active).astype(jnp.int32).sum()
    playout_len = (eval_boards == EMPTY).sum(axis=1).astype(jnp.int32)

    return SearchMetrics(
        sync_iterations=m.sync_iterations + 1,
        lane_playouts=m.lane_playouts + w_active,
        masked_lane_iterations=m.masked_lane_iterations
        + (active.shape[0] - w_active),
        depth_sum=m.depth_sum + (depths * act_i).sum(),
        depth_max=jnp.maximum(m.depth_max, (depths * act_i).max()),
        held_levels=m.held_levels + held,
        expand_proposals=m.expand_proposals + proposals,
        expansions=m.expansions + (n_nodes_after - n_nodes_before),
        expand_collisions=m.expand_collisions
        + _pair_dup_count(leaves, moves, (moves >= 0) & active),
        leaf_collisions=m.leaf_collisions
        + _sorted_dup_count(leaves, active),
        playout_moves=m.playout_moves + (playout_len * act_i).sum(),
        playout_len_max=jnp.maximum(m.playout_len_max,
                                    (playout_len * act_i).max()),
        tree_nodes_peak=jnp.maximum(m.tree_nodes_peak, n_nodes_after),
        tree_nodes_reused=m.tree_nodes_reused,   # seeded at init, not per-iter
    )


def merge_metrics(a: SearchMetrics, b: SearchMetrics) -> SearchMetrics:
    """Combine two accumulators (sums for counters, max for the gauges)."""
    maxed = {"depth_max", "playout_len_max", "tree_nodes_peak"}
    return SearchMetrics(*[
        jnp.maximum(x, y) if f in maxed else x + y
        for f, x, y in zip(SearchMetrics._fields, a, b)])


def summarize_metrics(m: SearchMetrics) -> dict:
    """One host readback -> a plain dict of counters + derived rates.

    Accepts a scalar accumulator or a forest one (leading member axis —
    members are merged first, so the summary is whole-ensemble).
    """
    m = jax.tree.map(jnp.asarray, m)
    if m.sync_iterations.ndim > 0:
        flat = jax.tree.map(lambda x: x.reshape(-1), m)
        n = flat.sync_iterations.shape[0]
        merged = jax.tree.map(lambda x: x[0], flat)
        for e in range(1, n):
            merged = merge_metrics(merged,
                                   jax.tree.map(lambda x, e=e: x[e], flat))
        m = merged
    host = {f: int(v) for f, v in zip(SearchMetrics._fields,
                                      jax.device_get(tuple(m)))}
    playouts = max(1, host["lane_playouts"])
    host["depth_mean"] = host["depth_sum"] / playouts
    host["playout_len_mean"] = host["playout_moves"] / playouts
    host["expand_collision_rate"] = (
        host["expand_collisions"] / max(1, host["expand_proposals"]))
    host["leaf_collision_rate"] = host["leaf_collisions"] / playouts
    return host
