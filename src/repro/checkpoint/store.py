"""Fault-tolerant checkpoint store: atomic, async, elastic.

Layout::

    <dir>/step_000123/
        manifest.json      # step, data_state, tree structure, shapes/dtypes
        arrays.npz         # flattened path -> GLOBAL logical array
    <dir>/LATEST           # atomically-renamed pointer file

Guarantees:
- **atomic**: a checkpoint directory is written under a tmp name and
  renamed into place; LATEST is updated last (write-new + os.replace), so a
  crash mid-save can never corrupt the restore path.
- **elastic**: arrays are saved as *global* logical values; ``restore``
  re-device_puts them onto whatever mesh/sharding the relaunch derives from
  the visible device count — a 256-chip checkpoint restores onto 8 chips or
  512 (tested in tests/test_checkpoint.py).
- **async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes to disk on a background thread, overlapping I/O with the next
  training steps; ``wait()`` joins before the next save or exit.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def _unflatten_like(template: Any, flat: dict[str, Any]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:09d}")


def save(base: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Synchronous atomic save of a pytree of (possibly sharded) arrays."""
    os.makedirs(base, exist_ok=True)
    flat = _flatten(tree)
    # gather to host as GLOBAL logical arrays (elasticity requirement).
    # npz cannot serialize ml_dtypes (bf16/fp8): store those as fp32 and
    # let restore cast back per the template dtype.
    def to_host(v):
        a = np.asarray(jax.device_get(v))
        if a.dtype.kind == "V" or str(a.dtype) in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
            a = a.astype(np.float32)
        return a
    host = {k: to_host(v) for k, v in flat.items()}
    final = step_dir(base, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    manifest = {
        "step": step,
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in host.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic on POSIX
    latest_tmp = os.path.join(base, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(base, "LATEST"))
    return final


def latest_step(base: str) -> int | None:
    """Newest valid checkpoint step (via LATEST, falling back to a scan)."""
    try:
        with open(os.path.join(base, "LATEST")) as f:
            name = f.read().strip()
        if os.path.exists(os.path.join(base, name, "manifest.json")):
            return int(name.split("_")[1])
    except (FileNotFoundError, ValueError, IndexError):
        pass
    best = None
    if os.path.isdir(base):
        for name in os.listdir(base):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(base, name, "manifest.json")):
                    s = int(name.split("_")[1])
                    best = s if best is None else max(best, s)
    return best


def restore(base: str, step: int, template: Any,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Load global arrays and (re)shard onto the current mesh.

    `template` supplies tree structure + expected shapes (ShapeDtypeStructs
    or arrays). `shardings` (same tree shape, or None for single-device) is
    applied via device_put — this is the elastic-reshard path.
    """
    d = step_dir(base, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_like(template, flat)
    tree = jax.tree.map(
        lambda a, tmpl: jnp.asarray(a).astype(tmpl.dtype), tree, template)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jax.device_put, tree)
    return tree, manifest.get("extra", {})


class AsyncSaver:
    """Snapshot-now, write-later checkpointing (overlaps I/O with compute)."""

    def __init__(self, base: str, keep: int = 3):
        self.base = base
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: dict | None = None,
             on_done: Callable[[str], None] | None = None):
        self.wait()                              # one in flight at a time
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}
        treedef = jax.tree.structure(tree)

        def work():
            try:
                snap = jax.tree.unflatten(treedef, list(host.values()))
                path = save(self.base, step, snap, extra)
                self._gc()
                if on_done:
                    on_done(path)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.base)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(step_dir(self.base, s), ignore_errors=True)
