"""Failure model, deterministic fault injection, and recovery primitives
for the TPFIFO serving stack (DESIGN.md §17).

The paper's thread-pool result — and the tournament Go service it feeds
(arXiv:1409.4297) — is a *production* claim: the FIFO pool must survive
irregular workloads, wedged workers, and corrupted results, not merely
outrun work stealing on a good day. This module gives the serving layer a
failure vocabulary and the tools to provoke and absorb each failure class:

- ``FaultPlan`` / ``FaultInjector`` — a *seeded, deterministic* schedule of
  ``(tick, slot, kind)`` fault events. Chaos runs are reproducible runs:
  the same plan against the same trace produces the same fault sequence,
  which is what lets tests pin recovery behavior bit-for-bit.
- fault kinds (``FAULT_KINDS``):

  ``dispatch_error``     the slot's quantum dispatch raises (device loss,
                         XLA error) — the engine must contain it to the
                         slot, not crash the driver loop;
  ``poison_nan``         the slot's device-resident root statistics are
                         corrupted after a quantum (NaN wins, negative
                         visits) — the *result guard* must catch it at
                         retirement and convert it into a retry;
  ``clock_stall``        the host clock jumps forward (GC pause, noisy
                         neighbor) — deadline pressure: expiries must
                         retire cleanly, never poison a slot;
  ``duplicate_submit``   an already-pending request is submitted again
                         (client retry storm) — admission must dedup.

- ``validate_result`` — the host-side result guard: cheap summary-level
  invariants (finite wins, non-negative visits, visit conservation against
  the committed schedule) — the retirement-boundary cousin of
  ``core/tree.check_invariants``. A guard rejection is converted by the
  engine into a retry from the last committed snapshot.
- ``snapshot_search`` / ``restore_search`` — host-side copies of the
  device-resident search state at committed round boundaries, flattened
  through the SAME path machinery as ``repro.checkpoint.store`` (one
  flatten vocabulary repo-wide). Because RNG streams depend only on
  ``(key, round.task_ids)`` (DESIGN.md §14), a search restored from round
  k and replayed is **bit-identical** to one that never failed — the
  recovery pin of tests/test_resilience.py.

Nothing here touches compiled programs: injected dispatch errors raise
*before* dispatch, poison/restore are eager array edits on the host
boundary, and retried rounds re-enter the exact ``run_chunk`` program the
class already owns — chaos churn adds ZERO jit entries.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store

FAULT_KINDS = ("dispatch_error", "poison_nan", "clock_stall",
               "duplicate_submit")

# driver-level kinds are applied by ``TPFIFODriver._tick`` itself; the
# slot-level kinds are consumed by the engine around each slot's quantum
DRIVER_KINDS = ("clock_stall", "duplicate_submit")
SLOT_KINDS = ("dispatch_error", "poison_nan")


class InjectedFaultError(RuntimeError):
    """Raised in place of a quantum dispatch to simulate device failure."""


class ResultGuardError(RuntimeError):
    """A retired answer failed the host-side result guard."""


# ------------------------------------------------------------- fault plan ----
@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One planned fault: at engine tick ``tick``, against ``slot``.

    ``slot`` is a flat slot index for the slot-level kinds; for
    ``duplicate_submit`` it picks the victim request (mod the number of
    pending requests); ``clock_stall`` ignores it. ``stall_s`` is the
    simulated host-clock jump for ``clock_stall`` events.
    """
    tick: int
    slot: int
    kind: str
    stall_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, seeded schedule of fault events."""

    events: tuple[FaultEvent, ...]
    seed: int | None = None
    rate: float = 0.0

    @classmethod
    def generate(cls, seed: int, n_ticks: int, n_slots: int, rate: float,
                 kinds: tuple[str, ...] = FAULT_KINDS,
                 stall_s: float = 0.25) -> "FaultPlan":
        """Bernoulli(rate) fault per (tick, slot) cell, kind drawn uniformly
        from ``kinds``. Pure function of its arguments: chaos sweeps at the
        same seed replay the identical fault sequence.
        """
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}; "
                                 f"known: {FAULT_KINDS}")
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        rng = np.random.default_rng(seed)
        events = []
        for tick in range(n_ticks):
            for slot in range(n_slots):
                if rng.random() < rate:
                    kind = str(kinds[int(rng.integers(len(kinds)))])
                    events.append(FaultEvent(
                        tick=tick, slot=slot, kind=kind,
                        stall_s=stall_s if kind == "clock_stall" else 0.0))
        return cls(events=tuple(events), seed=seed, rate=rate)


class FaultInjector:
    """Feeds a ``FaultPlan`` into a running driver, tick by tick.

    The driver calls ``begin_tick`` at the top of every ``_tick`` and
    applies the returned driver-level events itself (clock stalls,
    duplicate submissions); the engine polls ``dispatch_fault``/``poison``
    around each slot's quantum. Events that target an idle slot simply do
    not fire — ``fired`` vs ``len(plan.events)`` reports the hit rate.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._by_tick: dict[int, list[FaultEvent]] = collections.defaultdict(
            list)
        for ev in plan.events:
            self._by_tick[ev.tick].append(ev)
        self._current: list[FaultEvent] = []
        self.fired: collections.Counter = collections.Counter()

    def begin_tick(self, tick: int) -> list[FaultEvent]:
        """Arm this tick's events; return the driver-level ones."""
        self._current = list(self._by_tick.get(tick, ()))
        return [ev for ev in self._current if ev.kind in DRIVER_KINDS]

    def _take(self, kind: str, slot: int) -> FaultEvent | None:
        for i, ev in enumerate(self._current):
            if ev.kind == kind and ev.slot == slot:
                del self._current[i]
                return ev
        return None

    def dispatch_fault(self, slot: int) -> FaultEvent | None:
        return self._take("dispatch_error", slot)

    def poison(self, slot: int) -> FaultEvent | None:
        return self._take("poison_nan", slot)

    def record_fired(self, ev: FaultEvent) -> None:
        self.fired[ev.kind] += 1

    def summary(self) -> dict:
        return {"planned": len(self.plan.events),
                "fired": dict(self.fired),
                "fired_total": sum(self.fired.values())}


def poison_root_stats(tree):
    """Corrupt a tree's root-region statistics (simulated device-memory
    corruption): NaN wins at the root and its first child, a negative
    visit count on the child. Eager array edits — no jitted program is
    created or touched."""
    return tree._replace(
        wins=tree.wins.at[0].set(jnp.nan).at[1].set(jnp.nan),
        visits=tree.visits.at[1].set(-1.0))


# ------------------------------------------------------------ result guard ----
def validate_result(res: dict,
                    expected_playouts: int | None = None) -> list[str]:
    """Summary-level invariants a retired answer must satisfy.

    The cheap cousin of ``core/tree.check_invariants``: it sees only the
    dense root summary (``core/tree.root_summary``), so it runs on every
    retirement at O(n_actions) host cost. Returns the list of violations
    (empty == valid). The serving engine converts violations into retries
    from the last committed snapshot.

    ``expected_playouts`` enables the exact visit-conservation check (sum
    of root-child visits == committed playouts). It only holds for COLD
    searches — a warm-started tree carries retained evidence whose child
    sum is not exactly recoverable from the root count — so warm
    retirements pass ``None`` and rely on the finiteness/range checks.
    """
    bad: list[str] = []
    visits = np.asarray(res["root_visits"], dtype=np.float64)
    wins = np.asarray(res["root_wins"], dtype=np.float64)
    finite_v = bool(np.isfinite(visits).all())
    if not finite_v or (visits < 0).any():
        bad.append("root visits not finite and non-negative")
    if not np.isfinite(wins).all():
        bad.append("root wins not finite")
    elif finite_v and ((wins < 0) | (wins > np.maximum(visits, 0))).any():
        bad.append("root wins outside [0, visits]")
    total = float(visits.sum()) if finite_v else -1.0
    if expected_playouts is not None and total != float(expected_playouts):
        bad.append(f"visit conservation broken: root visits sum {total} "
                   f"!= committed playouts {expected_playouts}")
    if total > 0 and not np.isfinite(res["root_value"]):
        bad.append("root value not finite")
    if not -1 <= int(res["best_move"]) < len(visits):
        bad.append(f"best_move {res['best_move']} out of range")
    return bad


def snapshot_is_clean(snap: "SearchSnapshot") -> bool:
    """Cheap sanity screen on an already-host-resident snapshot: float tree
    arrays finite, visit counts non-negative.

    This gates snapshot COMMITMENT in the engine: corruption that slipped
    in before the copy (a poisoned quantum that ran before detection) must
    not overwrite the last good commit point, or a guard rejection at
    retirement would roll back into the corruption and retry forever.
    """
    for path, arr in snap.tree_flat.items():
        a = np.asarray(arr)
        if a.dtype.kind == "f":
            if not np.isfinite(a).all():
                return False
            if path.endswith("visits") and (a < 0).any():
                return False
    return True


# -------------------------------------------------------------- snapshots ----
@dataclasses.dataclass
class SearchSnapshot:
    """Host-side copy of a search at a committed round boundary.

    Arrays are flattened to a ``path -> np.ndarray`` dict through the same
    ``checkpoint.store`` machinery the training checkpoints use, plus
    ShapeDtypeStruct templates to rebuild the exact pytrees. Restoring and
    replaying the remaining rounds is bit-identical to never having failed
    (round RNG depends only on the schedule, never on wall-clock).
    """
    round_idx: int
    playouts: int
    out_len: int
    tree_flat: dict[str, np.ndarray]
    tree_template: Any
    metrics_flat: dict[str, np.ndarray] | None
    metrics_template: Any


def _host_flat(pytree: Any) -> dict[str, np.ndarray]:
    return {k: np.asarray(jax.device_get(v))
            for k, v in store._flatten(pytree).items()}


def _template(pytree: Any) -> Any:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
        pytree)


def _rebuild(template: Any, flat: dict[str, np.ndarray]) -> Any:
    host = store._unflatten_like(template, flat)
    return jax.tree.map(lambda a, t: jnp.asarray(a, dtype=t.dtype),
                        host, template)


def snapshot_search(tree, metrics, round_idx: int, playouts: int,
                    out_len: int) -> SearchSnapshot:
    """Copy the device-resident search state to host memory (blocking)."""
    return SearchSnapshot(
        round_idx=round_idx, playouts=playouts, out_len=out_len,
        tree_flat=_host_flat(tree), tree_template=_template(tree),
        metrics_flat=None if metrics is None else _host_flat(metrics),
        metrics_template=None if metrics is None else _template(metrics))


def restore_search(snap: SearchSnapshot):
    """Rebuild ``(tree, metrics)`` device pytrees from a snapshot."""
    tree = _rebuild(snap.tree_template, snap.tree_flat)
    metrics = (None if snap.metrics_flat is None
               else _rebuild(snap.metrics_template, snap.metrics_flat))
    return tree, metrics
