"""Serving engine: prefill/decode steps + slot-based continuous batching.

``make_prefill_step`` / ``make_serve_step`` build the jit-able functions the
dry-run lowers for the ``prefill_32k`` / ``decode_32k`` / ``long_500k``
cells. ``SlotEngine`` is the host-side batcher: a fixed pool of B slots,
each holding one request's position; finished slots are refilled from the
queue without recompiling (shapes never change — TPU-friendly continuous
batching).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.common import ModelConfig


def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    """(params, batch) -> (last-position logits, cache tree)."""

    def prefill_step(params, batch: dict):
        return api.prefill(params, cfg, batch, max_len)

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """One-token decode for the whole slot batch.

    tokens: (B, 1) i32; pos: () or (B,) i32; cache in/out (donated under
    jit). Logits out: (B, 1, V).
    """

    def serve_step(params, tokens, pos, cache):
        return api.decode(params, cfg, tokens, pos, cache)

    return serve_step


def sample_tokens(logits: jnp.ndarray, key: jax.Array,
                  temperature: float = 0.0) -> jnp.ndarray:
    """(B, 1, V) -> (B, 1) greedy (t=0) or temperature sampling."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class SlotEngine:
    """Fixed-B continuous batcher over the jitted prefill/decode steps.

    Per-slot prefill writes the prompt's KV into the slot's rows of the
    shared cache; all active slots then decode in lockstep. The batch
    shape is constant, so there is exactly one compiled decode executable
    regardless of arrival pattern — the TPU analogue of a FIFO worker pool
    (requests queue; a free slot takes the head of the queue).
    """

    def __init__(self, params, cfg: ModelConfig, n_slots: int, max_len: int,
                 temperature: float = 0.0, eos_id: int = 2, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.B = n_slots
        self.max_len = max_len
        self.temperature = temperature
        self.eos_id = eos_id
        self.key = jax.random.key(seed)

        self.cache = api.init_cache(cfg, n_slots, max_len)
        # cache leaves are layer-stacked: locate each leaf's batch axis so
        # per-slot copies index the right dimension
        spec_tree = api.cache_specs(cfg, n_slots, max_len)
        is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                             and hasattr(x[0], "shape"))
        self._batch_axes = [a.index("batch") for a in jax.tree.leaves(
            jax.tree.map(lambda t: t[1], spec_tree, is_leaf=is_leaf),
            is_leaf=lambda x: isinstance(x, tuple))]
        self.pos = np.zeros((n_slots,), np.int32)       # next write position
        self.active: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []

        # jit once; batch=1 prefill per admitted request
        self._prefill1 = jax.jit(make_prefill_step(cfg, max_len))
        self._decode = jax.jit(make_serve_step(cfg), donate_argnums=(3,))
        self._pending_tok = np.zeros((n_slots, 1), np.int32)

    # ------------------------------------------------------------- admit ----
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.B):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, cache1 = self._prefill1(self.params, {"tokens": toks})
                # copy the single-request cache into slot s (per-leaf batch axis)
                big_leaves, treedef = jax.tree.flatten(self.cache)
                one_leaves = jax.tree.leaves(cache1)
                out = []
                for big, one, bi in zip(big_leaves, one_leaves,
                                        self._batch_axes):
                    idx = (slice(None),) * bi
                    out.append(big.at[idx + (s,)].set(one[idx + (0,)]))
                self.cache = jax.tree.unflatten(treedef, out)
                self.key, k = jax.random.split(self.key)
                tok = sample_tokens(logits, k, self.temperature)
                req.out.append(int(tok[0, 0]))
                self._pending_tok[s] = np.asarray(tok[0])
                self.pos[s] = len(req.prompt)
                self.active[s] = req

    # -------------------------------------------------------------- step ----
    def step(self) -> int:
        """One engine tick: admit, decode all active slots, retire finished."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        tokens = jnp.asarray(self._pending_tok)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, tokens, pos, self.cache)
        self.key, k = jax.random.split(self.key)
        nxt = np.asarray(sample_tokens(logits, k, self.temperature))
        n_active = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            n_active += 1
            tok = int(nxt[s, 0])
            req.out.append(tok)
            self.pos[s] += 1
            self._pending_tok[s] = tok
            if (tok == self.eos_id or len(req.out) >= req.max_new
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                self.finished.append(req)
                self.active[s] = None
        return n_active

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
