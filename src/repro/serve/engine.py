"""Serving engine: prefill/decode steps + slot-based continuous batching.

``make_prefill_step`` / ``make_serve_step`` build the jit-able functions the
dry-run lowers for the ``prefill_32k`` / ``decode_32k`` / ``long_500k``
cells. ``SlotEngine`` is the host-side batcher: a fixed pool of B slots,
each holding one request's position; finished slots are refilled from the
queue without recompiling (shapes never change — TPU-friendly continuous
batching).

``MCTSSlotEngine`` is the search-guided sibling (DESIGN.md §3/§4): the same
fixed pool of B slots, but every slot owns a GSCPM token tree and each
engine tick runs ONE root-parallel batched search
(``mcts_decode.mcts_decode_search_batch`` — all slots advance through a
single jitted step per round) and commits one searched token per active
slot. Empty slots ride along as masked requests, so arrival patterns never
change shapes and the whole serve lifetime uses one compiled search program.

Both engines are *lockstep policies* (one micro-step per tick, admission
only into free slots, no preemption) over the work-sharing FIFO driver in
``repro.serve.tpfifo`` (DESIGN.md §10), which owns the queue discipline,
admission bookkeeping, and per-request telemetry (``QueueStats``). The
grain-size-controlled engines — ``TPFIFOEngine`` / ``TPFIFOMCTSEngine`` —
live there too.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.common import ModelConfig
from repro.serve.tpfifo import TPFIFODriver, Ticket, sample_tokens  # noqa: F401  (sample_tokens re-exported: public API of this module since PR 1)


def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    """(params, batch) -> (last-position logits, cache tree)."""

    def prefill_step(params, batch: dict):
        return api.prefill(params, cfg, batch, max_len)

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """One-token decode for the whole slot batch.

    tokens: (B, 1) i32; pos: () or (B,) i32; cache in/out (donated under
    jit). Logits out: (B, 1, V).
    """

    def serve_step(params, tokens, pos, cache):
        return api.decode(params, cfg, tokens, pos, cache)

    return serve_step


@functools.lru_cache(maxsize=64)
def _shared_prefill(cfg: ModelConfig, max_len: int) -> Callable:
    """Process-wide jitted prefill: engines come and go (one per benchmark
    trace, one per test), the compile cache must not die with them."""
    return jax.jit(make_prefill_step(cfg, max_len))


@functools.lru_cache(maxsize=64)
def _shared_decode(cfg: ModelConfig) -> Callable:
    return jax.jit(make_serve_step(cfg), donate_argnums=(3,))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class SlotEngine(TPFIFODriver):
    """Fixed-B continuous batcher over the jitted prefill/decode steps.

    Per-slot prefill writes the prompt's KV into the slot's rows of the
    shared cache; all active slots then decode in lockstep. The batch
    shape is constant, so there is exactly one compiled decode executable
    regardless of arrival pattern — the TPU analogue of a FIFO worker pool
    (requests queue; a free slot takes the head of the queue).
    """

    def __init__(self, params, cfg: ModelConfig, n_slots: int, max_len: int,
                 temperature: float = 0.0, eos_id: int = 2, seed: int = 0,
                 tracer=None, registry=None):
        super().__init__(n_slots, tracer=tracer, registry=registry)
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.temperature = temperature
        self.eos_id = eos_id
        self.key = jax.random.key(seed)

        self.cache = api.init_cache(cfg, n_slots, max_len)
        # cache leaves are layer-stacked: locate each leaf's batch axis so
        # per-slot copies index the right dimension
        self._batch_axes = jax.tree.leaves(
            api.cache_batch_axes(cfg, n_slots, max_len))
        self.pos = np.zeros((n_slots,), np.int32)       # next write position
        self._pending_admits: list[tuple[int, Ticket]] = []

        # jitted once per (cfg, max_len) across ALL engine instances;
        # batch=1 prefill per admitted request
        self._prefill1 = _shared_prefill(cfg, max_len)
        self._decode = _shared_decode(cfg)
        self._pending_tok = np.zeros((n_slots, 1), np.int32)

    def submit(self, req: Request, at: float | None = None):
        if len(req.prompt) > self.max_len:
            raise ValueError(
                f"prompt ({len(req.prompt)}) exceeds the cache "
                f"(max_len {self.max_len}); generation past the cache is "
                f"merely truncated, but an oversized prompt cannot prefill")
        super().submit(req, at=at)

    def _should_retire(self, tok: int, req: Request, pos: int) -> bool:
        """Shared by the admission and decode paths — the two must agree."""
        return (tok == self.eos_id or len(req.out) >= req.max_new
                or pos >= self.max_len - 1)

    # ------------------------------------------------------------- admit ----
    def _load_slot(self, s: int, t: Ticket):
        # defer device work: all of a tick's admissions share one
        # flatten/unflatten of the big cache pytree (see _apply_admits)
        self._pending_admits.append((s, t))

    def _apply_admits(self):
        big_leaves, treedef = jax.tree.flatten(self.cache)
        for s, t in self._pending_admits:
            req = t.req
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache1 = self._prefill1(self.params, {"tokens": toks})
            # copy the single-request cache into slot s (per-leaf batch axis)
            one_leaves = jax.tree.leaves(cache1)
            for i, (big, one, bi) in enumerate(
                    zip(big_leaves, one_leaves, self._batch_axes)):
                idx = (slice(None),) * bi
                big_leaves[i] = big.at[idx + (s,)].set(one[idx + (0,)])
            self.key, k = jax.random.split(self.key)
            tok = sample_tokens(logits, k, self.temperature)
            tok_i = int(tok[0, 0])
            req.out.append(tok_i)
            self._pending_tok[s] = np.asarray(tok[0])
            self.pos[s] = len(req.prompt)
            # the admission token can already satisfy the request (eos, a
            # max_new=1 budget, or a full cache): retire now, or the next
            # decode tick would overrun the budget
            if self._should_retire(tok_i, req, int(self.pos[s])):
                self._retire_slot(s)
        self.cache = jax.tree.unflatten(treedef, big_leaves)
        self._pending_admits = []

    # -------------------------------------------------------------- step ----
    def step(self) -> int:
        """One engine tick: admit, decode all active slots, retire finished."""
        self._admit_free_slots()
        if self._pending_admits:
            self._apply_admits()
        if not any(t is not None for t in self.active):
            return 0
        tokens = jnp.asarray(self._pending_tok)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, tokens, pos, self.cache)
        self.key, k = jax.random.split(self.key)
        nxt = np.asarray(sample_tokens(logits, k, self.temperature))
        n_active = 0
        for s, t in enumerate(self.active):
            if t is None:
                continue
            n_active += 1
            req = t.req
            tok = int(nxt[s, 0])
            req.out.append(tok)
            self.pos[s] += 1
            self._pending_tok[s] = tok
            if self._should_retire(tok, req, int(self.pos[s])):
                self._retire_slot(s)
        return n_active


class MCTSSlotEngine(TPFIFODriver):
    """Multi-user MCTS-decode server: B slots, B trees, one jitted step.

    Each tick = admit waiting requests into free slots, run one batched
    GSCPM search over ALL active slots' prompts (each slot's tree is an
    independent root-parallel member; see ``mcts_decode_search_batch``),
    commit each slot's most-visited root token, retire finished requests.

    The token buffer is a fixed (B, max_prompt_len) matrix and prompt
    lengths are traced, so admissions, commits, and retirements never
    recompile — the search analogue of ``SlotEngine``'s continuous batching.
    ``max_prompt_len`` must cover every request's prompt PLUS its
    ``max_new`` generated tokens (enforced at submit).
    """

    def __init__(self, params, cfg: ModelConfig, dcfg, n_slots: int,
                 max_prompt_len: int, eos_id: int = 2, seed: int = 0,
                 tracer=None, registry=None):
        super().__init__(n_slots, tracer=tracer, registry=registry)
        self.params = params
        self.cfg = cfg
        self.dcfg = dcfg
        self.max_prompt_len = max_prompt_len
        self.eos_id = eos_id
        self.key = jax.random.key(seed)

        self.tokens = np.zeros((n_slots, max_prompt_len), np.int32)
        self.lens = np.ones((n_slots,), np.int32)   # >=1: masked slots still
        # bounded tick history: a long-lived server must not grow host
        # memory with one dict per committed token
        self.search_stats: collections.deque = collections.deque(maxlen=256)

    def submit(self, req: Request, at: float | None = None):
        if len(req.prompt) + req.max_new > self.max_prompt_len:
            raise ValueError(
                f"prompt ({len(req.prompt)}) + max_new ({req.max_new}) "
                f"exceeds max_prompt_len ({self.max_prompt_len})")
        super().submit(req, at=at)

    def _load_slot(self, s: int, t: Ticket):
        req = t.req
        L = len(req.prompt)
        self.tokens[s, :] = 0
        self.tokens[s, :L] = np.asarray(req.prompt, np.int32)
        self.lens[s] = L

    def step(self) -> int:
        """One tick: admit, search all slots in lockstep, commit one token
        per active slot, retire finished. Returns #active slots served."""
        from repro.serve.mcts_decode import mcts_decode_search_batch

        self._admit_free_slots()
        mask = np.array([t is not None for t in self.active])
        if not mask.any():
            return 0
        self.key, k = jax.random.split(self.key)
        _, stats = mcts_decode_search_batch(
            self.params, self.cfg, jnp.asarray(self.tokens), self.dcfg, k,
            prompt_lens=jnp.asarray(self.lens),
            request_mask=jnp.asarray(mask))
        self.search_stats.append(stats)
        for s, t in enumerate(self.active):
            if t is None:
                continue
            req = t.req
            tok = int(stats["best_tokens"][s])
            req.out.append(tok)
            self.tokens[s, self.lens[s]] = tok
            self.lens[s] += 1
            if (tok == self.eos_id or len(req.out) >= req.max_new
                    or self.lens[s] >= self.max_prompt_len):
                self._retire_slot(s)
        return int(mask.sum())
