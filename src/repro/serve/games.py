"""Multi-tenant game-search serving: ``Game`` requests through the TPFIFO
quantum engine (DESIGN.md §14).

The paper's FIFO work-sharing pool schedules one search's task queue;
this module is the same scheduler serving *strangers' games*. Board-game
search requests (hex, gomoku, any ``Game``-registry entry) queue in the
host-side TPFIFO and are served in work quanta of ``m`` GSC-PM schedule
rounds each — the batched descent + fused playout machinery of
``core/gscpm``, dispatched through ``run_schedule_round``, exactly the
calls an uninterrupted ``gscpm_search`` would make.

Layout:

- one FIFO queue for ALL traffic, but a fixed slot pool **per game
  class**. A game class is the request's ``GSCPMConfig`` — games hash by
  type (``stamp_game_identity``) and the budget knobs (``n_playouts``,
  ``n_tasks``, ``cp``, inner scheduler) are ``compare=False``, so the
  class key is simultaneously ``run_chunk``'s static argument: mixed
  hex/gomoku traffic with per-request budget/Cp/grain churn compiles
  exactly ONE quantum program per game class and never cross-recompiles
  (asserted in tests/test_serve_games.py).
- per-request budgets: ``n_playouts``/``n_tasks`` fix the request's round
  schedule (``core/scheduler.make_schedule``), ``cp`` rides into the
  quantum as a traced operand (PR 3), and ``deadline_s`` is a
  time-to-move deadline — an expired request retires immediately with
  whatever root statistics its tree holds (``core/tree.root_summary``),
  never a crash, never a poisoned slot.
- tail-requeue preemption reuses ``core/scheduler.quantum_plan`` and the
  PR 2 progress guard (≥1 committed round per admission segment, and only
  when a SAME-class request waits — a freed hex slot cannot serve a
  queued gomoku). A preempted request's device-resident tree rides along
  in the engine's state table, so resumption continues the identical
  round sequence: a quantum-served search is **bit-identical** to the
  same search run uninterrupted. That contract is this module's center of
  gravity and is pinned by the serving-equivalence test suite.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import game as game_mod
from repro.core import scheduler as sched
from repro.core.gscpm import (GSCPMConfig, fold_task_keys,
                              run_schedule_round, warm_tree_check)
from repro.core.root_parallel import (ensemble_mesh, ensemble_sharding,
                                      forest_retire_summary,
                                      materialize_forest_summary,
                                      pad_forest_members,
                                      run_schedule_round_forest)
from repro.core.tree import (Tree, init_forest, init_tree,
                             materialize_root_summary, reroot_tree,
                             root_summary_device)
from repro.serve import resilience
from repro.serve.resilience import InjectedFaultError, ResultGuardError
from repro.serve.tpfifo import Ticket, TPFIFODriver


# ---------------------------------------------------------------- request ----
@dataclasses.dataclass
class GameRequest:
    """One search-a-move request against a registered ``Game``.

    Duck-typed for ``TPFIFODriver``'s ``Ticket`` (``rid``/``out``/``done``):
    ``out`` records completed schedule rounds — the progress-guard and
    telemetry currency, the serving twin of an LM request's generated
    tokens. ``board`` is an optional ``(n_cells,)`` int8 position (None =
    the empty board); ``deadline_s`` is the time-to-move budget measured
    from submission. The answer lands in ``result``: the
    ``core/tree.root_summary`` snapshot plus serving metadata.
    """

    rid: Any
    game: str = "hex"
    board_size: int = 9
    to_move: int = 1
    n_playouts: int = 512
    n_tasks: int = 16
    cp: float = 1.0
    seed: int = 0
    deadline_s: float | None = None
    board: Any = None
    # root-parallel ensemble width: E > 1 serves the request as a FOREST
    # tenant — E independent trees on the request's position, advanced by
    # one dispatch per round (sharded over the ensemble mesh when more
    # than one device is visible) and retired with merged root stats
    # (``root_parallel.forest_root_summary``). ``n_playouts`` is the
    # PER-MEMBER budget; ``result["playouts"]`` reports the ensemble
    # total. Forest requests are stateless (no ``session``).
    n_trees: int = 1
    # the stateful tenant this request belongs to (``GameSession``): the
    # session's device-resident tree warm-starts the search and the final
    # tree is handed back at retirement. None = the classic stateless
    # search-a-position request.
    session: Any = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    result: dict | None = None


@dataclasses.dataclass
class _SearchState:
    """Device-side search of one admitted request.

    Survives preemption (the tree stays device-resident in the engine's
    state table while the ticket waits at the queue tail), which is what
    makes resumption literally a continuation of the same round sequence —
    nothing is replayed, nothing is lost.
    """

    cfg: GSCPMConfig
    board: jnp.ndarray
    key: jax.Array
    cp: jnp.ndarray
    schedule: list[sched.Round]
    tree: Tree
    round_idx: int = 0
    playouts: int = 0
    deadline: float | None = None   # absolute engine-clock instant
    expired: bool = False
    metrics: Any = None             # SearchMetrics accumulator (cfg.metrics)
    session: Any = None             # owning GameSession (tree returns to it)
    reused_nodes: int = 0           # warm-start inheritance (beyond the root)
    reused_visits: float = 0.0      # root evidence the search started from
    snap: Any = None                # last committed SearchSnapshot (chaos)
    # forest tenants (n_trees > 1): ``tree`` is an E-member forest (padded
    # to ``n_padded`` rows when the ensemble mesh does not divide E),
    # ``board`` is the (n_padded, n_cells) tiled position, and rounds
    # dispatch ``run_schedule_round_forest`` with these member streams
    n_trees: int = 1
    n_padded: int = 1
    member_keys: Any = None         # (n_padded,) typed member key streams
    mesh: Any = None                # ensemble mesh (None on one device)


def warm_budget(n_playouts: int, n_tasks: int, n_workers: int,
                retained_visits: float) -> tuple[int, int]:
    """Equal-evidence budget for a warm-started search (DESIGN.md §16).

    ``n_playouts`` is the TOTAL root evidence the move decision should rest
    on; a warm tree already holds ``retained_visits`` of it, so the search
    only runs the remainder (floored at one full worker batch so a fully
    warm position still refreshes its statistics). The task count shrinks
    proportionally — the grain ``m = n_playouts // n_tasks`` is preserved,
    so warm and cold searches run the SAME quantum program with the same
    per-round shape, just fewer rounds. This is the honest accounting
    behind "warm beats cold at equal playout budget": warm moves are
    faster because they run fewer fresh playouts for the same evidence,
    not because a playout got cheaper.
    """
    m = max(1, n_playouts // max(1, n_tasks))
    eff = max(n_workers, n_playouts - int(retained_visits))
    return eff, max(1, eff // m)


# ----------------------------------------------------------------- engine ----
class TPFIFOGameEngine(TPFIFODriver):
    """Work-sharing FIFO server for board-game search.

    ``n_slots`` is the slot-pool width PER GAME CLASS (pools materialize
    lazily as classes appear in traffic); ``grain`` is the quantum size in
    GSC-PM schedule rounds; ``policy``/``preempt_quanta`` are the PR 2
    disciplines. Engine-level knobs that shape compiled programs
    (``n_workers``, ``tree_cap``, ``vl_rounds``, ``select_noise``) are
    fixed per engine; everything per-request (budget, grain, Cp, deadline,
    position, seed) is traced or host-only and never recompiles.

    ``metrics=True`` turns on the device-plane ``SearchMetrics`` plane for
    every served search (DESIGN.md §15): each request's accumulator rides
    its quanta (surviving preemption alongside the tree) and lands in
    ``result["metrics"]`` at retirement. It is a HASHED config field, so a
    metrics engine's game classes compile their own (second) quantum
    program — still one per class, still bit-identical results.
    ``tracer``/``registry`` enable the host plane (see ``TPFIFODriver``),
    adding per-quantum ``X`` spans annotated with the round/iteration work
    they covered — the spans ``repro.obsv.profile`` fits burden terms from
    — plus deadline-expiry instants and device-sync spans at retirement.
    """

    def __init__(self, n_slots: int = 2, grain: int = 2,
                 policy: str = "fifo", preempt_quanta: int | None = None,
                 n_workers: int = 8, vl_rounds: int = 1,
                 tree_cap: int = 1 << 12, select_noise: float = 1e-3,
                 inner_scheduler: str = "fifo", metrics: bool = False,
                 max_queue: int | None = None,
                 quarantine_after: int | None = None,
                 injector=None, retry_backoff: tuple[int, int] = (1, 8),
                 guard: bool = True, snapshots: bool | None = None,
                 pipeline: bool | None = None,
                 tracer=None, registry=None):
        super().__init__(n_slots, grain=grain, policy=policy,
                         preempt_quanta=preempt_quanta,
                         max_queue=max_queue,
                         quarantine_after=quarantine_after,
                         injector=injector, retry_backoff=retry_backoff,
                         tracer=tracer, registry=registry)
        # the result guard runs on every retirement; snapshots (needed to
        # retry from the last committed round instead of round 0) default
        # to on exactly when an injector is attached — a no-chaos engine
        # pays zero copy cost
        self.guard = guard
        self._snapshots = (injector is not None) if snapshots is None \
            else bool(snapshots)
        # async round pipelining (DESIGN.md §18): a finished search frees
        # its slot immediately and its retirement readback is deferred one
        # tick, so the host materializes it WHILE the device runs the next
        # tick's quanta — no device readback on the hot tick path at all.
        # Pipelining needs that path sync-free, so it disables cleanly
        # whenever something must block per quantum: a tracer (honest span
        # durations), a fault injector, or snapshot commit points. The
        # served results are bit-identical either way (pinned in
        # tests/test_pipeline.py); ``self.pipeline`` reports the EFFECTIVE
        # mode.
        want = True if pipeline is None else bool(pipeline)
        self.pipeline = (want and tracer is None and injector is None
                         and not self._snapshots)
        # deferred retirements: (class, slot, ticket, state, device summary)
        self._pending_retire: list[tuple] = []
        self.slots_per_class = n_slots
        self.template = GSCPMConfig(
            n_workers=n_workers, vl_rounds=vl_rounds, tree_cap=tree_cap,
            select_noise=select_noise, scheduler=inner_scheduler,
            metrics=metrics)
        if tracer is not None:
            from repro.core.gscpm import run_chunk
            tracer.watch_compiles("run_chunk", run_chunk)
        # one slot pool per game class; self.active/self.B mirror the
        # flattened pools so the base driver's has_work/_tick_m accounting
        # (quantum plans, rebalance widening) applies unchanged
        self.pools: dict[GSCPMConfig, list[Ticket | None]] = {}
        self._states: dict[Any, _SearchState] = {}
        self.active = []
        self.B = 0

    # -- game classes -----------------------------------------------------
    def request_cfg(self, req: GameRequest) -> GSCPMConfig:
        """The request's full search config — also its game-class key.

        ``GSCPMConfig`` hashes/compares only by program-shaping fields
        (game, board_size, n_workers, tree_cap, ...): budget knobs are
        ``compare=False``, so requests differing only in
        n_playouts/n_tasks/cp/scheduler land in ONE pool and reuse ONE
        compiled quantum. Tests build their uninterrupted reference
        searches from this same config.
        """
        return dataclasses.replace(
            self.template, game=req.game, board_size=req.board_size,
            n_playouts=req.n_playouts, n_tasks=req.n_tasks, cp=req.cp,
            n_trees=getattr(req, "n_trees", 1))

    def _sync_active(self) -> None:
        self.active = [t for pool in self.pools.values() for t in pool]
        self.B = self.slots_per_class * max(1, len(self.pools))

    # -- queue ------------------------------------------------------------
    def submit(self, req: GameRequest, at: float | None = None) -> bool:
        """Admission with full request validation (DESIGN.md §17).

        Malformed requests fail HERE with a typed error naming the field,
        not three quanta later as an XLA shape error that poisons a slot.
        Returns True if queued; False if deduplicated (rid already
        pending) or shed (class queue at ``max_queue`` — the request
        retires immediately with ``status="shed"``).
        """
        cfg = self.request_cfg(req)
        game = cfg.game_obj        # raises for unregistered game names
        if isinstance(req.n_playouts, bool) or not isinstance(
                req.n_playouts, (int, np.integer)) or req.n_playouts < 1:
            raise ValueError(
                f"n_playouts must be a positive int, got {req.n_playouts!r}")
        if isinstance(req.n_tasks, bool) or not isinstance(
                req.n_tasks, (int, np.integer)) or req.n_tasks < 1:
            raise ValueError(
                f"n_tasks must be a positive int, got {req.n_tasks!r}")
        if req.to_move not in (1, 2):
            raise ValueError(f"to_move must be 1 or 2, got {req.to_move!r}")
        n_trees = getattr(req, "n_trees", 1)
        if isinstance(n_trees, bool) or not isinstance(
                n_trees, (int, np.integer)) or n_trees < 1:
            raise ValueError(
                f"n_trees must be a positive int, got {n_trees!r}")
        if n_trees > 1 and req.session is not None:
            raise ValueError(
                "forest requests (n_trees > 1) are stateless: sessions "
                "re-root ONE tree across moves (use reroot_forest + "
                "gscpm_search_batch(forest=...) for warm forests)")
        try:
            cp = float(req.cp)
        except (TypeError, ValueError):
            raise TypeError(
                f"cp must be a real number, got {type(req.cp).__name__}")
        if not math.isfinite(cp) or cp < 0:
            raise ValueError(f"cp must be finite and >= 0, got {req.cp!r}")
        if req.deadline_s is not None:
            try:
                dl = float(req.deadline_s)
            except (TypeError, ValueError):
                raise TypeError(f"deadline_s must be a real number or None, "
                                f"got {type(req.deadline_s).__name__}")
            if not math.isfinite(dl) or dl < 0:
                raise ValueError(
                    f"deadline_s must be finite and >= 0, "
                    f"got {req.deadline_s!r}")
        if req.board is not None:
            b = np.asarray(req.board)
            if b.dtype.kind not in "iu":
                raise TypeError(
                    f"board dtype must be integer (int8 positions), "
                    f"got {b.dtype}")
            if b.shape != (game.n_cells,):
                raise ValueError(
                    f"board shape {b.shape} != ({game.n_cells},); {req.game} "
                    f"{req.board_size}x{req.board_size} needs a flat "
                    f"({game.n_cells},) array")
            if not np.isin(b, (0, 1, 2)).all():
                raise ValueError(
                    "board cells must be 0 (empty), 1, or 2")
        return super().submit(req, at=at)

    def _queue_load(self, req: GameRequest) -> int:
        """Shedding is per game class: one game's burst fills only its own
        admission budget, it cannot starve another game's queue."""
        ck = self.request_cfg(req)
        return sum(1 for t in self.queue if self.request_cfg(t.req) == ck)

    def _healthy_peers(self, slot_key: tuple[GSCPMConfig, int]) -> int:
        ck, _ = slot_key
        return sum(1 for i in range(self.slots_per_class)
                   if (ck, i) not in self.quarantined)

    # -- TPFIFODriver hooks ----------------------------------------------
    def _work_estimate(self, t: Ticket) -> int:
        st = self._states[t.req.rid]
        return max(1, len(st.schedule) - st.round_idx)

    def _waiting_for(self, t: Ticket) -> bool:
        # slots are partitioned by class: preempting only helps a queued
        # request that can occupy the freed slot
        ck = self.request_cfg(t.req)
        return any(self.request_cfg(q.req) == ck for q in self.queue)

    def _admit_free_slots(self) -> list[tuple[GSCPMConfig, int]]:
        """FIFO admission against per-class pools.

        The queue is scanned in submission order; a request whose class
        pool is full stays queued (later requests of the SAME class cannot
        overtake it — its pool stays full for them too), while requests of
        other classes may pass (per-class pools exist precisely so one
        game's burst cannot head-of-line-block another's).
        """
        admitted: list[tuple[GSCPMConfig, int]] = []
        skipped: collections.deque[Ticket] = collections.deque()
        while self.queue:
            t = self.queue.popleft()
            if t.not_before > self._ticks:      # retry backoff gate
                skipped.append(t)
                continue
            ck = self.request_cfg(t.req)
            pool = self.pools.setdefault(ck, [None] * self.slots_per_class)
            s = next((i for i, x in enumerate(pool)
                      if x is None and (ck, i) not in self.quarantined),
                     None)
            if s is None:                       # pool full or quarantined
                skipped.append(t)
                continue
            if t.req.rid not in self._states:
                st = self._make_state(ck, t)
                if self._snapshots:
                    # round-0 commit point: a fault before the first
                    # quantum completes rolls back HERE (preserving a warm
                    # session tree) instead of rebuilding from scratch
                    with self._device_wait("snapshot", rid=t.req.rid):
                        st.snap = resilience.snapshot_search(
                            st.tree, st.metrics, 0, 0, len(t.req.out))
                self._states[t.req.rid] = st
            if t.t_admit is None:
                t.t_admit = self._now()
            t.quanta_at_admit = t.quanta
            t.seg_base = len(t.req.out)
            t.plan = sched.quantum_plan(self._work_estimate(t), self.grain,
                                        self.policy)
            t.plan_idx = 0
            t.q_rem = t.plan[0]
            pool[s] = t
            self.admission_order.append(t.req.rid)
            admitted.append((ck, s))
            if self.tracer:
                self.tracer.instant("admission", {
                    "rid": t.req.rid, "game": ck.game, "slot": s,
                    "resumed": t.preemptions > 0,
                    "wait_s": round(t.t_admit - t.t_submit, 6)})
            if self.registry:
                self.registry.counter(
                    "serve_admissions_total",
                    "requests admitted into a device slot").inc()
        self.queue = skipped
        self._sync_active()
        return admitted

    def _make_state(self, cfg: GSCPMConfig, t: Ticket) -> _SearchState:
        req = t.req
        game = cfg.game_obj
        board = (game.init_board() if req.board is None
                 else jnp.asarray(req.board, jnp.int8))
        if cfg.n_trees > 1:
            return self._make_forest_state(cfg, t, board)
        # warm start: a session-backed request checks its tenant's
        # device-resident tree out of the session (ownership moves to the
        # engine until retirement) and shrinks the budget by the evidence
        # the tree already holds — same class key, same compiled quantum,
        # fewer rounds (``warm_budget``)
        tree = None
        reused_nodes = 0
        reused_visits = 0.0
        sess = req.session
        if sess is not None:
            tree = sess._checkout()
        if tree is not None:
            warm_tree_check(tree, req.to_move, cfg)
            reused_nodes = int(tree.n_nodes) - 1
            reused_visits = float(tree.visits[0])
            eff_po, eff_tasks = warm_budget(
                cfg.n_playouts, cfg.n_tasks, cfg.n_workers, reused_visits)
            # compare=False fields: the replaced cfg hashes identically, so
            # the pool key and the quantum program are untouched
            cfg = dataclasses.replace(cfg, n_playouts=eff_po,
                                      n_tasks=eff_tasks)
        else:
            tree = init_tree(cfg.tree_cap, game.n_actions, req.to_move)
        metrics = None
        if cfg.metrics:
            from repro.obsv.search_metrics import init_search_metrics
            metrics = init_search_metrics(tree_nodes_reused=reused_nodes)
        return _SearchState(
            cfg=cfg, board=board, key=jax.random.key(req.seed),
            cp=jnp.asarray(cfg.cp, jnp.float32),
            schedule=sched.make_schedule(cfg.n_playouts, cfg.n_tasks,
                                         cfg.n_workers, cfg.scheduler),
            tree=tree,
            deadline=(None if req.deadline_s is None
                      else t.t_submit + req.deadline_s),
            metrics=metrics, session=sess,
            reused_nodes=reused_nodes, reused_visits=reused_visits)

    def _make_forest_state(self, cfg: GSCPMConfig, t: Ticket,
                           board: jnp.ndarray) -> _SearchState:
        """State for a forest tenant: E member trees on one position,
        ensemble axis sharded over the device mesh when one exists
        (padded to the device count with bitwise-inert members — see
        ``root_parallel.ensemble_sharding``). Per-member RNG streams are
        the ``gscpm_search_batch`` folding of the request seed, so a
        quantum-served forest is bit-identical to the uninterrupted batch
        search (tests/test_forest_serving equivalence)."""
        req = t.req
        E = cfg.n_trees
        mesh = ensemble_mesh()
        sharding, Ep = ensemble_sharding(E, mesh)
        forest = init_forest(E, cfg.tree_cap, cfg.game_obj.n_actions,
                             req.to_move)
        boards = jnp.tile(board[None, :], (E, 1))
        forest, boards = pad_forest_members(forest, boards, Ep, cfg,
                                            req.to_move)
        member_keys = fold_task_keys(jax.random.key(req.seed),
                                     jnp.arange(Ep, dtype=jnp.int32))
        if sharding is not None:
            forest, boards, member_keys = jax.device_put(
                (forest, boards, member_keys), sharding)
        metrics = None
        if cfg.metrics:
            from repro.obsv.search_metrics import init_search_metrics_forest
            metrics = init_search_metrics_forest(Ep)
        return _SearchState(
            cfg=cfg, board=boards, key=jax.random.key(req.seed),
            cp=jnp.asarray(cfg.cp, jnp.float32),
            schedule=sched.make_schedule(cfg.n_playouts, cfg.n_tasks,
                                         cfg.n_workers, cfg.scheduler),
            tree=forest,
            deadline=(None if req.deadline_s is None
                      else t.t_submit + req.deadline_s),
            metrics=metrics, n_trees=E, n_padded=Ep,
            member_keys=member_keys, mesh=mesh)

    # -- tick -------------------------------------------------------------
    def step(self) -> int:
        """One engine tick, double-buffered when ``self.pipeline``.

        The hot path — admission, quantum planning, round dispatch,
        retirement DETECTION (``round_idx``/``schedule`` are host state) —
        touches no device buffer. Retirements deferred by EARLIER ticks are
        materialized last, after this tick's quanta are already in flight,
        so their host readbacks overlap the device work instead of
        serializing with it (DESIGN.md §18). With pipelining off, ``ready``
        is always empty and ``_retire`` blocks inline as before.
        """
        ready, self._pending_retire = self._pending_retire, []
        self._admit_free_slots()
        live = [(ck, s, t) for ck, pool in self.pools.items()
                for s, t in enumerate(pool) if t is not None]
        if live:
            m = self._tick_m()
            failed: set = set()
            for ck, s, t in live:
                # fault containment boundary: a quantum that raises
                # (injected dispatch error, device loss, anything) is
                # contained to ITS slot — the search rolls back to its last
                # committed snapshot and requeues with backoff, the slot
                # takes a quarantine strike, and every other slot's quantum
                # still runs
                try:
                    self._run_slot(t, m, slot_key=(ck, s))
                except Exception as err:  # noqa: BLE001 — containment seam
                    self._fail_slot(ck, s, t, err)
                    failed.add(t.req.rid)
                else:
                    self._note_slot_ok((ck, s))
            for ck, s, t in live:
                if t.req.rid in failed:
                    continue
                st = self._states[t.req.rid]
                if st.expired or st.round_idx >= len(st.schedule):
                    self._retire(ck, s, t)
                elif self._should_preempt(t):
                    self._preempt(ck, s, t)
            self._sync_active()
        for ck, s, t, st, dev in ready:
            with self._device_wait("retire_summary", rid=t.req.rid):
                self._materialize_retirement(ck, s, t, st, dev)
        return len(live)

    def has_work(self) -> bool:
        # deferred retirements are still work: run() must not exit (and
        # run_trace must not sleep past) requests awaiting materialization
        return bool(self._pending_retire) or super().has_work()

    def _is_pending(self, rid) -> bool:
        # a deferred retirement still owns its rid: a duplicate submitted
        # inside the one-tick materialization window must not double-serve
        return (super()._is_pending(rid)
                or any(p[2].req.rid == rid for p in self._pending_retire))

    def _flat_slot(self, slot_key: tuple[GSCPMConfig, int]) -> int:
        """Flatten a (class, slot) key to the injector's slot index space
        (pool insertion order × slots_per_class + slot)."""
        ck, s = slot_key
        return list(self.pools).index(ck) * self.slots_per_class + s

    def _run_slot(self, t: Ticket, m: int,
                  slot_key: tuple[GSCPMConfig, int] | None = None) -> None:
        """One quantum: up to ``m`` schedule rounds of this request's
        search — the exact ``run_schedule_round`` calls (same key, same
        Round sequence) the uninterrupted driver would make, which is the
        whole bit-identity argument. With a tracer the quantum is recorded
        as an ``X`` span annotated with the rounds/iterations it actually
        covered (blocking on the device at span end so the duration is
        honest — a profiling perturbation, documented in DESIGN.md §15)."""
        st = self._states[t.req.rid]
        if self.injector is not None and slot_key is not None:
            ev = self.injector.dispatch_fault(self._flat_slot(slot_key))
            if ev is not None:
                self._record_injected(ev)
                raise InjectedFaultError(
                    f"injected dispatch failure: tick {self._ticks}, "
                    f"slot {self._flat_slot(slot_key)}, rid {t.req.rid}")
        span_args = {"rid": t.req.rid, "game": st.cfg.game, "rounds": 0,
                     "iterations": 0, "lane_iterations": 0,
                     "workers": st.cfg.n_workers} if self.tracer else None
        span = (self.tracer.span("quantum", span_args) if self.tracer
                else contextlib.nullcontext())
        with span:
            for _ in range(m):
                if st.round_idx >= len(st.schedule):
                    break
                if st.deadline is not None and self._now() >= st.deadline:
                    st.expired = True
                    if self.tracer:
                        self.tracer.instant("deadline_expiry", {
                            "rid": t.req.rid, "game": st.cfg.game,
                            "rounds_done": st.round_idx,
                            "rounds_total": len(st.schedule)})
                    if self.registry:
                        self.registry.counter(
                            "serve_deadline_expiries_total",
                            "searches retired on deadline").inc()
                    break
                rnd = st.schedule[st.round_idx]
                if st.n_trees > 1:
                    # root-parallel forest tenant: every member runs the
                    # SAME Round under its own folded key stream (pad
                    # members run all-inactive), sharded over the ensemble
                    # mesh when one exists
                    if st.cfg.metrics:
                        st.tree, st.metrics = run_schedule_round_forest(
                            st.tree, st.board, st.cfg, st.member_keys, rnd,
                            st.cp, st.metrics, n_real=st.n_trees,
                            mesh=st.mesh)
                    else:
                        st.tree = run_schedule_round_forest(
                            st.tree, st.board, st.cfg, st.member_keys, rnd,
                            st.cp, n_real=st.n_trees, mesh=st.mesh)
                elif st.cfg.metrics:
                    st.tree, st.metrics = run_schedule_round(
                        st.tree, st.board, st.cfg, st.key, rnd, st.cp,
                        st.metrics)
                else:
                    st.tree = run_schedule_round(st.tree, st.board, st.cfg,
                                                 st.key, rnd, st.cp)
                st.round_idx += 1
                # a forest request's budget is per member; the conservation
                # guard checks the ENSEMBLE total, so count all members
                st.playouts += st.n_trees * int(rnd.active.sum()) * rnd.m
                t.req.out.append(st.round_idx)   # committed progress
                if span_args is not None:
                    span_args["rounds"] += 1
                    span_args["iterations"] += int(rnd.m)
                    span_args["lane_iterations"] += (
                        int(rnd.active.sum()) * rnd.m)
            if self.tracer and span_args["rounds"] > 0:
                with self._device_wait("quantum_sync", rid=t.req.rid):
                    jax.block_until_ready(st.tree.visits)
        # commit point: snapshot the post-quantum state to the host, THEN
        # apply any planned poison — a later guard rejection rolls back to
        # here and replays the remaining rounds bit-identically. A dirty
        # snapshot (corruption that predates the copy — e.g. a poisoned
        # tree that ran another quantum before the guard could see it) must
        # NOT overwrite the last good commit point: rolling back into the
        # corruption would retry forever.
        if self._snapshots:
            with self._device_wait("snapshot", rid=t.req.rid):
                snap = resilience.snapshot_search(
                    st.tree, st.metrics, st.round_idx, st.playouts,
                    len(t.req.out))
            if resilience.snapshot_is_clean(snap):
                st.snap = snap
        if self.injector is not None and slot_key is not None:
            ev = self.injector.poison(self._flat_slot(slot_key))
            if ev is not None:
                self._record_injected(ev)
                st.tree = resilience.poison_root_stats(st.tree)

    # -- slot lifecycle ---------------------------------------------------
    def _retire(self, ck: GSCPMConfig, s: int, t: Ticket) -> None:
        """Dispatch the retirement summary on device; pull it NOW (blocking
        mode) or a tick later (``self.pipeline``), freeing the slot
        immediately so admission refills it while the readback is still in
        flight (DESIGN.md §18)."""
        st = self._states[t.req.rid]
        n_moves = st.cfg.game_obj.n_actions
        if self.tracer:
            # tracer implies pipelining is off: block here so the trace
            # attributes the retirement device sync honestly (§15)
            with self.tracer.span("device_sync", {"rid": t.req.rid}):
                with self._device_wait("device_sync", rid=t.req.rid):
                    jax.block_until_ready(st.tree.visits)
        if st.n_trees > 1:
            forest = st.tree
            if st.n_padded > st.n_trees:
                # sharding pads never ran a playout; slice them off so the
                # merge, vote, and node count see only real members
                forest = jax.tree.map(lambda x: x[:st.n_trees], forest)
            dev = forest_retire_summary(forest, n_moves)
        else:
            dev = root_summary_device(st.tree, n_moves)
        if self.pipeline:
            self._states.pop(t.req.rid)
            self.pools[ck][s] = None
            self._pending_retire.append((ck, s, t, st, dev))
            return
        with self._device_wait("retire_summary", rid=t.req.rid):
            self._materialize_retirement(ck, s, t, st, dev)

    def _materialize_retirement(self, ck: GSCPMConfig, s: int, t: Ticket,
                                st: _SearchState, dev: dict) -> None:
        """Pull a dispatched retirement summary to the host, run the result
        guard, and finalize the request. In blocking mode the search is
        still registered and the slot still held; in pipelined mode both
        were released at detection, so failure takes the deferred path."""
        deferred = t.req.rid not in self._states
        warm = st.session is not None or st.reused_nodes \
            or st.reused_visits > 0
        if st.n_trees > 1:
            res = materialize_forest_summary(dev, st.n_trees)
        else:
            res = materialize_root_summary(
                dev, reused_visits=int(st.reused_visits) if warm else None)
        if self.guard:
            # host-side result guard (DESIGN.md §17): a corrupted answer
            # never ships — it becomes a retry from the last committed
            # snapshot, and the slot takes a quarantine strike
            bad = resilience.validate_result(
                res, None if warm else st.playouts)
            if bad:
                if self.tracer:
                    self.tracer.instant("guard_reject", {
                        "rid": t.req.rid, "game": st.cfg.game, "slot": s,
                        "violations": "; ".join(bad)})
                if self.registry:
                    self.registry.counter(
                        "serve_guard_failures_total",
                        "retired answers rejected by the result "
                        "guard").inc()
                err = ResultGuardError("; ".join(bad))
                if deferred:
                    self._fail_deferred(ck, s, t, err)
                else:
                    self._fail_slot(ck, s, t, err)
                return
        if not deferred:
            self._states.pop(t.req.rid)
        t.t_done = self._now()
        res.update(
            game=st.cfg.game, board_size=st.cfg.board_size,
            playouts=st.playouts, rounds=st.round_idx,
            rounds_total=len(st.schedule), deadline_expired=st.expired,
            status="deadline_expired" if st.expired else "answered",
            retries=t.retries, preemptions=t.preemptions,
            queue_wait_s=t.t_admit - t.t_submit,
            latency_s=t.t_done - t.t_submit)
        if st.session is not None or st.reused_nodes:
            res["reused_nodes"] = st.reused_nodes
        if st.cfg.metrics:
            from repro.obsv.search_metrics import summarize_metrics
            mm = st.metrics
            if st.n_padded > st.n_trees:
                mm = jax.tree.map(lambda x: x[:st.n_trees], mm)
            res["metrics"] = summarize_metrics(mm)
        if self.pools[ck][s] is t:
            # blocking mode still holds the slot; a deferred retirement
            # freed it at detection and it may already host a new search
            self.pools[ck][s] = None
        t.req.result = res
        t.req.done = True
        if st.session is not None:
            # hand the finished tree back to its tenant: the session's
            # next ``play(move)`` re-roots it and the move after searches
            # warm — this is the whole cross-move reuse loop
            st.session._deliver(st.tree, res)
        self.finished.append(t.req)
        self.finished_tickets.append(t)
        if self.tracer:
            self.tracer.instant("retire", {
                "rid": t.req.rid, "game": st.cfg.game, "slot": s,
                "quanta": t.quanta, "preemptions": t.preemptions,
                "rounds": st.round_idx, "playouts": st.playouts,
                "deadline_expired": st.expired,
                "latency_s": round(t.t_done - t.t_submit, 6)})
        if self.registry:
            self.registry.counter("serve_requests_finished_total",
                                  "requests retired complete").inc()
            self.registry.counter("serve_playouts_total",
                                  "playouts committed across all "
                                  "retired searches").inc(st.playouts)

    def _preempt(self, ck: GSCPMConfig, s: int, t: Ticket) -> None:
        """Tail-requeue (round-robin sharing within the class). The tree
        stays in ``self._states`` — nothing to replay on re-admission."""
        self.pools[ck][s] = None
        t.preemptions += 1
        self.queue.append(t)
        if self.tracer:
            st = self._states[t.req.rid]
            self.tracer.instant("preempt", {
                "rid": t.req.rid, "game": ck.game, "slot": s,
                "quanta_run": t.quanta - t.quanta_at_admit,
                "rounds_done": st.round_idx,
                "progress": len(t.req.out) - t.seg_base})
        if self.registry:
            self.registry.counter("serve_preemptions_total",
                                  "over-budget requests requeued").inc()

    def _fail_slot(self, ck: GSCPMConfig, s: int, t: Ticket,
                   err: Exception) -> None:
        """Contain a slot failure: free the slot, roll the search back to
        its last committed snapshot (or rebuild it from round 0), requeue
        the ticket with exponential backoff, and count a quarantine strike
        against the slot. The driver loop never sees the exception.

        Rollback restores the EXACT device state of the commit point —
        tree, metrics accumulator, round index, committed-playouts count,
        and the ``out`` progress log — so the replayed rounds reproduce
        the uninterrupted search bit for bit (RNG streams depend only on
        ``(key, round.task_ids)``, never on wall-clock or retry count).
        """
        self.pools[ck][s] = None
        st = self._states[t.req.rid]
        if st.snap is not None:
            tree, metrics = resilience.restore_search(st.snap)
            st.tree = tree
            st.metrics = metrics
            st.round_idx = st.snap.round_idx
            st.playouts = st.snap.playouts
            st.expired = False
            del t.req.out[st.snap.out_len:]
        else:
            # no snapshot discipline (no injector attached and snapshots
            # not forced): the device state is suspect, so rebuild the
            # search from scratch — still a correct answer, just a cold
            # restart (a lost warm-session tree falls back to full budget)
            self._states.pop(t.req.rid)
            del t.req.out[:]
            self._states[t.req.rid] = self._make_state(
                self.request_cfg(t.req), t)
        self._requeue_for_retry(t, err)
        self._note_slot_failure((ck, s))
        self._sync_active()

    def _fail_deferred(self, ck: GSCPMConfig, s: int, t: Ticket,
                       err: Exception) -> None:
        """Guard rejection surfacing a tick AFTER the slot was freed: the
        search state was popped at detection and the slot may already host
        a new search, so only the ticket rolls back — a cold rebuild from
        round 0 (pipelining and snapshot discipline are mutually exclusive,
        so there is never a commit point to restore) plus a quarantine
        strike against the slot that produced the bad answer."""
        del t.req.out[:]
        self._states[t.req.rid] = self._make_state(
            self.request_cfg(t.req), t)
        self._requeue_for_retry(t, err)
        self._note_slot_failure((ck, s))
        self._sync_active()


# ---------------------------------------------------------------- session ----
class GameSession:
    """A stateful tenant: one game played move by move through the engine
    (DESIGN.md §16).

    The session owns the game's host-side position (board, side to move,
    move list) and — between searches — the device-resident search tree.
    Lifecycle per move:

    1. ``make_request(...)`` builds a ``GameRequest`` bound to this session
       (current position, current side, per-move seed); submit it to the
       engine and drive ``step()``/``run()`` as usual.
    2. At admission the engine checks the session's tree out
       (``_checkout``) and warm-starts the search from it; the budget
       shrinks by the retained root evidence (``warm_budget``), so
       ``n_playouts`` always means total evidence at the root.
    3. At retirement the searched tree is handed back (``_deliver``).
    4. ``play(move)`` applies the move to the board and re-roots the tree
       onto the played child (``core.tree.reroot_tree``) — the retained
       subtree seeds the NEXT search warm.

    One request may be in flight per session (the tree has one owner);
    ``make_request`` enforces it. ``reuse_tree=False`` keeps the full
    session bookkeeping but drops the tree at every ``play`` — the cold
    ablation arm of the self-play benchmark. Sessions add no new compiled
    programs: a session request is an ordinary request of its game class,
    sharing the class's slot pool and quantum program.
    """

    def __init__(self, engine: TPFIFOGameEngine, game: str, board_size: int,
                 *, reuse_tree: bool = True, base_seed: int = 0,
                 name: str | None = None):
        self.engine = engine
        self.game = game
        self.board_size = board_size
        self.reuse = reuse_tree
        self.base_seed = base_seed
        self.name = name or f"{game}{board_size}-{base_seed}"
        self.game_obj = game_mod.make_game(game, board_size)
        self.board = self.game_obj.init_board()
        self.to_move = 1
        self.moves: list[int] = []
        self.tree: Tree | None = None       # warm tree for the NEXT search
        self.last_result: dict | None = None
        # per-move retention telemetry (what examples/benchmarks print)
        self.retained_visits = 0.0
        self.retained_fraction = 0.0
        self._pending = False

    # -- engine-facing tree custody ---------------------------------------
    def _checkout(self) -> Tree | None:
        """Engine takes the tree at admission (single-owner discipline:
        ``run_chunk`` donates its buffers, so the session must not hold a
        reference while the search runs)."""
        tree, self.tree = self.tree, None
        return tree

    def _deliver(self, tree: Tree, result: dict) -> None:
        """Engine hands the searched tree back at retirement."""
        self.tree = tree if self.reuse else None
        self.last_result = result
        self._pending = False

    # -- client API -------------------------------------------------------
    def make_request(self, rid: Any = None, *, n_playouts: int = 512,
                     n_tasks: int = 16, cp: float = 1.0,
                     seed: int | None = None,
                     deadline_s: float | None = None) -> GameRequest:
        """A ``GameRequest`` for the session's current position.

        ``seed`` defaults to ``base_seed + move number`` — deterministic
        per-move streams, so whole games replay bit-identically.
        """
        if self._pending:
            raise RuntimeError(
                f"session {self.name}: a request is already in flight — "
                "the device tree has one owner; await its result and "
                "play() before searching again")
        self._pending = True
        return GameRequest(
            rid=(rid if rid is not None
                 else f"{self.name}#mv{len(self.moves)}"),
            game=self.game, board_size=self.board_size,
            to_move=self.to_move, n_playouts=n_playouts, n_tasks=n_tasks,
            cp=cp, seed=(self.base_seed + len(self.moves)
                         if seed is None else seed),
            deadline_s=deadline_s, board=np.asarray(self.board),
            session=self)

    def play(self, move: int) -> None:
        """Commit a move: update the position and re-root the tree onto the
        played child so the next search starts warm.

        Any legal move works — the opponent's reply included, whether or
        not this session's searches ever expanded it (an unseen move just
        yields a 1-node tree, a cold start in warm clothing).
        """
        if self._pending:
            raise RuntimeError(
                f"session {self.name}: cannot play() while a request is in "
                "flight — the engine owns the tree")
        move = int(move)
        legal = np.asarray(self.game_obj.legal_mask(self.board))
        if not legal[move]:
            raise ValueError(
                f"session {self.name}: illegal move {move} for "
                f"{self.game} at move {len(self.moves)}")
        if self.reuse and self.tree is not None:
            before = float(self.tree.visits[0])
            self.tree = reroot_tree(self.tree, move)
            self.retained_visits = float(self.tree.visits[0])
            self.retained_fraction = (self.retained_visits / before
                                      if before > 0 else 0.0)
        else:
            self.tree = None
            self.retained_visits = 0.0
            self.retained_fraction = 0.0
        self.board = self.game_obj.place(
            self.board, jnp.int32(move), jnp.int8(self.to_move))
        self.to_move = 3 - self.to_move
        self.moves.append(move)

    def winner(self) -> int:
        """Game status at the current position via ``Game.winner_probe``:
        -1 ongoing, 0 draw, 1/2 the winning player."""
        return int(self.game_obj.winner_probe(self.board))

    def over(self) -> bool:
        return self.winner() >= 0


# the protocol-level name; TPFIFO is the (only) scheduling flavor today
GameSearchEngine = TPFIFOGameEngine
