"""TPFIFO serving: a work-sharing FIFO request scheduler over device slots.

The paper's headline result is that a plain FIFO work-sharing thread pool
(TPFIFO) with controlled task grain out-scales work-stealing runtimes for
irregular MCTS workloads. This module ports that scheduler to the serving
layer (DESIGN.md §10): the *queue* holds requests, the *workers* are the B
fixed device slots of the batched engines, and the *task grain* is ``m``
micro-steps (decode ticks, or MCTS commit rounds) per dispatch.

Three layers:

- ``TPFIFODriver`` — the host-side pool: one FIFO queue of ``Ticket``s, B
  slots, per-request quantum plans derived from
  ``repro.core.scheduler.quantum_plan`` (the same disciplines the GSCPM
  round scheduler uses: ``fifo``/``rebalance`` slice requests into uniform
  grains, ``one_per_core`` runs each request to completion), preemption and
  requeue of over-budget requests, and per-request telemetry summarized by
  ``QueueStats``. `repro.serve.engine`'s lockstep engines subclass it with
  ``grain=None``; the TPFIFO engines below subclass it with a real grain.

- ``TPFIFOEngine`` — grain-size-controlled continuous batching for LM
  decode. One jitted quantum (``run_quantum``) advances ALL slots ``m``
  micro-steps; each micro-step feeds exactly one token per slot through
  ``api.decode``, so *prefill and decode share one program*: a slot whose
  cursor is still inside its context consumes the next context token
  (chunked prefill — a long prompt advances ``m`` positions per quantum and
  never blocks other slots' decode ticks), a slot past its context appends
  the token it just sampled. Shapes are fixed by ``(n_slots, max_len)`` and
  the grain ``m`` is a *traced* scalar, so admissions, retirements,
  preemptions, and grain changes never recompile — a finishing request's
  slot is refilled from the queue at the next dispatch within the same
  compiled step.

- ``TPFIFOMCTSEngine`` — the search-guided sibling: a quantum is ``m``
  search+commit rounds of ``mcts_decode_search_batch`` (each round is
  itself one jitted program over all slots), with the same queue,
  preemption, and telemetry.

Preemption is lossless: a preempted request keeps its generated tokens in
``Request.out``; on re-admission its context is ``prompt ⊕ out`` and the
chunked prefill recomputes the KV for the full context, so greedy decoding
resumes bit-identically.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import math
import time
import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sched
from repro.models import api
from repro.models.common import ModelConfig


# ------------------------------------------------------------------ queue ----
@dataclasses.dataclass
class Ticket:
    """Queue entry wrapping one request, with scheduling state + telemetry.

    ``req`` is duck-typed: the driver itself needs only ``rid``, ``out``
    (a list that grows with committed progress — the preemption guard's
    currency), and ``done``. The LM engines additionally read ``prompt``/
    ``max_new`` (``repro.serve.engine.Request``); the game-search engine
    reads its own fields (``repro.serve.games.GameRequest``).
    """
    req: Any
    t_submit: float
    t_admit: float | None = None        # first admission
    t_done: float | None = None
    quanta: int = 0                     # completed quanta (all segments)
    quanta_at_admit: int = 0            # snapshot at current admission
    preemptions: int = 0
    retries: int = 0                    # failure-driven requeues (faults,
                                        # guard rejections) — NOT preemptions
    not_before: int = 0                 # earliest tick this ticket may be
                                        # re-admitted (retry backoff gate)
    seg_base: int = 0                   # len(req.out) at current admission
    plan: list[int] | None = None       # remaining quantum sizes
    plan_idx: int = 0
    q_rem: int = 0                      # micro-steps left in current quantum


@dataclasses.dataclass(frozen=True)
class QueueStats:
    """Aggregate per-request telemetry for one serve run (seconds)."""
    n_finished: int
    n_preemptions: int
    tokens: int
    quanta: int
    wall_s: float
    throughput_tok_s: float
    queue_wait_p50: float
    queue_wait_p95: float
    service_p50: float
    service_p95: float
    latency_p50: float
    latency_p95: float
    # resilience telemetry (PR 9): failure-driven requeues, shed + still-
    # unfinished request counts, quarantined slots — defaults keep older
    # call sites and serialized stats comparable
    n_retries: int = 0
    n_shed: int = 0
    n_quarantined: int = 0
    n_unfinished: int = 0
    # host seconds spent BLOCKED on device readback (snapshots, retirement
    # summaries, lane-state pulls) across the whole run — the async-
    # pipelining currency (DESIGN.md §18): the pipelined engine hides this
    # time under the next tick's device work, the blocking one eats it
    device_wait_s: float = 0.0

    @classmethod
    def from_tickets(cls, tickets: list[Ticket], *, n_shed: int = 0,
                     n_quarantined: int = 0,
                     device_wait_s: float = 0.0) -> "QueueStats":
        # progress accounting covers ALL tickets — a run that preempted
        # requests but finished none still reports its preemptions, quanta,
        # and committed tokens (they live in req.out across requeues);
        # latency percentiles are defined only for finished requests.
        n_preempt = sum(t.preemptions for t in tickets)
        quanta = sum(t.quanta for t in tickets)
        tokens = sum(len(t.req.out) for t in tickets)
        extras = dict(
            n_retries=sum(t.retries for t in tickets), n_shed=n_shed,
            n_quarantined=n_quarantined,
            n_unfinished=sum(1 for t in tickets if t.t_done is None),
            device_wait_s=device_wait_s)
        done = [t for t in tickets if t.t_done is not None]
        if not done:
            return cls(0, n_preempt, tokens, quanta, 0.0, 0.0,
                       *([0.0] * 6), **extras)
        waits = np.asarray([t.t_admit - t.t_submit for t in done])
        service = np.asarray([t.t_done - t.t_admit for t in done])
        latency = np.asarray([t.t_done - t.t_submit for t in done])
        t0 = min(t.t_submit for t in done)
        wall = max(t.t_done for t in done) - t0
        tokens_done = sum(len(t.req.out) for t in done)
        p = np.percentile
        return cls(
            n_finished=len(done),
            n_preemptions=n_preempt,
            tokens=tokens,
            quanta=quanta,
            wall_s=wall,
            throughput_tok_s=tokens_done / max(wall, 1e-9),
            queue_wait_p50=float(p(waits, 50)),
            queue_wait_p95=float(p(waits, 95)),
            service_p50=float(p(service, 50)),
            service_p95=float(p(service, 95)),
            latency_p50=float(p(latency, 50)),
            latency_p95=float(p(latency, 95)),
            **extras,
        )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


# ----------------------------------------------------------------- driver ----
class TPFIFODriver:
    """Host-side work-sharing FIFO pool: one queue, B device-slot workers.

    Subclasses implement ``step()`` (one engine tick) and ``_load_slot``
    (move an admitted ticket's request into device-slot state). Lockstep
    engines pass ``grain=None`` (no quantum plans, no preemption); grained
    engines get per-request plans from ``scheduler.quantum_plan`` and call
    ``_tick_m()`` for each dispatch's micro-step count.

    Observability (DESIGN.md §15) is attach-to-enable: ``tracer`` (a
    ``repro.obsv.TraceRecorder``) records admission/retire/preempt instants,
    per-tick spans, queue-depth counter tracks, and jit-compile events;
    ``registry`` (a ``repro.obsv.MetricsRegistry``) keeps running
    counters/gauges. Both default to ``None`` and cost nothing detached.
    """

    def __init__(self, n_slots: int, grain: int | None = None,
                 policy: str = "fifo", preempt_quanta: int | None = None,
                 max_queue: int | None = None,
                 quarantine_after: int | None = None, injector=None,
                 retry_backoff: tuple[int, int] = (1, 8),
                 tracer=None, registry=None):
        if grain is not None and policy not in (
                "fifo", "rebalance", "one_per_core", "sequential"):
            raise ValueError(f"unknown TPFIFO policy: {policy!r}")
        if grain is not None and grain < 1:
            raise ValueError(f"grain must be >= 1, got {grain}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if quarantine_after is not None and quarantine_after < 1:
            raise ValueError(
                f"quarantine_after must be >= 1, got {quarantine_after}")
        self.B = n_slots
        self.grain = grain
        self.policy = policy
        self.preempt_quanta = preempt_quanta
        # resilience knobs (DESIGN.md §17): bounded admission per queue
        # class, slot quarantine after k CONSECUTIVE failures, retry
        # backoff of min(base * 2**(retries-1), cap) ticks, and an optional
        # deterministic FaultInjector driving chaos
        self.max_queue = max_queue
        self.quarantine_after = quarantine_after
        self.injector = injector
        self.backoff_base, self.backoff_cap = retry_backoff
        self.tracer = tracer
        self.registry = registry
        self.queue: collections.deque[Ticket] = collections.deque()
        self.active: list[Ticket | None] = [None] * n_slots
        self.finished: list[Any] = []            # Request objects (public)
        self.finished_tickets: list[Ticket] = []
        self.shed: list[Any] = []                # load-shed Request objects
        self.quarantined: set = set()            # slot keys out of service
        self._slot_strikes: dict = {}            # slot key -> consecutive fails
        self.admission_order: list[Any] = []     # rids, in admission order
        self.device_wait_s = 0.0                 # host blocked on readback
        self._t0 = time.perf_counter()
        self._ticks = 0

    # -- clock / queue ----------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    @contextlib.contextmanager
    def _device_wait(self, what: str, rid=None):
        """Account (and trace) a host block on device readback.

        Wrap every ``block_until_ready`` / ``np.asarray``-of-device-buffer
        on the serving path in one of these: ``stats().device_wait_s`` and
        the Perfetto ``device_wait`` spans are how the pipelining win is
        MEASURED rather than inferred (DESIGN.md §18).
        """
        args = {"what": what}
        if rid is not None:
            args["rid"] = rid
        t0 = time.perf_counter()
        with (self.tracer.span("device_wait", args) if self.tracer
              else contextlib.nullcontext()):
            try:
                yield
            finally:
                self.device_wait_s += time.perf_counter() - t0

    def _queue_load(self, req) -> int:
        """Pending requests competing with ``req`` for admission (the
        ``max_queue`` currency). Engines with partitioned slot pools narrow
        this to the request's own class."""
        return len(self.queue)

    def _is_pending(self, rid) -> bool:
        return (any(t is not None and t.req.rid == rid for t in self.active)
                or any(t.req.rid == rid for t in self.queue))

    def _shed(self, req) -> None:
        """Load shedding: retire the request immediately with
        ``status="shed"`` instead of raising or queueing unboundedly."""
        req.done = True
        req.result = {"status": "shed", "reason": "queue_full"}
        self.shed.append(req)
        if self.tracer:
            self.tracer.instant("shed", {"rid": req.rid,
                                         "queue_depth": len(self.queue)})
        if self.registry:
            self.registry.counter(
                "serve_shed_total",
                "requests shed at admission (queue full)").inc()

    def submit(self, req, at: float | None = None) -> bool:
        """Enqueue a request; ``at`` overrides the submit timestamp (trace
        replay records the scheduled arrival, not the injection instant).

        Returns False without queueing when the request is a duplicate of a
        still-pending rid (client retry storms must not double-serve — the
        engine's state table is keyed by rid) or when ``max_queue`` sheds
        it (``req.result["status"] == "shed"``); True when queued.
        """
        if self._is_pending(req.rid):
            if self.tracer:
                self.tracer.instant("duplicate_dropped", {"rid": req.rid})
            if self.registry:
                self.registry.counter(
                    "serve_duplicates_dropped_total",
                    "duplicate submissions of a pending rid dropped").inc()
            return False
        if self.max_queue is not None and self._queue_load(req) >= \
                self.max_queue:
            self._shed(req)
            return False
        self.queue.append(Ticket(req=req,
                                 t_submit=self._now() if at is None else at))
        return True

    def has_work(self) -> bool:
        return bool(self.queue) or any(t is not None for t in self.active)

    def _next_admissible(self, held: list[Ticket]) -> Ticket | None:
        """Pop the first queue ticket past its retry-backoff gate; gated
        tickets go to ``held`` and keep their FIFO position."""
        while self.queue:
            t = self.queue.popleft()
            if t.not_before > self._ticks:
                held.append(t)
                continue
            return t
        return None

    def _restore_held(self, held: list[Ticket]) -> None:
        for t in reversed(held):
            self.queue.appendleft(t)

    # -- slot lifecycle ---------------------------------------------------
    def _admit_free_slots(self) -> list[int]:
        """FIFO admission: every free, non-quarantined slot takes the first
        admissible (backoff-gated tickets keep their place) queue head."""
        admitted = []
        held: list[Ticket] = []
        for s in range(self.B):
            if self.active[s] is None and s not in self.quarantined \
                    and self.queue:
                t = self._next_admissible(held)
                if t is None:
                    break
                if t.t_admit is None:
                    t.t_admit = self._now()
                t.quanta_at_admit = t.quanta
                t.seg_base = len(t.req.out)
                if self.grain is not None:
                    t.plan = sched.quantum_plan(self._work_estimate(t),
                                                self.grain, self.policy)
                    t.plan_idx = 0
                    t.q_rem = t.plan[0]
                self.active[s] = t
                self.admission_order.append(t.req.rid)
                self._load_slot(s, t)
                admitted.append(s)
                if self.tracer:
                    self.tracer.instant("admission", {
                        "rid": t.req.rid, "slot": s,
                        "resumed": t.preemptions > 0,
                        "wait_s": round(t.t_admit - t.t_submit, 6)})
                if self.registry:
                    self.registry.counter(
                        "serve_admissions_total",
                        "requests admitted into a device slot").inc()
        self._restore_held(held)
        return admitted

    def _retire_slot(self, s: int):
        t = self.active[s]
        self.active[s] = None
        t.t_done = self._now()
        t.req.done = True
        self.finished.append(t.req)
        self.finished_tickets.append(t)
        if self.tracer:
            self.tracer.instant("retire", {
                "rid": t.req.rid, "slot": s, "quanta": t.quanta,
                "preemptions": t.preemptions, "tokens": len(t.req.out),
                "latency_s": round(t.t_done - t.t_submit, 6)})
        if self.registry:
            self.registry.counter("serve_requests_finished_total",
                                  "requests retired complete").inc()
            self.registry.counter("serve_tokens_total",
                                  "committed progress units "
                                  "(tokens / moves)").inc(len(t.req.out))

    def _preempt_slot(self, s: int):
        """Requeue an over-budget request at the tail (round-robin sharing);
        generated tokens stay in ``req.out`` and are re-prefilled on
        re-admission, so nothing is lost."""
        t = self.active[s]
        self.active[s] = None
        t.preemptions += 1
        self.queue.append(t)
        if self.tracer:
            self.tracer.instant("preempt", {
                "rid": t.req.rid, "slot": s,
                "quanta_run": t.quanta - t.quanta_at_admit,
                "progress": len(t.req.out) - t.seg_base})
        if self.registry:
            self.registry.counter("serve_preemptions_total",
                                  "over-budget requests requeued").inc()

    def _waiting_for(self, t: Ticket) -> bool:
        """Would preempting ``t`` let queued work run?

        The flat-pool engines say yes whenever anything queues; engines
        with PARTITIONED slot pools (``repro.serve.games`` keeps one pool
        per game class) narrow this to waiters that can actually use the
        freed slot — preempting for a stranger of another class would only
        idle the slot.
        """
        return bool(self.queue)

    def _should_preempt(self, t: Ticket, progressed: bool | None = None) -> bool:
        # progress guard: a segment is only preemptible once it has
        # committed a fresh token — otherwise a resumed request whose
        # context replay outlasts its quantum budget would be requeued
        # before ever reaching emission and livelock at zero progress
        if progressed is None:
            progressed = len(t.req.out) > t.seg_base
        return (self.preempt_quanta is not None
                and self.policy not in ("one_per_core", "sequential")
                and t.quanta - t.quanta_at_admit >= self.preempt_quanta
                and progressed
                and self._waiting_for(t))

    # -- resilience (DESIGN.md §17) ---------------------------------------
    def _backoff_ticks(self, retries: int) -> int:
        """Capped exponential backoff: min(base * 2**(k-1), cap) ticks."""
        return min(self.backoff_base << max(0, retries - 1),
                   self.backoff_cap)

    def _requeue_for_retry(self, t: Ticket, err: BaseException) -> None:
        """Tail-requeue a failed ticket with retry count + backoff gate.
        FIFO fairness is preserved: the ticket rejoins the queue like a
        preempted one, and the backoff gate holds its *admission*, not its
        queue position."""
        t.retries += 1
        t.not_before = self._ticks + self._backoff_ticks(t.retries)
        self.queue.append(t)
        if self.tracer:
            self.tracer.instant("retry", {
                "rid": t.req.rid, "retries": t.retries,
                "error": type(err).__name__,
                "not_before_tick": t.not_before})
        if self.registry:
            self.registry.counter(
                "serve_retries_total",
                "failed dispatches requeued for retry").inc()

    def _healthy_peers(self, slot_key) -> int:
        """Slots still in service in ``slot_key``'s pool (flat pool here;
        per-class engines narrow it)."""
        return self.B - len(self.quarantined)

    def _note_slot_ok(self, slot_key) -> None:
        self._slot_strikes.pop(slot_key, None)

    def _note_slot_failure(self, slot_key) -> bool:
        """Record a slot failure; quarantine the slot after
        ``quarantine_after`` CONSECUTIVE failures — unless it is the last
        healthy slot of its pool (the engine degrades gracefully on
        survivors; it never quarantines itself to a standstill)."""
        strikes = self._slot_strikes.get(slot_key, 0) + 1
        self._slot_strikes[slot_key] = strikes
        if (self.quarantine_after is None
                or strikes < self.quarantine_after
                or self._healthy_peers(slot_key) <= 1):
            return False
        self.quarantined.add(slot_key)
        self._slot_strikes.pop(slot_key, None)
        if self.tracer:
            self.tracer.instant("quarantine", {
                "slot": str(slot_key), "strikes": strikes})
        if self.registry:
            self.registry.counter(
                "serve_slots_quarantined_total",
                "slots removed from service after repeated failures").inc()
        return True

    def _record_injected(self, ev) -> None:
        """Telemetry for a fault event that actually fired."""
        self.injector.record_fired(ev)
        if self.tracer:
            self.tracer.instant("fault", {
                "kind": ev.kind, "slot": ev.slot, "tick": self._ticks})
        if self.registry:
            self.registry.counter(
                "serve_faults_injected_total",
                "fault-injector events that fired").inc()

    def _apply_driver_fault(self, ev) -> None:
        """Driver-level fault kinds, applied at the top of ``_tick``."""
        if ev.kind == "clock_stall":
            # the engine clock jumps forward by stall_s: every deadline
            # gets closer, queue waits inflate — a simulated GC pause
            self._t0 -= ev.stall_s
            self._record_injected(ev)
        elif ev.kind == "duplicate_submit":
            victims = ([t.req for t in self.active if t is not None]
                       + [t.req for t in self.queue])
            if victims:
                self._record_injected(ev)
                self.submit(victims[ev.slot % len(victims)])

    # -- grain accounting -------------------------------------------------
    def _work_estimate(self, t: Ticket) -> int:
        """Micro-steps this admission segment needs (engine-specific)."""
        raise NotImplementedError

    def _tick_m(self) -> int:
        """Micro-steps for this dispatch.

        ``fifo`` dispatches exactly the configured grain — slots whose plan
        boundary falls mid-dispatch just account for it (cutting every
        dispatch to the smallest pending quantum would let staggered
        arrivals fragment the grain to nothing). ``rebalance`` re-splits
        idle slots' lane budget over the active ones (larger quanta keep
        device work per dispatch constant — the serving analogue of the
        scheduler's no-idle-lanes re-split). ``one_per_core`` dispatches
        until the LONGEST active request completes: one monolithic task per
        lane, the paper's baseline — and its head-of-line pathology.
        """
        live = [t for t in self.active if t is not None]
        if self.policy in ("one_per_core", "sequential"):
            m = max(max(1, t.q_rem) for t in live)
        elif self.policy == "rebalance" and len(live) < self.B:
            m = math.ceil(self.grain * self.B / len(live))
        else:
            m = self.grain
        for t in live:
            t.q_rem -= m
            while t.q_rem <= 0:
                t.quanta += 1
                t.plan_idx += 1
                t.q_rem += (t.plan[t.plan_idx] if t.plan_idx < len(t.plan)
                            else self.grain)
        return m

    # -- engine interface -------------------------------------------------
    def _load_slot(self, s: int, t: Ticket):
        raise NotImplementedError

    def step(self) -> int:
        raise NotImplementedError

    # -- run loops --------------------------------------------------------
    def _tick(self):
        """One observed engine tick: step(), wrapped in a trace span when a
        tracer is attached, plus queue/slot gauge updates. With a
        ``FaultInjector`` attached, this is also the chaos boundary: the
        tick's planned events are armed here, driver-level kinds (clock
        stalls, duplicate submissions) applied immediately, slot-level
        kinds consumed by the engine around each slot's quantum."""
        if self.injector is not None:
            for ev in self.injector.begin_tick(self._ticks):
                self._apply_driver_fault(ev)
        if self.tracer:
            with self.tracer.span("tick", {"tick": self._ticks}):
                self.step()
            self.tracer.counter("queue", {
                "depth": len(self.queue),
                "active": sum(t is not None for t in self.active)})
            self.tracer.poll_compiles()
        else:
            self.step()
        if self.registry:
            self.registry.counter("serve_ticks_total",
                                  "engine ticks dispatched").inc()
            self.registry.gauge("serve_queue_depth",
                                "requests waiting").set(len(self.queue))
            self.registry.gauge("serve_active_slots",
                                "occupied device slots").set(
                sum(t is not None for t in self.active))
        self._ticks += 1

    def _check_exhausted(self, what: str, budget: int,
                         on_exhaust: str) -> None:
        """Tick budget ran out with work still pending: silent work loss is
        a hang in disguise, so the default is to raise with the unfinished
        rids (``on_exhaust="warn"`` downgrades to a RuntimeWarning,
        ``"ignore"`` is the deliberate early-stop escape hatch; either way
        ``stats().n_unfinished`` reports the leftovers)."""
        if not self.has_work() or on_exhaust == "ignore":
            return
        unfinished = ([t.req.rid for t in self.active if t is not None]
                      + [t.req.rid for t in self.queue])
        msg = (f"{what}={budget} exhausted with {len(unfinished)} request(s)"
               f" unfinished: {unfinished[:8]}"
               f"{'...' if len(unfinished) > 8 else ''} — raise the tick "
               "budget, or pass on_exhaust='warn'/'ignore' for a deliberate "
               "early stop")
        if on_exhaust == "warn":
            warnings.warn(msg, RuntimeWarning, stacklevel=3)
        else:
            raise RuntimeError(msg)

    def run(self, max_ticks: int = 10_000,
            on_exhaust: str = "raise") -> list:
        """Drain loop: tick until the queue and all slots are empty.

        ``max_ticks`` bounds THIS call (``self._ticks`` keeps the lifetime
        total for telemetry) so a long-lived engine can run repeatedly.
        Exhausting the budget with tickets still queued or active raises by
        default (see ``_check_exhausted``) — an engine that quietly returns
        with unserved work is indistinguishable from one that hung.
        """
        ticks = 0
        while self.has_work() and ticks < max_ticks:
            self._tick()
            ticks += 1
        self._check_exhausted("max_ticks", max_ticks, on_exhaust)
        return self.finished

    def run_trace(self, trace: list[tuple[float, Any]],
                  max_ticks: int = 1_000_000,
                  on_exhaust: str = "raise") -> list:
        """Replay an arrival trace of ``(arrival_s, request)`` against the
        wall clock (arrival_s relative to the call instant).

        Arrivals are offset to the current clock rather than re-seating the
        engine epoch, so timestamps of requests already submitted (and of
        earlier runs) stay valid in ``stats()``.
        """
        base = self._now()
        pending = collections.deque(
            sorted(((base + t, req) for t, req in trace), key=lambda p: p[0]))
        ticks = 0
        while (pending or self.has_work()) and ticks < max_ticks:
            now = self._now()
            while pending and pending[0][0] <= now:
                at, req = pending.popleft()
                self.submit(req, at=at)
            if self.has_work():
                self._tick()
                ticks += 1
            elif pending:
                time.sleep(min(pending[0][0] - now, 1e-3))
        self._check_exhausted("max_ticks", max_ticks, on_exhaust)
        return self.finished

    def stats(self) -> QueueStats:
        """Telemetry over every ticket the driver has seen: finished,
        still-active, and queued — so a mid-run (or never-finishing) serve
        still reports its preemptions, quanta, and committed progress."""
        live = [t for t in self.active if t is not None]
        return QueueStats.from_tickets(
            self.finished_tickets + live + list(self.queue),
            n_shed=len(self.shed), n_quarantined=len(self.quarantined),
            device_wait_s=self.device_wait_s)


# ---------------------------------------------------------- jitted quantum ----
class LaneState(NamedTuple):
    """Per-slot device state for the unified prefill/decode micro-step.

    tokens: (B, L) i32 context ⊕ generated; pos: (B,) next KV write
    position; in_tok: (B,) token to feed at pos; ctx_len: (B,) context
    length (prompt ⊕ resumed tokens); gen: (B,) tokens generated this
    segment; budget: (B,) segment generation budget; live: (B,) slot is
    occupied and unfinished (dead lanes are frozen, not skipped — the batch
    shape never changes).
    """
    tokens: jnp.ndarray
    pos: jnp.ndarray
    in_tok: jnp.ndarray
    ctx_len: jnp.ndarray
    gen: jnp.ndarray
    budget: jnp.ndarray
    live: jnp.ndarray


def sample_tokens(logits: jnp.ndarray, key: jax.Array,
                  temperature: float = 0.0) -> jnp.ndarray:
    """(B, 1, V) -> (B, 1) greedy (t=0) or temperature sampling.

    Lives here (not ``serve.engine``) so both the lockstep engines and the
    jitted quantum share one sampling implementation without an import
    cycle; ``serve.engine`` re-exports it.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature,
        axis=-1).astype(jnp.int32)


def _sample(logits: jnp.ndarray, key: jax.Array, temperature: float):
    """(B, 1, V) -> (B,) — the quantum's squeezed view of sample_tokens."""
    return sample_tokens(logits, key, temperature)[:, 0]


@functools.partial(jax.jit, static_argnames=("mcfg", "temperature"),
                   donate_argnums=(1, 2))
def run_quantum(params, state: LaneState, cache, key: jax.Array, m, eos_id,
                *, mcfg: ModelConfig, temperature: float):
    """One grain-sized work quantum: ``m`` micro-steps for ALL B slots.

    Each micro-step is one ``api.decode`` over the whole slot batch at each
    slot's own cursor. A slot still inside its context feeds the next
    context token (chunked prefill); a slot past its context feeds — and
    records — the token it just sampled (decode). Finished/empty lanes are
    frozen in place. ``m`` and ``eos_id`` are traced, shapes are fixed by
    ``(n_slots, max_len)``: one compiled program serves every tick of every
    occupancy and every grain size.
    """
    B, L = state.tokens.shape
    slot = jnp.arange(B)

    def micro(t, carry):
        st, cache = carry
        logits, cache = api.decode(params, mcfg, st.in_tok[:, None],
                                   st.pos, cache)
        sampled = _sample(logits, jax.random.fold_in(key, t), temperature)
        new_pos = st.pos + 1
        # this step fed the last context token (or a generated one): its
        # logits produce a fresh token for the slot
        emitting = st.live & (new_pos >= st.ctx_len)
        wpos = jnp.minimum(new_pos, L - 1)
        cur = st.tokens[slot, wpos]
        tokens = st.tokens.at[slot, wpos].set(
            jnp.where(emitting, sampled, cur))
        gen = st.gen + emitting.astype(jnp.int32)
        finished = emitting & ((sampled == eos_id) | (gen >= st.budget)
                               | (new_pos >= L - 1))
        return LaneState(
            tokens=tokens,
            pos=jnp.where(st.live, new_pos, st.pos),
            in_tok=jnp.where(st.live, tokens[slot, wpos], st.in_tok),
            ctx_len=st.ctx_len,
            gen=gen,
            budget=st.budget,
            live=st.live & ~finished,
        ), cache

    return jax.lax.fori_loop(0, m, micro, (state, cache))


@functools.partial(jax.jit, donate_argnums=(0,))
def load_slot(state: LaneState, s, row, ctx_len, budget) -> LaneState:
    """Admit one request into slot ``s`` (traced — one compiled program
    serves every slot): context row in, cursor to 0, lane made live."""
    return LaneState(
        tokens=state.tokens.at[s].set(row),
        pos=state.pos.at[s].set(0),
        in_tok=state.in_tok.at[s].set(row[0]),
        ctx_len=state.ctx_len.at[s].set(ctx_len),
        gen=state.gen.at[s].set(0),
        budget=state.budget.at[s].set(budget),
        live=state.live.at[s].set(True),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def free_slot(state: LaneState, s) -> LaneState:
    """Kill slot ``s``'s lane (preemption): the frozen lane stops burning
    micro-steps until an admission overwrites it."""
    return state._replace(live=state.live.at[s].set(False))


@functools.partial(jax.jit, static_argnames=("axes_def",),
                   donate_argnums=(0,))
def reset_slot_rows(cache, mask, *, axes_def: tuple):
    """Zero the cache rows of admitted slots (mask: (B,) bool).

    Attention KV rows are masked by position anyway, but recurrent-state
    leaves (ssm/xlstm families) are cumulative — a refilled slot must start
    its chunked re-prefill from a clean state.
    """
    leaves, treedef = jax.tree.flatten(cache)
    out = []
    for x, bi in zip(leaves, axes_def):
        shape = [1] * x.ndim
        shape[bi] = x.shape[bi]
        m = mask.reshape(shape)
        out.append(jnp.where(m, jnp.zeros((), x.dtype), x))
    return jax.tree.unflatten(treedef, out)


# ------------------------------------------------------------- LM engine ----
class TPFIFOEngine(TPFIFODriver):
    """Work-sharing FIFO LM server with grain-controlled continuous batching.

    B device slots over one KV cache; each tick dispatches ONE jitted
    quantum of ``m`` unified prefill/decode micro-steps (``run_quantum``).
    Long prompts prefill in grain-sized chunks alongside other slots'
    decodes; finished slots refill from the queue at the next dispatch with
    no shape change; over-budget requests are preempted and requeued
    losslessly (``preempt_quanta``).
    """

    def __init__(self, params, cfg: ModelConfig, n_slots: int, max_len: int,
                 grain: int = 8, policy: str = "fifo",
                 preempt_quanta: int | None = None, temperature: float = 0.0,
                 eos_id: int = 2, seed: int = 0, tracer=None, registry=None):
        super().__init__(n_slots, grain=grain, policy=policy,
                         preempt_quanta=preempt_quanta, tracer=tracer,
                         registry=registry)
        if tracer is not None:
            tracer.watch_compiles("run_quantum", run_quantum)
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.temperature = temperature
        self.eos_id = eos_id
        self.key = jax.random.key(seed)

        self.cache = api.init_cache(cfg, n_slots, max_len)
        self._axes_def = tuple(jax.tree.leaves(
            api.cache_batch_axes(cfg, n_slots, max_len)))
        # device-resident lane state: per tick the host pulls only the (B,)
        # live/gen vectors; token rows cross back only at retire/preempt
        # boundaries, so tick cost is one quantum dispatch + two scalarish
        # transfers regardless of grain
        B = n_slots
        self._state = LaneState(
            tokens=jnp.zeros((B, max_len), jnp.int32),
            pos=jnp.zeros((B,), jnp.int32),
            in_tok=jnp.zeros((B,), jnp.int32),
            ctx_len=jnp.ones((B,), jnp.int32),
            gen=jnp.zeros((B,), jnp.int32),
            budget=jnp.zeros((B,), jnp.int32),
            live=jnp.zeros((B,), bool))
        self._host_ctx_len = np.ones((B,), np.int32)

    def submit(self, req, at: float | None = None) -> bool:
        if len(req.prompt) + req.max_new >= self.max_len:
            raise ValueError(
                f"prompt ({len(req.prompt)}) + max_new ({req.max_new}) "
                f"must stay below max_len ({self.max_len})")
        return super().submit(req, at=at)

    # -- TPFIFODriver hooks ----------------------------------------------
    def _work_estimate(self, t: Ticket) -> int:
        # context replay + remaining generation: emission starts on the
        # micro-step that feeds the LAST context token, so the total is
        # ctx_len + budget - 1, not ctx_len + budget. Invariant across
        # resumes: ctx grows by exactly the tokens the budget shrinks by.
        return len(t.req.prompt) + t.req.max_new - 1

    def _load_slot(self, s: int, t: Ticket):
        req = t.req
        ctx = np.asarray(list(req.prompt) + list(req.out), np.int32)
        row = np.zeros((self.max_len,), np.int32)
        row[:len(ctx)] = ctx
        self._host_ctx_len[s] = len(ctx)
        self._state = load_slot(
            self._state, jnp.asarray(s, jnp.int32), jnp.asarray(row),
            jnp.asarray(len(ctx), jnp.int32),
            jnp.asarray(req.max_new - len(req.out), jnp.int32))

    def _sync_out(self, s: int, t: Ticket, gen: int):
        """Pull slot ``s``'s generated tokens into ``req.out`` (boundary
        crossings only: retire, preempt, or an explicit flush)."""
        pl = int(self._host_ctx_len[s])
        row = np.asarray(self._state.tokens[s])
        t.req.out[t.seg_base:] = row[pl:pl + gen].tolist()

    # -- tick -------------------------------------------------------------
    def step(self) -> int:
        admitted = self._admit_free_slots()
        if admitted:
            mask = np.zeros((self.B,), bool)
            mask[admitted] = True
            self.cache = reset_slot_rows(self.cache, jnp.asarray(mask),
                                         axes_def=self._axes_def)
        if not any(t is not None for t in self.active):
            return 0
        m = self._tick_m()
        self.key, k = jax.random.split(self.key)
        self._state, self.cache = run_quantum(
            self.params, self._state, self.cache, k,
            jnp.asarray(m, jnp.int32), jnp.asarray(self.eos_id, jnp.int32),
            mcfg=self.cfg, temperature=self.temperature)
        # the tick's only mandatory readback: two (B,) scalar vectors the
        # scheduler needs; token rows stay on device until retire/preempt
        with self._device_wait("lane_summary"):
            live = np.asarray(self._state.live)
            gen = np.asarray(self._state.gen)

        served = 0
        for s, t in enumerate(self.active):
            if t is None:
                continue
            served += 1
            if not live[s]:
                self._sync_out(s, t, int(gen[s]))
                self._retire_slot(s)
            elif self._should_preempt(t, progressed=bool(gen[s] > 0)):
                self._sync_out(s, t, int(gen[s]))
                self._state = free_slot(self._state,
                                        jnp.asarray(s, jnp.int32))
                self._preempt_slot(s)
        return served


# ----------------------------------------------------------- MCTS engine ----
class TPFIFOMCTSEngine(TPFIFODriver):
    """TPFIFO over search-guided decoding: a quantum is ``m`` search+commit
    rounds of ``mcts_decode_search_batch`` (each round already advances all
    slots' trees through one jitted program). Admission, preemption, and
    requeue happen only at quantum boundaries — the grain dial trades
    scheduling responsiveness against per-round host dispatch, exactly the
    paper's Table I axis.
    """

    def __init__(self, params, cfg: ModelConfig, dcfg, n_slots: int,
                 max_prompt_len: int, grain: int = 4, policy: str = "fifo",
                 preempt_quanta: int | None = None, eos_id: int = 2,
                 seed: int = 0, tracer=None, registry=None):
        super().__init__(n_slots, grain=grain, policy=policy,
                         preempt_quanta=preempt_quanta, tracer=tracer,
                         registry=registry)
        self.params = params
        self.cfg = cfg
        self.dcfg = dcfg
        self.max_prompt_len = max_prompt_len
        self.eos_id = eos_id
        self.key = jax.random.key(seed)
        self.tokens = np.zeros((n_slots, max_prompt_len), np.int32)
        self.lens = np.ones((n_slots,), np.int32)
        self._done = np.zeros((n_slots,), bool)
        self.search_stats: collections.deque = collections.deque(maxlen=256)

    def submit(self, req, at: float | None = None) -> bool:
        if len(req.prompt) + req.max_new > self.max_prompt_len:
            raise ValueError(
                f"prompt ({len(req.prompt)}) + max_new ({req.max_new}) "
                f"exceeds max_prompt_len ({self.max_prompt_len})")
        return super().submit(req, at=at)

    def _work_estimate(self, t: Ticket) -> int:
        return t.req.max_new - len(t.req.out)     # commit rounds remaining

    def _load_slot(self, s: int, t: Ticket):
        req = t.req
        ctx = np.asarray(list(req.prompt) + list(req.out), np.int32)
        L = len(ctx)
        self.tokens[s, :] = 0
        self.tokens[s, :L] = ctx
        self.lens[s] = L
        self._done[s] = False

    def step(self) -> int:
        from repro.serve.mcts_decode import mcts_decode_search_batch

        self._admit_free_slots()
        if not any(t is not None for t in self.active):
            return 0
        m = self._tick_m()
        served = 0
        for _ in range(m):
            mask = np.array([t is not None for t in self.active]) & ~self._done
            if not mask.any():
                break           # grain tail after every slot finished
            served = max(served, int(mask.sum()))
            self.key, k = jax.random.split(self.key)
            _, stats = mcts_decode_search_batch(
                self.params, self.cfg, jnp.asarray(self.tokens), self.dcfg,
                k, prompt_lens=jnp.asarray(self.lens),
                request_mask=jnp.asarray(mask))
            self.search_stats.append(stats)
            for s, t in enumerate(self.active):
                if t is None or self._done[s]:
                    continue
                tok = int(stats["best_tokens"][s])
                t.req.out.append(tok)
                self.tokens[s, self.lens[s]] = tok
                self.lens[s] += 1
                if (tok == self.eos_id or len(t.req.out) >= t.req.max_new
                        or self.lens[s] >= self.max_prompt_len):
                    self._done[s] = True
        for s, t in enumerate(self.active):
            if t is None:
                continue
            if self._done[s]:
                self._retire_slot(s)
            elif self._should_preempt(t):
                self._preempt_slot(s)
        return served
