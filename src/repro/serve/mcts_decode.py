"""GSCPM-guided LM decoding — the paper's technique as a serving feature.

A search job over token continuations is exactly the paper's "logical task
of fungible iterations" (DESIGN.md §4): ``n_playouts`` UCT iterations are
split into ``n_tasks`` grains of ``m`` iterations, scheduled onto
``n_workers`` vmapped lanes against ONE shared token tree, by the same
``repro.core.scheduler`` disciplines the Hex engine uses.

Mapping of MCTS steps onto the LM:

- *state* of a node at depth k = prompt ⊕ k tree tokens; the root holds the
  prefilled prompt KV cache (computed once, broadcast to the worker lanes).
- *selection*: level-synchronous UCT descent over up-to-``branch`` children
  per node — all W lanes step down the token tree in lockstep, one
  ``kernels.ops.uct_select`` (W, C) tile per level, the same batched descent
  as the Hex engine (single-agent: a node's value is its mean rollout score).
- *expansion*: an untried token among the leaf's top-``branch`` logits;
  batch-deduped via the same prefix-sum allocator as Hex (token ids are
  legal `move`s since expand_batch orders (leaf, move) lexicographically).
- *playout*: ``rollout_len`` sampled continuation tokens; the score is
  exp(mean logprob) ∈ (0,1] — the model's own confidence in the line
  (a likelihood-based stand-in for the game result Δ).
- *backup*: scatter-add of the score along the path (atomics → .at[].add).

Every playout replays its path through the decode step (positions after the
prompt are rewritten each iteration, so one (W, S_max) cache serves all
iterations without copying).

Multi-request root parallelism (DESIGN.md §3): ``mcts_decode_search_batch``
stacks B independent token trees (one per concurrent request) into a forest
and advances ALL of them through one shared jitted step — ``jax.vmap`` over
the single-request chunk, with the KV cache's per-leaf batch axis split into
(requests, lanes). ``prompt_len`` is a *traced* per-request scalar, so a
batch may mix prompt lengths (shorter prompts are left-aligned in a padded
token matrix; decode masks positions beyond each request's own cursor) and
token commits never recompile.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sched
from repro.core import uct as uct_mod
from repro.core.gscpm import (advance_paths, expand_batch, fold_task_keys,
                              level_noise)
from repro.core.root_parallel import fold_member_task_keys
from repro.core.tree import (NO_NODE, Tree, best_child, child_stat_tile,
                             init_forest, init_tree)
from repro.kernels import ops
from repro.models import api
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class MCTSDecodeConfig:
    """Fields marked compare=False are excluded from hash/eq: ``cp`` reaches
    the jitted chunks as a traced operand and the playout/task/scheduler
    knobs only shape the host-side schedule (grain arrives as traced ``m``),
    so sweeping them shares one compiled program. Traced code must never
    read a compare=False field directly."""

    n_playouts: int = dataclasses.field(default=128, compare=False)
    # the grain dial: m = n_playouts / n_tasks
    n_tasks: int = dataclasses.field(default=16, compare=False)
    n_workers: int = 8           # vmapped lanes through the LM
    cp: float = dataclasses.field(default=1.0, compare=False)
    branch: int = 8              # children per node = top-k tokens
    max_depth: int = 6           # tree horizon in tokens
    rollout_len: int = 8
    temperature: float = 1.0
    select_noise: float = 1e-3
    tree_cap: int = 2048
    scheduler: str = dataclasses.field(default="fifo", compare=False)
    descent: str = "batched"     # batched (level-synchronous) | scalar (oracle)

    @property
    def grain(self) -> int:
        return max(1, self.n_playouts // max(1, self.n_tasks))


# ------------------------------------------------------------- selection ----
def select_token_path(tree: Tree, cfg: MCTSDecodeConfig, noise_key: jax.Array,
                      cp=None):
    """UCT descent to a not-fully-expanded node (single-agent values).

    Per-lane scalar oracle for ``select_token_batch`` (``cp`` defaults to
    cfg.cp for standalone use; the jitted chunks pass the traced operand).
    """
    cap = tree.cap
    C = tree.max_children
    max_path = cfg.max_depth + 2
    cp = cfg.cp if cp is None else cp
    path0 = jnp.full((max_path,), cap, dtype=jnp.int32).at[0].set(0)

    def cond(st):
        node, depth, path, done = st
        return ~done

    def body(st):
        node, depth, path, _ = st
        n_kids = tree.n_children[node]
        fully = (n_kids >= cfg.branch) & (depth < cfg.max_depth)
        slots = tree.children[node]
        valid = jnp.arange(C, dtype=jnp.int32) < n_kids
        safe = jnp.where(valid, slots, cap)
        scores = uct_mod.uct_scores(
            tree.wins[safe], tree.visits[safe], tree.vloss[safe],
            tree.visits[node] + tree.vloss[node], cp, valid)
        noise = cfg.select_noise * jax.random.uniform(
            jax.random.fold_in(noise_key, depth), (C,))
        child = safe[uct_mod.select_child(scores, noise)]
        nxt = (child, depth + 1, path.at[depth + 1].set(child), False)
        stay = (node, depth, path, True)
        return jax.tree.map(lambda a, b: jnp.where(fully, a, b), nxt, stay)

    node, depth, path, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.int32(0), path0, False))
    return path, depth, node


def select_token_batch(tree: Tree, cfg: MCTSDecodeConfig, cp,
                       noise_keys: jax.Array):
    """Level-synchronous batched descent over the token tree.

    The LM twin of ``gscpm.select_batch``: all W lanes step down in
    lockstep, one ``kernels.ops.uct_select`` (W, C) tile per level, with
    finished lanes masked and held. Bit-identical to
    ``jax.vmap(select_token_path)`` under the same RNG schedule.
    """
    cap = tree.cap
    C = tree.max_children
    max_path = cfg.max_depth + 2
    W = noise_keys.shape[0]

    nodes0 = jnp.zeros((W,), jnp.int32)
    depths0 = jnp.zeros((W,), jnp.int32)
    paths0 = jnp.full((W, max_path), cap, dtype=jnp.int32).at[:, 0].set(0)
    done0 = jnp.zeros((W,), bool)

    def cond(st):
        return ~st[-1].all()

    def body(st):
        nodes, depths, paths, done = st
        n_kids = tree.n_children[nodes]
        fully = (n_kids >= cfg.branch) & (depths < cfg.max_depth)
        safe, valid, wins, visits, vloss, ptot = child_stat_tile(tree, nodes)
        noise = level_noise(noise_keys, depths, C, cfg.select_noise)
        picks = ops.uct_select(wins, visits, vloss, ptot, valid, cp,
                               noise=noise, lane_mask=~done)
        child = safe[jnp.arange(W), picks]
        step = fully & ~done
        nodes = jnp.where(step, child, nodes)
        paths = advance_paths(paths, depths, child, step)
        depths = jnp.where(step, depths + 1, depths)
        return nodes, depths, paths, done | ~step

    nodes, depths, paths, _ = jax.lax.while_loop(
        cond, body, (nodes0, depths0, paths0, done0))
    return paths, depths, nodes


def path_tokens(tree: Tree, path: jnp.ndarray, max_depth: int) -> jnp.ndarray:
    """Tokens along the path (token of path[t+1]), 0-padded."""
    toks = tree.move[path[1:max_depth + 1]]
    return jnp.maximum(toks, 0).astype(jnp.int32)


def propose_token(tree: Tree, leaf: jnp.ndarray, leaf_logits: jnp.ndarray,
                  cfg: MCTSDecodeConfig, depth: jnp.ndarray,
                  key: jax.Array) -> jnp.ndarray:
    """Random untried token among the leaf's top-`branch` logits (-1: none)."""
    C = tree.max_children
    cap = tree.cap
    _, top_tok = jax.lax.top_k(leaf_logits, cfg.branch)   # (branch,)
    slots = tree.children[leaf]
    valid = jnp.arange(C, dtype=jnp.int32) < tree.n_children[leaf]
    tried = jnp.where(valid, tree.move[jnp.where(valid, slots, cap)], -1)
    is_tried = (top_tok[:, None] == tried[None, :]).any(axis=1)  # (branch,)
    can = ~is_tried & (depth < cfg.max_depth)
    g = jax.random.gumbel(key, (cfg.branch,))
    pick = jnp.argmax(jnp.where(can, g, -jnp.inf))
    return jnp.where(can.any(), top_tok[pick], NO_NODE).astype(jnp.int32)


# ----------------------------------------------------------------- backup ----
def backup_values(tree: Tree, paths: jnp.ndarray, values: jnp.ndarray,
                  weights: jnp.ndarray) -> Tree:
    """Single-agent scatter-add backup: every node on the path gains value."""
    W, D = paths.shape
    flat = paths.reshape(-1)
    w = jnp.repeat(weights, D) * (flat != tree.cap)
    visits = tree.visits.at[flat].add(w).at[tree.cap].set(0.0)
    wins = tree.wins.at[flat].add(
        w * jnp.repeat(values, D)).at[tree.cap].set(0.0)
    return tree._replace(visits=visits, wins=wins)


# ---------------------------------------------------------- one iteration ----
def _iteration(tree: Tree, params, mcfg: ModelConfig, cfg: MCTSDecodeConfig,
               cache, root_logits: jnp.ndarray, prompt_len, cp,
               iter_keys: jnp.ndarray, active: jnp.ndarray):
    """One batched GSCPM iteration of width W against the shared token tree.

    ``prompt_len`` is a traced i32 scalar (per-request under vmap), not a
    static python int — decode positions are computed from it, so one
    compiled program serves every prompt length up to the cache size.
    ``cp`` is the traced exploration constant (never read from cfg here).
    """
    W = cfg.n_workers
    V = root_logits.shape[-1]
    prompt_len = jnp.asarray(prompt_len, jnp.int32)

    noise_keys = jax.vmap(lambda k: jax.random.fold_in(k, 0))(iter_keys)
    if cfg.descent == "scalar":
        sel = jax.vmap(lambda k: select_token_path(tree, cfg, k, cp)
                       )(noise_keys)
    else:
        sel = select_token_batch(tree, cfg, cp, noise_keys)
    paths, depths, leaves = sel                                # (W, D), (W,), (W,)
    toks = jax.vmap(lambda p: path_tokens(tree, p, cfg.max_depth))(paths)

    # --- replay the paths through the decode step (lockstep positions) ----
    def replay_step(t, carry):
        cache, leaf_logits = carry
        tok_t = toks[:, t][:, None]                            # (W,1)
        logits, cache = api.decode(params, mcfg, tok_t,
                                   prompt_len + t, cache)
        leaf_logits = jnp.where((depths == t + 1)[:, None],
                                logits[:, 0, :], leaf_logits)
        return cache, leaf_logits

    leaf_logits0 = jnp.broadcast_to(root_logits, (W, V))
    cache, leaf_logits = jax.lax.fori_loop(
        0, cfg.max_depth, replay_step, (cache, leaf_logits0))

    # --- expansion (dedup batch insert, same allocator as Hex) ------------
    k_prop = jax.vmap(lambda k: jax.random.fold_in(k, 1))(iter_keys)
    moves = jax.vmap(
        lambda l, ll, d, k: propose_token(tree, l, ll, cfg, d, k)
    )(leaves, leaf_logits, depths, k_prop)
    tree, new_ids = expand_batch(tree, leaves, moves, active)
    expanded = new_ids < tree.cap
    paths = jnp.where(
        jnp.arange(paths.shape[1])[None, :] == (depths + 1)[:, None],
        jnp.where(expanded[:, None], new_ids[:, None], tree.cap), paths)

    # --- rollout: expanded token first, then sampled continuation --------
    start_pos = prompt_len + cfg.max_depth   # parked replay ends here

    def rollout(cache):
        tok0 = jnp.where(expanded, jnp.maximum(moves, 0),
                         jnp.argmax(leaf_logits, -1).astype(jnp.int32))

        def body(t, carry):
            cache, tok, logp_sum = carry
            logits, cache = api.decode(params, mcfg, tok[:, None],
                                       start_pos + t, cache)
            logits = logits[:, 0, :].astype(jnp.float32)
            logits_t = logits / max(cfg.temperature, 1e-6)
            keys = jax.vmap(
                lambda k: jax.random.fold_in(jax.random.fold_in(k, 2), t)
            )(iter_keys)
            nxt = jax.vmap(jax.random.categorical)(keys, logits_t)
            logp = jax.nn.log_softmax(logits, axis=-1)
            lp = jnp.take_along_axis(logp, nxt[:, None], axis=1)[:, 0]
            return cache, nxt.astype(jnp.int32), logp_sum + lp

        cache, _, logp_sum = jax.lax.fori_loop(
            0, cfg.rollout_len, body,
            (cache, tok0, jnp.zeros((W,), jnp.float32)))
        return cache, logp_sum

    cache, logp_sum = rollout(cache)
    values = jnp.exp(logp_sum / cfg.rollout_len)               # (0,1]
    tree = backup_values(tree, paths, values, active.astype(jnp.float32))
    return tree, cache


@functools.partial(jax.jit,
                   static_argnames=("mcfg", "cfg"),
                   donate_argnums=(0, 4))
def run_chunk(tree: Tree, params, mcfg: ModelConfig, cfg: MCTSDecodeConfig,
              cache, root_logits, prompt_len, task_keys, active,
              m, cp) -> tuple[Tree, Any]:
    """m sync iterations — one task grain per lane (jitted once per config;
    ``prompt_len``, ``m`` and ``cp`` are traced, so prompt length, grain and
    Cp changes do not recompile)."""

    def body(i, carry):
        tree, cache = carry
        iter_keys = jax.vmap(lambda tk: jax.random.fold_in(tk, i))(task_keys)
        return _iteration(tree, params, mcfg, cfg, cache, root_logits,
                          prompt_len, cp, iter_keys, active)

    return jax.lax.fori_loop(0, m, body, (tree, cache))


@functools.partial(jax.jit,
                   static_argnames=("mcfg", "cfg", "cache_axes_def"),
                   donate_argnums=(0, 4))
def run_chunk_batch(forest: Tree, params, mcfg: ModelConfig,
                    cfg: MCTSDecodeConfig, cache, root_logits, prompt_lens,
                    task_keys, active, m, cp, cache_axes_def
                    ) -> tuple[Tree, Any]:
    """`run_chunk` vmapped over B concurrent requests — one jitted program.

    forest: B stacked trees; cache leaves carry a (B, W) split batch axis at
    each leaf's own position (``cache_axes_def``, hashable static arg);
    root_logits (B, V); prompt_lens (B,); task_keys/active (B, W); ``cp`` a
    traced scalar shared by all requests.
    """
    cache_axes = jax.tree.unflatten(
        jax.tree.structure(cache), list(cache_axes_def))

    def one(tree, cache_b, rl, pl, keys, act):
        def body(i, carry):
            tr, ch = carry
            iter_keys = jax.vmap(
                lambda tk: jax.random.fold_in(tk, i))(keys)
            return _iteration(tr, params, mcfg, cfg, ch, rl, pl, cp,
                              iter_keys, act)

        return jax.lax.fori_loop(0, m, body, (tree, cache_b))

    return jax.vmap(one, in_axes=(0, cache_axes, 0, 0, 0, 0),
                    out_axes=(0, cache_axes))(
        forest, cache, root_logits, prompt_lens, task_keys, active)


# ------------------------------------------------------------------ driver ----
def mcts_decode_search(params, mcfg: ModelConfig, prompt: jnp.ndarray,
                       cfg: MCTSDecodeConfig, key: jax.Array,
                       batch_extras: dict | None = None
                       ) -> tuple[Tree, dict[str, Any]]:
    """One GSCPM search for the best next token after `prompt` (1D i32)."""
    prompt_len = int(prompt.shape[0])
    max_len = prompt_len + cfg.max_depth + cfg.rollout_len + 1
    # prefill with the prompt tiled across the worker lanes: every lane gets
    # its own copy of the prompt KV (cache leaves are layer-stacked, so this
    # is simpler and shape-agnostic vs broadcasting a batch axis mid-tree)
    W = cfg.n_workers
    tiled = jnp.tile(prompt[None, :], (W, 1))
    extras = {k: jnp.tile(v, (W,) + (1,) * (v.ndim - 1))
              for k, v in (batch_extras or {}).items()}
    root_logits, cache = api.prefill(params, mcfg,
                                     {"tokens": tiled, **extras}, max_len)
    root_logits = root_logits[0, 0].astype(jnp.float32)

    tree = init_tree(cfg.tree_cap, cfg.branch, 1)
    schedule = sched.make_schedule(
        cfg.n_playouts, cfg.n_tasks, cfg.n_workers, cfg.scheduler)

    cp = jnp.asarray(cfg.cp, jnp.float32)
    t0 = time.perf_counter()
    playouts = 0
    for rnd in schedule:
        task_keys = fold_task_keys(key, jnp.asarray(rnd.task_ids, dtype=jnp.int32))
        active = jnp.asarray(rnd.active)
        tree, cache = run_chunk(tree, params, mcfg, cfg, cache, root_logits,
                                jnp.asarray(prompt_len, jnp.int32),
                                task_keys, active,
                                jnp.asarray(rnd.m, jnp.int32), cp)
        playouts += int(rnd.active.sum()) * rnd.m
    jax.block_until_ready(tree.visits)
    dt = time.perf_counter() - t0

    best = best_child(tree)
    stats = {
        "time_s": dt,
        "playouts": playouts,
        "playouts_per_s": playouts / max(dt, 1e-9),
        "tree_nodes": int(tree.n_nodes),
        "best_token": int(best),
        "grain": cfg.grain,
        "root_children": int(tree.n_children[0]),
    }
    return tree, stats


# --------------------------------------------- multi-request root parallel ----
def mcts_decode_search_batch(params, mcfg: ModelConfig, prompts: jnp.ndarray,
                             cfg: MCTSDecodeConfig, key: jax.Array, *,
                             prompt_lens: jnp.ndarray | None = None,
                             request_mask: jnp.ndarray | None = None,
                             batch_extras: dict | None = None
                             ) -> tuple[Tree, dict[str, Any]]:
    """Root-parallel GSCPM decode: B requests, B trees, ONE jitted step.

    prompts: (B, P) i32, left-aligned; rows shorter than P declare their true
    length in ``prompt_lens`` (pad tail tokens are never attended: root
    logits come from a decode at each request's own last real position, and
    every later decode masks positions beyond its cursor). ``request_mask``
    (B,) bool masks whole requests (their lanes run dead and their trees stay
    empty) — the slot-engine's empty-slot mechanism.

    Per round, ALL B trees advance through one ``run_chunk_batch`` dispatch;
    there is no per-request Python loop (DESIGN.md §3).
    """
    prompts = jnp.asarray(prompts, jnp.int32)
    if prompts.ndim == 1:
        prompts = prompts[None, :]
    B, P = prompts.shape
    W = cfg.n_workers
    lens = (jnp.full((B,), P, jnp.int32) if prompt_lens is None
            else jnp.asarray(prompt_lens, jnp.int32))
    mask = (jnp.ones((B,), bool) if request_mask is None
            else jnp.asarray(request_mask, bool))
    max_len = P + cfg.max_depth + cfg.rollout_len + 1

    # request-major tiling: lane w of request b sits at row b*W + w
    tiled = jnp.repeat(prompts, W, axis=0)                       # (B*W, P)
    extras = {k: jnp.repeat(jnp.asarray(v), W, axis=0)
              for k, v in (batch_extras or {}).items()}
    _, cache = api.prefill(params, mcfg, {"tokens": tiled, **extras}, max_len)
    # root logits at each request's true last position (prefill's last-column
    # logits would read a pad token for short rows); the rewrite of the last
    # real token's KV is idempotent
    last_tok = prompts[jnp.arange(B), lens - 1]
    logits, cache = api.decode(params, mcfg,
                               jnp.repeat(last_tok, W)[:, None],
                               jnp.repeat(lens - 1, W), cache)
    root_logits = logits.reshape(B, W, -1)[:, 0, :].astype(jnp.float32)

    # split every cache leaf's (B*W) batch axis into (B, W) at its own index
    axes_tree = api.cache_batch_axes(mcfg, B * W, max_len)
    cache = jax.tree.map(
        lambda x, bi: x.reshape(x.shape[:bi] + (B, W) + x.shape[bi + 1:]),
        cache, axes_tree)
    cache_axes_def = tuple(jax.tree.leaves(axes_tree))

    forest = init_forest(B, cfg.tree_cap, cfg.branch, 1)
    member_keys = fold_task_keys(key, jnp.arange(B, dtype=jnp.int32))
    schedule = sched.make_schedule(
        cfg.n_playouts, cfg.n_tasks, cfg.n_workers, cfg.scheduler)

    cp = jnp.asarray(cfg.cp, jnp.float32)
    t0 = time.perf_counter()
    playouts_per_req = 0
    for rnd in schedule:
        task_keys = fold_member_task_keys(
            member_keys, jnp.asarray(rnd.task_ids, dtype=jnp.int32))
        active = jnp.asarray(rnd.active)[None, :] & mask[:, None]   # (B, W)
        forest, cache = run_chunk_batch(
            forest, params, mcfg, cfg, cache, root_logits, lens,
            task_keys, active, jnp.asarray(rnd.m, jnp.int32), cp,
            cache_axes_def)
        playouts_per_req += int(rnd.active.sum()) * rnd.m
    jax.block_until_ready(forest.visits)
    dt = time.perf_counter() - t0

    n_req = int(np.asarray(mask).sum())
    # best_child returns the most-visited root child's move (token); a
    # masked request's empty tree yields NO_NODE (-1)
    best = np.asarray(jax.vmap(best_child)(forest))
    playouts = n_req * playouts_per_req
    stats = {
        "time_s": dt,
        "n_requests": B,
        "n_active_requests": n_req,
        "playouts": playouts,
        "playouts_per_request": playouts_per_req,
        "playouts_per_s": playouts / max(dt, 1e-9),
        "grain": cfg.grain,
        "tree_nodes": [int(n) for n in np.asarray(forest.n_nodes)],
        "best_tokens": best.tolist(),
        "root_children": [int(n) for n in np.asarray(forest.n_children[:, 0])],
    }
    return forest, stats


def mcts_generate_batch(params, mcfg: ModelConfig, prompts, prompt_lens,
                        n_tokens: int, cfg: MCTSDecodeConfig, key: jax.Array
                        ) -> tuple[np.ndarray, np.ndarray, list]:
    """Lockstep multi-request generation: one batched search per emitted
    token, all requests committing together. The token matrix keeps a fixed
    width of ``P0 + n_tokens``, so the whole generation reuses one compiled
    search program (prompt lengths are traced)."""
    prompts = np.asarray(prompts, np.int32)
    B, P0 = prompts.shape
    lens = np.asarray(prompt_lens, np.int32).copy()
    buf = np.zeros((B, P0 + n_tokens), np.int32)
    buf[:, :P0] = prompts
    all_stats = []
    for i in range(n_tokens):
        _, stats = mcts_decode_search_batch(
            params, mcfg, jnp.asarray(buf), cfg, jax.random.fold_in(key, i),
            prompt_lens=jnp.asarray(lens))
        toks = np.asarray(stats["best_tokens"], np.int32)
        buf[np.arange(B), lens] = toks
        lens += 1
        all_stats.append(stats)
    return buf, lens, all_stats


def mcts_generate(params, mcfg: ModelConfig, prompt: jnp.ndarray,
                  n_tokens: int, cfg: MCTSDecodeConfig, key: jax.Array,
                  batch_extras: dict | None = None) -> tuple[jnp.ndarray, list]:
    """Emit n_tokens, one GSCPM search per token (search-then-commit)."""
    toks = jnp.asarray(prompt, jnp.int32)
    all_stats = []
    for i in range(n_tokens):
        _, stats = mcts_decode_search(
            params, mcfg, toks, cfg, jax.random.fold_in(key, i), batch_extras)
        toks = jnp.concatenate(
            [toks, jnp.asarray([stats["best_token"]], jnp.int32)])
        all_stats.append(stats)
    return toks, all_stats
