"""Logical-axis → mesh-axis rules and sharding helpers.

Every parameter/activation carries logical axis names; these rules map them
onto the production mesh axes ("pod", "data", "model"). The mapping implements
FSDP-over-`data` × tensor-parallel-over-`model` × pure-DP-over-`pod` (only
gradient all-reduce crosses pods — DCN-friendly; DESIGN.md §6).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),   # activation batch dim
    "seq": None,
    "act_seq": "model",         # Megatron-style sequence parallelism at block edges
    "kv_len": "data",           # long-context decode: shard cache length
    "embed": "data",            # FSDP shard of the d_model weight axis
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "expert_group": "data",     # MoE token groups (expert-compute phase)
    "expert_group_all": ("data", "model"),  # groups own whole chips outside
                                            # the expert phase (dispatch/combine)
    "ssm_heads": "model",
    "layers": None,
    "conv": None,
    "lora": None,
    # root-parallel MCTS: the forest's leading member axis splits over the
    # 1-D ensemble mesh (launch.mesh.make_ensemble_mesh); on LM meshes
    # (no "ens" axis) logical_to_spec drops it, so the rule is inert there
    "ensemble": "ens",
}

_state = threading.local()


def current_rules() -> dict[str, Any]:
    return getattr(_state, "rules", DEFAULT_RULES)


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_rules(rules: dict[str, Any], mesh: Mesh | None = None):
    old_r = getattr(_state, "rules", None)
    old_m = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        if old_r is None:
            del _state.rules
        else:
            _state.rules = old_r
        if old_m is None:
            if hasattr(_state, "mesh"):
                del _state.mesh
        else:
            _state.mesh = old_m


def _mesh_axes(mesh: Mesh | None):
    if mesh is None:
        return None
    return set(mesh.axis_names)


def logical_to_spec(axes: tuple[str | None, ...],
                    rules: dict[str, Any] | None = None,
                    mesh: Mesh | None = None) -> P:
    """Map logical axes to a PartitionSpec, dropping mesh axes not present."""
    rules = rules or current_rules()
    mesh = mesh or current_mesh()
    names = _mesh_axes(mesh)
    out = []
    used: set[str] = set()
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m is None:
            out.append(None)
            continue
        cand = m if isinstance(m, tuple) else (m,)
        keep = tuple(c for c in cand
                     if (names is None or c in names) and c not in used)
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    return P(*out)


def _divisible_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes from dims they do not divide (e.g. S=1 over model=16)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        cand = entry if isinstance(entry, tuple) else (entry,)
        keep, prod = [], 1
        for c in cand:
            if dim % (prod * sizes[c]) == 0:
                keep.append(c)
                prod *= sizes[c]
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def shard(x, axes: tuple[str | None, ...],
          rules: dict[str, Any] | None = None):
    """with_sharding_constraint by logical axes (no-op outside a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _divisible_spec(logical_to_spec(axes, rules, mesh), x.shape, mesh)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x


def named_sharding_for(mesh: Mesh, axes: tuple[str | None, ...],
                       shape: tuple[int, ...],
                       rules: dict[str, Any] | None = None) -> NamedSharding:
    """Divisibility-aware NamedSharding for a concrete global shape."""
    spec = _divisible_spec(logical_to_spec(axes, rules, mesh), shape, mesh)
    return NamedSharding(mesh, spec)


def named_sharding(mesh: Mesh, axes: tuple[str | None, ...],
                   rules: dict[str, Any] | None = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, rules, mesh))


def tree_shardings(mesh: Mesh, axes_tree, rules: dict[str, Any] | None = None):
    """Map a tree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda a: named_sharding(mesh, a, rules),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, str) or e is None for e in x))
