"""Search launcher: GSCPM over any registered game (DESIGN.md §13).

``python -m repro.launch.search --game gomoku --size 9 --playouts 2048``
runs a Grain-Size Controlled Parallel MCTS from the empty position and
prints the chosen move and throughput; ``--trees E`` switches to the
root-parallel forest (E trees advanced by one jitted program per round,
visit-sum + majority-vote merges). The ``--game`` flag resolves through the
``Game`` registry (``repro.core.game``) — Hex and Gomoku ship; new games
only need to register a protocol implementation.

``--moves N`` plays N moves of self-play from the empty board: after each
search the best move is committed and the tree is RE-ROOTED onto the played
child (``core.tree.reroot_tree``, DESIGN.md §16) so the next search starts
warm — the single-CLI demonstration of cross-move tree reuse. ``--cold``
ablates it (fresh tree every move); ``--reuse-tree`` is the default,
spelled out for symmetry.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import game as game_mod
from repro.core.gscpm import GSCPMConfig, gscpm_search
from repro.core.root_parallel import gscpm_search_batch
from repro.core.tree import reroot_forest, reroot_tree


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--game", default="hex",
                   choices=list(game_mod.available_games()),
                   help="registered Game to search (core/game.py registry)")
    p.add_argument("--size", type=int, default=9, help="board side length")
    p.add_argument("--playouts", type=int, default=2048)
    p.add_argument("--tasks", type=int, default=64,
                   help="grain dial: m = playouts / tasks")
    p.add_argument("--workers", type=int, default=16, help="parallel lanes")
    p.add_argument("--trees", type=int, default=1,
                   help=">1: root-parallel ensemble of this many trees")
    p.add_argument("--scheduler", default="fifo",
                   choices=["fifo", "rebalance", "one_per_core",
                            "sequential"])
    p.add_argument("--cp", type=float, default=1.0)
    p.add_argument("--to-move", type=int, default=1, choices=[1, 2])
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--moves", type=int, default=1,
                   help="play this many self-play moves (search, commit the "
                        "best move, re-root, repeat)")
    reuse = p.add_mutually_exclusive_group()
    reuse.add_argument("--reuse-tree", dest="reuse", action="store_true",
                       default=True,
                       help="warm-start each move from the re-rooted tree "
                            "(default)")
    reuse.add_argument("--cold", dest="reuse", action="store_false",
                       help="ablation: fresh tree every move")
    p.add_argument("--metrics", action="store_true",
                   help="thread the device-plane SearchMetrics accumulator "
                        "through the search and print its summary "
                        "(bit-identical results, one extra compiled "
                        "program)")
    p.add_argument("--trace", default=None, metavar="OUT.json",
                   help="record per-round spans as Chrome/Perfetto trace-"
                        "event JSON (blocks per round while tracing)")
    args = p.parse_args()

    cfg = GSCPMConfig(game=args.game, board_size=args.size,
                      n_playouts=args.playouts, n_tasks=args.tasks,
                      n_workers=args.workers, cp=args.cp,
                      scheduler=args.scheduler,
                      tree_cap=max(1 << 14, 4 * args.playouts),
                      metrics=args.metrics)
    board = cfg.game_obj.init_board()
    key = jax.random.key(args.seed)
    tracer = None
    if args.trace:
        from repro.obsv import TraceRecorder
        tracer = TraceRecorder(process_name="repro-search")
        from repro.core import gscpm as gscpm_mod
        tracer.watch_compiles("run_chunk", gscpm_mod.run_chunk)

    game = cfg.game_obj
    to_move = args.to_move
    carry = None    # the re-rooted tree/forest warm-starting the next move
    for mvno in range(args.moves):
        key_mv = key if args.moves == 1 else jax.random.fold_in(key, mvno)
        reused = ""
        if args.trees > 1:
            forest, st = gscpm_search_batch(
                board, to_move, cfg, key_mv, n_trees=args.trees,
                forest=carry, tracer=tracer)
            mv = st["best_move_sum"]
            if "reused_nodes" in st:
                reused = f", reused {st['reused_nodes']} nodes"
            print(f"[{args.game} {args.size}x{args.size}] {st['n_trees']} "
                  f"trees, {st['playouts']} playouts in {st['time_s']:.2f}s "
                  f"({st['playouts_per_s']:.0f}/s, grain m={st['grain']}"
                  f"{reused})")
            print(f"  best move (visit-sum) {st['best_move_sum']}, "
                  f"(majority vote) {st['best_move_vote']}; member values "
                  f"{['%.3f' % v for v in st['member_root_values']]}")
        else:
            tree, st = gscpm_search(board, to_move, cfg, key_mv,
                                    tree=carry, tracer=tracer)
            mv = st["best_move"]
            if "reused_visits" in st:
                reused = (f", reused {st['reused_nodes']} nodes / "
                          f"{st['reused_visits']:.0f} visits")
            print(f"[{args.game} {args.size}x{args.size}] {st['playouts']} "
                  f"playouts in {st['time_s']:.2f}s "
                  f"({st['playouts_per_s']:.0f}/s, grain m={st['grain']}, "
                  f"{st['tree_nodes']} nodes{reused})")
            print(f"  best move {st['best_move']}, "
                  f"root value {st['root_value']:.3f}")
        if args.metrics:
            dm = st["metrics"]
            print(f"  device metrics: depth mean/max {dm['depth_mean']:.2f}/"
                  f"{dm['depth_max']}, {dm['expansions']} expansions "
                  f"({dm['expand_collision_rate']:.2f} collision rate), "
                  f"playout len mean/max {dm['playout_len_mean']:.1f}/"
                  f"{dm['playout_len_max']}, held levels {dm['held_levels']}, "
                  f"peak {dm['tree_nodes_peak']} nodes, "
                  f"reused {dm['tree_nodes_reused']}")
        if mvno == args.moves - 1 or mv < 0:
            break
        if args.reuse:
            carry = (reroot_forest(forest, mv) if args.trees > 1
                     else reroot_tree(tree, mv))
        board = game.place(board, jnp.int32(mv), jnp.int8(to_move))
        to_move = 3 - to_move
    if tracer is not None:
        from repro.obsv import validate_trace
        path = tracer.save(args.trace)
        print(f"  trace: {validate_trace(path)} events -> {path}")


if __name__ == "__main__":
    main()
