"""Self-play launcher: whole games through stateful sessions (DESIGN.md §16).

``python -m repro.launch.selfplay --game hex --size 7 --playouts 512``
plays one complete game: each player owns a ``GameSession`` on a shared
``TPFIFOGameEngine``, every move is a ``GameRequest`` served through the
per-game-class quantum pools, and after each move BOTH sessions re-root
their device-resident trees onto the played child (``core.tree.reroot_tree``)
so the next search starts warm. Per-move lines report the retained-visit
fraction — the amortization the cross-move reuse machinery buys.

Flags of note:

- ``--cold``: the ablation arm — sessions keep the full lifecycle but drop
  their trees at every move, so every search starts from scratch;
- ``--playouts2 N``: engine-vs-engine with asymmetric budgets (player 1
  searches ``--playouts``, player 2 searches ``N``);
- ``--game2``: unused boards are not a thing — but two GAMES can run
  back-to-back (``--game hex --game2 gomoku`` plays one game of each);
- ``--trace OUT.json``: the serving trace (admissions, quanta, re-roots
  ride as ordinary requests) in Chrome/Perfetto format.
"""

from __future__ import annotations

import argparse
import time

from repro.core import game as game_mod


def play_game(eng, game: str, size: int, *, playouts: tuple[int, int],
              tasks: int, cp: float = 1.0, seed: int = 0,
              reuse: bool = True, max_moves: int | None = None,
              deadline_s: float | None = None, quiet: bool = False) -> dict:
    """One full game; returns a summary dict (winner, moves, retention).

    Two sessions — one per player — share the engine (and therefore the
    game class's slot pool and compiled quantum). Each session observes
    every move via ``play``: its own choices and the opponent's, so both
    trees stay rooted at the CURRENT position and the retained subtree is
    whatever each player's last search knew about the line actually played.
    """
    from repro.serve.games import GameSession

    sessions = {
        1: GameSession(eng, game, size, reuse_tree=reuse, base_seed=seed,
                       name=f"{game}-p1"),
        2: GameSession(eng, game, size, reuse_tree=reuse,
                       base_seed=seed + 1_000_003, name=f"{game}-p2"),
    }
    limit = max_moves or sessions[1].game_obj.max_moves
    moves, fractions, latencies = [], [], []
    winner = -1
    t0 = time.perf_counter()
    while len(moves) < limit:
        side = sessions[1].to_move     # both sessions track the same game
        sess = sessions[side]
        req = sess.make_request(n_playouts=playouts[side - 1],
                                n_tasks=tasks, cp=cp, deadline_s=deadline_s)
        t_mv = time.perf_counter()
        eng.submit(req)
        eng.run()
        dt_mv = time.perf_counter() - t_mv
        res = req.result
        mv = res["best_move"]
        if mv < 0:      # no legal move was ever expanded: the game is over
            break
        for s in sessions.values():
            s.play(mv)
        moves.append(int(mv))
        fractions.append(sess.retained_fraction)
        latencies.append(dt_mv)
        if not quiet:
            print(f"  mv{len(moves):>3} p{side} -> {mv:>3}  "
                  f"value {res['root_value']:+.3f}  "
                  f"{res['playouts']:>5} playouts "
                  f"(reused {res.get('reused_visits', 0):>5} visits, "
                  f"retained {sess.retained_fraction:.2f})  "
                  f"{dt_mv * 1e3:6.0f} ms")
        winner = sessions[1].winner()
        if winner >= 0:
            break
    dt = time.perf_counter() - t0
    return {
        "game": game, "size": size, "winner": int(winner),
        "n_moves": len(moves), "moves": moves, "time_s": dt,
        "move_latencies_s": latencies,
        "retained_fractions": fractions,
        "mean_retained_fraction": (sum(fractions) / len(fractions)
                                   if fractions else 0.0),
    }


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--game", default="hex",
                   choices=list(game_mod.available_games()))
    p.add_argument("--game2", default=None,
                   choices=list(game_mod.available_games()),
                   help="also play one game of this (after --game)")
    p.add_argument("--size", type=int, default=7, help="board side length")
    p.add_argument("--playouts", type=int, default=512,
                   help="player 1's total root evidence per move")
    p.add_argument("--playouts2", type=int, default=None,
                   help="player 2's budget (default: same as player 1) — "
                        "engine-vs-engine with asymmetric strength")
    p.add_argument("--tasks", type=int, default=16)
    p.add_argument("--workers", type=int, default=8)
    p.add_argument("--grain", type=int, default=4,
                   help="quantum size in schedule rounds")
    p.add_argument("--tree-cap", type=int, default=None,
                   help="node capacity per tree (default: 4x the larger "
                        "budget, min 4096)")
    p.add_argument("--cp", type=float, default=1.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--max-moves", type=int, default=None)
    p.add_argument("--deadline", type=float, default=None,
                   help="per-move time budget in seconds")
    p.add_argument("--cold", action="store_true",
                   help="ablation: drop the tree after every move")
    p.add_argument("--metrics", action="store_true",
                   help="device-plane SearchMetrics per served move")
    p.add_argument("--trace", default=None, metavar="OUT.json",
                   help="Chrome/Perfetto trace of the whole self-play run")
    args = p.parse_args()

    tracer = None
    if args.trace:
        from repro.obsv import TraceRecorder
        tracer = TraceRecorder(process_name="repro-selfplay")

    from repro.serve.games import TPFIFOGameEngine

    po2 = args.playouts2 if args.playouts2 is not None else args.playouts
    cap = args.tree_cap or max(4096, 4 * max(args.playouts, po2))
    eng = TPFIFOGameEngine(n_slots=2, grain=args.grain,
                           n_workers=args.workers, tree_cap=cap,
                           metrics=args.metrics, tracer=tracer)

    games = [args.game] + ([args.game2] if args.game2 else [])
    for g in games:
        mode = "cold" if args.cold else "warm"
        vs = (f"{args.playouts} vs {po2}" if po2 != args.playouts
              else f"{args.playouts}")
        print(f"[selfplay {g} {args.size}x{args.size}] {mode}, "
              f"{vs} playouts/move")
        summ = play_game(eng, g, args.size,
                         playouts=(args.playouts, po2), tasks=args.tasks,
                         cp=args.cp, seed=args.seed, reuse=not args.cold,
                         max_moves=args.max_moves,
                         deadline_s=args.deadline)
        who = {0: "draw", 1: "player 1", 2: "player 2"}.get(
            summ["winner"], "unfinished")
        print(f"  {who} after {summ['n_moves']} moves in "
              f"{summ['time_s']:.1f}s (mean retained fraction "
              f"{summ['mean_retained_fraction']:.2f})")

    if tracer is not None:
        from repro.obsv import validate_trace
        path = tracer.save(args.trace)
        print(f"  trace: {validate_trace(path)} events -> {path}")


if __name__ == "__main__":
    main()
