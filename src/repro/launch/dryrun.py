import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices stand in for 2 TPU v5e pods; for each
cell the jitted step function must ``.lower().compile()`` under the
production mesh, and we record

- ``compiled.memory_analysis()``  — proves the cell fits 16 GB/chip,
- ``compiled.cost_analysis()``    — per-chip HLO FLOPs / bytes,
- parsed collective ops           — per-chip wire bytes (roofline/collectives),
- the three roofline terms        — EXPERIMENTS.md §Roofline reads these.

Cost accounting: XLA's ``cost_analysis`` visits a while body ONCE (a ~94x
FLOP undercount for scanned layers), and fully unrolling makes XLA:CPU
codegen take ~12 min/cell (measured). So cells compile in their scanned
form (fast) and costs come from ``repro.roofline.hlo_costs`` — a
per-computation cost model over the compiled HLO text that scales while
bodies by their parsed trip counts (validated at 74-100% of the
unrolled-compiled ground truth on smollm; dot FLOPs are exact).
``--crosscheck`` additionally lowers the unroll_loops=True variant and
reports its pre-partitioning global FLOPs.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
        [--skip-existing] [--out artifacts/dryrun]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat, configs
from repro.launch import inputs as inp
from repro.launch.mesh import make_production_mesh, mesh_dims
from repro.models import api
from repro.optim import adamw
from repro.roofline import collectives as coll
from repro.roofline import hlo_costs
from repro.roofline import terms as rt
from repro.serve import engine as serve_engine
from repro.sharding import rules as shr
from repro.train import step as train_step_mod


def _metrics_shardings(mesh):
    rep = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return {"loss": rep, "grad_norm": rep, "lr": rep, "skipped": rep}


def lower_cell(arch: str, shape_name: str, mesh, unroll: bool = True,
               cfg_overrides: dict | None = None,
               rules_overrides: dict | None = None):
    """Build and lower one cell; returns (lowered, cfg, spec, rules)."""
    spec = inp.input_specs(arch, shape_name, cfg_overrides)
    cfg = spec["cfg"].replace(unroll_loops=unroll, scan_layers=not unroll)
    rules = dict(spec["rules"])
    rules.update(rules_overrides or {})
    shape = spec["shape"]

    with shr.use_rules(rules, mesh):
        if shape.kind == "train":
            import jax.numpy as jnp
            step = train_step_mod.make_train_step(
                cfg,
                adamw.OptConfig(moment_dtype=spec.get("moment_dtype",
                                                      "float32")),
                n_microbatches=spec.get("n_microbatches", 1),
                accum_dtype=jnp.dtype(spec.get("accum_dtype", "float32")))
            ss = inp.shardings_for(mesh, spec["state"], spec["state_axes"],
                                   rules)
            bs = inp.batch_shardings_for(mesh, spec["batch"], rules)
            jitted = jax.jit(step, in_shardings=(ss, bs),
                             out_shardings=(ss, _metrics_shardings(mesh)),
                             donate_argnums=(0,))
            lowered = jitted.lower(spec["state"], spec["batch"])
        elif shape.kind == "prefill":
            step = serve_engine.make_prefill_step(cfg, shape.seq_len)
            ps = inp.shardings_for(mesh, spec["params"], spec["param_axes"],
                                   rules)
            bs = inp.batch_shardings_for(mesh, spec["batch"], rules)
            jitted = jax.jit(step, in_shardings=(ps, bs))
            lowered = jitted.lower(spec["params"], spec["batch"])
        else:  # decode
            step = serve_engine.make_serve_step(cfg)
            ps = inp.shardings_for(mesh, spec["params"], spec["param_axes"],
                                   rules)
            cs = inp.shardings_for(mesh, spec["cache"], spec["cache_axes"],
                                   rules)
            ts = shr.named_sharding_for(
                mesh, ("batch", None), tuple(spec["tokens"].shape), rules)
            pos_s = shr.named_sharding_for(
                mesh, ("batch",), tuple(spec["pos"].shape), rules)
            jitted = jax.jit(step, in_shardings=(ps, ts, pos_s, cs),
                             donate_argnums=(3,))
            lowered = jitted.lower(spec["params"], spec["tokens"],
                                   spec["pos"], spec["cache"])
    return lowered, cfg, spec, rules


def analyze_cell(arch: str, shape_name: str, mesh, mesh_name: str,
                 crosscheck: bool = False, cfg_overrides: dict | None = None,
                 rules_overrides: dict | None = None) -> dict:
    """lower + compile + extract every §Roofline input for one cell."""
    t0 = time.perf_counter()
    lowered, cfg, spec, rules = lower_cell(
        arch, shape_name, mesh, False, cfg_overrides, rules_overrides)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compat.cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    parsed = hlo_costs.rollup(hlo)

    crosscheck_flops = None
    if crosscheck:
        lo_u, *_ = lower_cell(arch, shape_name, mesh, True, cfg_overrides,
                              rules_overrides)
        crosscheck_flops = float(
            compat.cost_analysis_dict(lo_u).get("flops", 0.0))

    shape = spec["shape"]
    chips = len(mesh.devices.flatten())
    n_params = api.n_params(cfg)
    mf = rt.model_flops(cfg, n_params, shape.kind, shape.seq_len,
                        shape.global_batch)
    af = rt.attn_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)
    terms = rt.RooflineTerms(
        flops_per_chip=parsed.flops,
        hbm_bytes_per_chip=parsed.bytes_major,
        wire_bytes_per_chip=parsed.coll_wire,
        chips=chips,
        model_flops_global=mf,
        attn_flops_global=af,
    )
    peak_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                  + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "mesh_dims": mesh_dims(mesh),
        "chips": chips,
        "kind": shape.kind,
        "n_params": n_params,
        "n_params_active": rt.active_params(cfg, n_params),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_chip": peak_bytes,
            "fits_16GiB": bool(peak_bytes < 16 * 1024**3),
        },
        "cost": {
            "parsed_flops_per_chip": parsed.flops,
            "parsed_bytes_per_chip": parsed.bytes_major,
            "parsed_bytes_upper_bound": parsed.bytes,
            "parsed_transcendentals": parsed.transcendentals,
            "xla_flat_flops": float(cost.get("flops", 0.0)),
            "xla_flat_bytes": float(cost.get("bytes accessed", 0.0)),
            "crosscheck_unrolled_global_flops": crosscheck_flops,
            "while_trips": parsed.while_trips,
        },
        "collectives": {
            "count": parsed.coll_count,
            "operand_bytes": parsed.coll_operand,
            "wire_bytes": parsed.coll_wire,
            "by_op": parsed.coll_by_op,
            "flat_structure": coll.summarize(coll.parse_collectives(hlo)),
        },
        "roofline": terms.to_dict(),
        "timing": {"lower_s": t_lower, "compile_s": t_compile},
        "overrides": {"cfg": cfg_overrides or {},
                      "rules": rules_overrides or {}},
    }


def run_cells(cells, out_dir: str, skip_existing: bool = False) -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    results = []
    meshes = {}
    for arch, shape_name, mesh_name in cells:
        tag = f"{arch}__{shape_name}__{mesh_name}"
        path = os.path.join(out_dir, tag + ".json")
        if skip_existing and os.path.exists(path):
            with open(path) as f:
                results.append(json.load(f))
            print(f"[skip] {tag}")
            continue
        if mesh_name not in meshes:
            meshes[mesh_name] = make_production_mesh(
                multi_pod=(mesh_name == "multipod"))
        try:
            res = analyze_cell(arch, shape_name, meshes[mesh_name], mesh_name)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            r = res["roofline"]
            print(f"[ok] {tag}: flops/chip={r['flops_per_chip']:.3e} "
                  f"wire/chip={r['wire_bytes_per_chip']:.3e} "
                  f"peak={res['memory']['peak_bytes_per_chip']/2**30:.2f}GiB "
                  f"bottleneck={r['bottleneck']} "
                  f"(compile {res['timing']['compile_s']:.1f}s)")
            results.append(res)
        except Exception as e:
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
            traceback.print_exc()
            results.append({"arch": arch, "shape": shape_name,
                            "mesh": mesh_name, "error": str(e)})
    return results


def all_cells(mesh_names=("single", "multipod")):
    cells = []
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        for shape_name, shape in configs.SHAPES.items():
            ok, _ = configs.applicable(cfg, shape)
            if not ok:
                continue
            for mesh_name in mesh_names:
                cells.append((arch, shape_name, mesh_name))
    return cells


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, choices=list(configs.ARCHS))
    p.add_argument("--shape", default=None, choices=list(configs.SHAPES))
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true",
                   help="use the 2x16x16 multi-pod mesh for --arch/--shape")
    p.add_argument("--single-pod-only", action="store_true")
    p.add_argument("--multi-pod-only", action="store_true")
    p.add_argument("--out", default="artifacts/dryrun")
    p.add_argument("--skip-existing", action="store_true")
    args = p.parse_args()

    if args.all:
        names = ("single", "multipod")
        if args.single_pod_only:
            names = ("single",)
        if args.multi_pod_only:
            names = ("multipod",)
        cells = all_cells(names)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape,
                  "multipod" if args.multi_pod else "single")]
    results = run_cells(cells, args.out, args.skip_existing)
    n_fail = sum(1 for r in results if "error" in r)
    print(f"\n{len(results) - n_fail}/{len(results)} cells OK")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
