"""Serving launcher: continuous-batched decode + optional GSCPM decoding.

``python -m repro.launch.serve --arch smollm-135m --requests 8`` runs the
slot engine over synthetic prompts; ``--mcts`` decodes each prompt's next
tokens with Grain-Size Controlled MCTS instead of greedy sampling (the
paper's technique in the serving path).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api
from repro.serve.engine import Request, SlotEngine
from repro.serve.mcts_decode import MCTSDecodeConfig, mcts_generate


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m", choices=list(configs.ARCHS))
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--mcts", action="store_true")
    p.add_argument("--playouts", type=int, default=64)
    p.add_argument("--tasks", type=int, default=16)
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = configs.reduced_config(args.arch)
    params = api.init_params(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    if args.mcts:
        prompt = jnp.asarray(
            rng.integers(1, cfg.vocab, size=(args.prompt_len,)), jnp.int32)
        dcfg = MCTSDecodeConfig(n_playouts=args.playouts, n_tasks=args.tasks,
                                n_workers=args.workers)
        t0 = time.perf_counter()
        toks, stats = mcts_generate(params, cfg, prompt, args.max_new, dcfg,
                                    jax.random.key(args.seed + 1))
        dt = time.perf_counter() - t0
        print(f"GSCPM decode: {args.max_new} tokens in {dt:.1f}s "
              f"({sum(s['playouts'] for s in stats)} playouts, grain "
              f"{dcfg.grain})")
        print("tokens:", toks.tolist())
        return

    eng = SlotEngine(params, cfg, n_slots=args.slots,
                     max_len=args.prompt_len + args.max_new + 8,
                     temperature=args.temperature, seed=args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(1, cfg.vocab, size=(plen,),
                                               dtype=np.int64).astype(np.int32),
                           max_new=args.max_new))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    tok = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {tok} tokens in {dt:.1f}s "
          f"({tok/dt:.1f} tok/s, {args.slots} slots)")


if __name__ == "__main__":
    main()
