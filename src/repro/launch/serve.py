"""Serving launcher: continuous-batched decode + optional GSCPM decoding.

``python -m repro.launch.serve --arch smollm-135m --requests 8`` serves
synthetic prompts; ``--scheduler tpfifo`` swaps the lockstep slot engine for
the work-sharing TPFIFO queue (grain-size-controlled continuous batching,
DESIGN.md §10) and ``--mcts`` decodes with Grain-Size Controlled MCTS
instead of greedy sampling (the paper's technique in the serving path).

``--mcts-game {hex,gomoku,mixed}`` serves board-game SEARCH requests
instead of language-model traffic: ``GameRequest``s through the TPFIFO
quantum engine's per-game-class slot pools (DESIGN.md §14). Requires
``--scheduler tpfifo``; no model is instantiated on this path.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import api
from repro.serve.engine import MCTSSlotEngine, Request, SlotEngine
from repro.serve.mcts_decode import MCTSDecodeConfig
from repro.serve.tpfifo import TPFIFOEngine, TPFIFOMCTSEngine


def make_observers(args):
    """--trace / --metrics-out -> (TraceRecorder | None, Registry | None)."""
    tracer = registry = None
    if args.trace:
        from repro.obsv import TraceRecorder
        tracer = TraceRecorder(process_name="repro-serve")
    if args.metrics_out:
        from repro.obsv import MetricsRegistry
        registry = MetricsRegistry()
    return tracer, registry


def finish_observers(args) -> None:
    """Write (and structurally validate) the observability artifacts."""
    if args.tracer is not None:
        from repro.obsv import validate_trace
        path = args.tracer.save(args.trace)
        n = validate_trace(path)
        print(f"  trace: {n} events -> {path} "
              f"(open in chrome://tracing or ui.perfetto.dev)")
    if args.registry is not None:
        print(f"  metrics snapshot -> {args.registry.save(args.metrics_out)}")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m", choices=list(configs.ARCHS))
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--scheduler", default="lockstep",
                   choices=["lockstep", "tpfifo"],
                   help="lockstep: one decode step per tick; tpfifo: "
                        "work-sharing FIFO queue dispatching grain-sized "
                        "quanta (chunked prefill + continuous batching)")
    p.add_argument("--grain", type=int, default=8,
                   help="micro-steps per TPFIFO dispatch quantum")
    p.add_argument("--policy", default="fifo",
                   choices=["fifo", "rebalance", "one_per_core"],
                   help="TPFIFO admission/requeue discipline")
    p.add_argument("--preempt-quanta", type=int, default=None,
                   help="preempt+requeue a request after this many quanta")
    p.add_argument("--mcts", action="store_true",
                   help="decode with GSCPM search instead of greedy")
    p.add_argument("--mcts-game", default=None,
                   choices=["hex", "gomoku", "mixed"],
                   help="serve board-game search requests (no LM) through "
                        "the TPFIFO game engine; 'mixed' alternates classes")
    p.add_argument("--board-size", type=int, default=7)
    p.add_argument("--deadline", type=float, default=None,
                   help="per-request time-to-move deadline in seconds "
                        "(game serving only)")
    p.add_argument("--playouts", type=int, default=64)
    p.add_argument("--tasks", type=int, default=16)
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--trace", default=None, metavar="OUT.json",
                   help="record a Chrome/Perfetto trace of the serve run "
                        "(admissions, quanta, preemptions, deadline "
                        "expiries, jit compiles) to this file — open in "
                        "chrome://tracing or ui.perfetto.dev")
    p.add_argument("--metrics-out", default=None, metavar="OUT.json",
                   help="write a MetricsRegistry counter/gauge snapshot "
                        "(JSON) at the end of the run")
    p.add_argument("--device-metrics", action="store_true",
                   help="thread the device-plane SearchMetrics accumulator "
                        "through every served search (game serving only; "
                        "results stay bit-identical)")
    p.add_argument("--chaos-rate", type=float, default=0.0,
                   help="inject a seeded Bernoulli fault plan at this "
                        "per-(tick,slot) rate — dispatch errors, NaN "
                        "poisoning, clock stalls, duplicate submissions "
                        "(game serving only; DESIGN.md §17)")
    p.add_argument("--chaos-seed", type=int, default=0,
                   help="fault-plan seed: same seed, same fault sequence")
    p.add_argument("--max-queue", type=int, default=None,
                   help="bounded admission: shed requests beyond this many "
                        "queued per game class (status='shed')")
    p.add_argument("--quarantine-after", type=int, default=None,
                   help="quarantine a slot after this many consecutive "
                        "quantum failures (the engine serves on survivors)")
    args = p.parse_args()
    args.tracer, args.registry = make_observers(args)

    if args.mcts_game:
        if args.scheduler != "tpfifo":
            p.error("--mcts-game requires --scheduler tpfifo "
                    "(game serving runs on the quantum engine)")
        serve_games(args)
        return

    cfg = configs.reduced_config(args.arch)
    params = api.init_params(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    if args.mcts:
        dcfg = MCTSDecodeConfig(n_playouts=args.playouts, n_tasks=args.tasks,
                                n_workers=args.workers)
        max_plen = args.prompt_len + args.max_new
        if args.scheduler == "tpfifo":
            eng = TPFIFOMCTSEngine(params, cfg, dcfg, n_slots=args.slots,
                                   max_prompt_len=max_plen, grain=args.grain,
                                   policy=args.policy,
                                   preempt_quanta=args.preempt_quanta,
                                   seed=args.seed, tracer=args.tracer,
                                   registry=args.registry)
        else:
            eng = MCTSSlotEngine(params, cfg, dcfg, n_slots=args.slots,
                                 max_prompt_len=max_plen, seed=args.seed,
                                 tracer=args.tracer, registry=args.registry)
    elif args.scheduler == "tpfifo":
        eng = TPFIFOEngine(params, cfg, n_slots=args.slots,
                           max_len=args.prompt_len + args.max_new + 8,
                           grain=args.grain, policy=args.policy,
                           preempt_quanta=args.preempt_quanta,
                           temperature=args.temperature, seed=args.seed,
                           tracer=args.tracer, registry=args.registry)
    else:
        eng = SlotEngine(params, cfg, n_slots=args.slots,
                         max_len=args.prompt_len + args.max_new + 8,
                         temperature=args.temperature, seed=args.seed,
                         tracer=args.tracer, registry=args.registry)

    for rid in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(1, cfg.vocab, size=(plen,),
                                               dtype=np.int64).astype(np.int32),
                           max_new=args.max_new))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    tok = sum(len(r.out) for r in done)
    mode = ("GSCPM " if args.mcts else "") + args.scheduler
    print(f"[{mode}] served {len(done)} requests, {tok} tokens in {dt:.1f}s "
          f"({tok/dt:.1f} tok/s, {args.slots} slots)")
    st = eng.stats()
    line = (f"  queue wait p50/p95 {st.queue_wait_p50*1e3:.0f}/"
            f"{st.queue_wait_p95*1e3:.0f} ms, latency p50/p95 "
            f"{st.latency_p50*1e3:.0f}/{st.latency_p95*1e3:.0f} ms")
    if args.scheduler == "tpfifo":    # lockstep engines have no quanta
        line += f", {st.quanta} quanta, {st.n_preemptions} preemptions"
    print(line)
    finish_observers(args)


def serve_games(args) -> None:
    """Board-game search traffic through the TPFIFO quantum engine."""
    from repro.serve.games import GameRequest, TPFIFOGameEngine

    games = (["hex", "gomoku"] if args.mcts_game == "mixed"
             else [args.mcts_game])
    injector = None
    if args.chaos_rate > 0:
        from repro.serve.resilience import FaultInjector, FaultPlan
        injector = FaultInjector(FaultPlan.generate(
            seed=args.chaos_seed, n_ticks=4096,
            n_slots=args.slots * len(games), rate=args.chaos_rate))
    eng = TPFIFOGameEngine(n_slots=args.slots, grain=args.grain,
                           policy=args.policy,
                           preempt_quanta=args.preempt_quanta,
                           n_workers=args.workers,
                           metrics=args.device_metrics,
                           max_queue=args.max_queue,
                           quarantine_after=args.quarantine_after,
                           injector=injector,
                           tracer=args.tracer, registry=args.registry)
    rng = np.random.default_rng(args.seed)
    shed = 0
    for rid in range(args.requests):
        # heterogeneous budgets around --playouts (the irregular workload)
        npo = max(1, int(args.playouts * rng.choice((0.5, 1.0, 2.0))))
        if not eng.submit(GameRequest(
                rid=rid, game=games[rid % len(games)],
                board_size=args.board_size, n_playouts=npo,
                n_tasks=args.tasks, seed=args.seed + rid,
                deadline_s=args.deadline)):
            shed += 1
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    playouts = sum(r.result["playouts"] for r in done)
    print(f"[game tpfifo] served {len(done)} searches, {playouts} playouts "
          f"in {dt:.1f}s ({playouts/dt:.0f} playouts/s, "
          f"{args.slots} slots per game class)")
    for r in done:
        res = r.result
        tag = " (deadline)" if res["deadline_expired"] else ""
        if res.get("retries"):
            tag += f" ({res['retries']} retries)"
        print(f"  req {r.rid}: {res['game']:>6} {res['board_size']}x"
              f"{res['board_size']} -> move {res['best_move']:>3} "
              f"value {res['root_value']:+.3f}  {res['playouts']} playouts, "
              f"{res['rounds']}/{res['rounds_total']} rounds{tag}")
    st = eng.stats()
    print(f"  queue wait p50/p95 {st.queue_wait_p50*1e3:.0f}/"
          f"{st.queue_wait_p95*1e3:.0f} ms, move latency p50/p95 "
          f"{st.latency_p50*1e3:.0f}/{st.latency_p95*1e3:.0f} ms, "
          f"{st.quanta} quanta, {st.n_preemptions} preemptions")
    if injector is not None or shed or st.n_retries or st.n_quarantined:
        fired = injector.summary() if injector is not None else None
        print(f"  resilience: {st.n_retries} retries, "
              f"{st.n_quarantined} quarantined slots, {st.n_shed} shed"
              + (f", faults fired {fired['fired_total']}"
                 f"/{fired['planned']} {fired['fired']}" if fired else ""))
    if args.device_metrics and done:
        dm = done[0].result["metrics"]
        print(f"  device metrics (req {done[0].rid}): "
              f"depth mean/max {dm['depth_mean']:.2f}/{dm['depth_max']}, "
              f"{dm['expansions']} expansions, "
              f"playout len mean {dm['playout_len_mean']:.1f}, "
              f"leaf-collision rate {dm['leaf_collision_rate']:.2f}")
    finish_observers(args)


if __name__ == "__main__":
    main()
