"""Training launcher: ``python -m repro.launch.train --arch smollm-135m``.

Host mode (default) trains a reduced config on the local devices — the CPU
e2e path used by examples/tests. ``--production`` lowers against the
single-pod production mesh instead (requires the 512-device dry-run env;
used to validate launcher plumbing without hardware).

Fault tolerance is live in either mode: async checkpoints every
``--ckpt-every`` steps, automatic resume from the newest valid checkpoint,
non-finite-grad skip, straggler watchdog (repro.train.loop).
"""

from __future__ import annotations

import argparse

from repro import configs
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import LoopConfig, train


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m", choices=list(configs.ARCHS))
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--seq-len", type=int, default=256)
    p.add_argument("--global-batch", type=int, default=16)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--full-config", action="store_true",
                   help="use the full published config (default: reduced twin)")
    p.add_argument("--mesh", action="store_true",
                   help="train under a mesh over the visible local devices")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = (configs.get_config(args.arch) if args.full_config
           else configs.reduced_config(args.arch))
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()

    out = train(
        cfg,
        OptConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                  total_steps=args.steps),
        DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                   seed=args.seed),
        LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every,
                   n_microbatches=args.microbatches, seed=args.seed),
        mesh=mesh,
    )
    print(f"final loss {out['final_loss']:.4f} | "
          f"{out['steps_per_s']:.2f} steps/s | "
          f"stragglers {out['stragglers']}")


if __name__ == "__main__":
    main()
