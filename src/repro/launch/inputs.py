"""ShapeDtypeStruct stand-ins for every (arch × shape) cell + sharding rules.

``input_specs(arch, shape)`` returns exactly what the lowered step function
consumes — weak-type-correct, shardable, zero allocation:

- train cells:   (abstract_state, batch{tokens, labels, mask[, patches|frames]})
- prefill cells: (abstract_params, batch{tokens[, patches|frames]})
- decode cells:  (abstract_params, tokens(B,1), pos(B,), abstract KV cache)

``rules_for_shape`` picks the logical->mesh mapping per cell kind:
decode shards the KV-cache length over ``model`` (MLA latents have no head
axis to shard — without this the 236B decode cells blow 16 GB/chip), and
long_500k (batch=1) spreads cache length over both axes.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.shapes import ShapeSpec
from repro.models import api
from repro.models.common import ModelConfig
from repro.sharding import rules as shr
from repro.train import step as train_step_mod

SDS = jax.ShapeDtypeStruct

ENCDEC_SRC_LEN = api.ENCDEC_SRC_LEN


def rules_for_shape(shape: ShapeSpec) -> dict[str, Any]:
    r = dict(shr.DEFAULT_RULES)
    if shape.kind == "decode":
        if shape.global_batch == 1:
            r["batch"] = None
            r["kv_len"] = ("data", "model")
        else:
            r["kv_len"] = "model"
    if shape.kind == "prefill":
        r["kv_len"] = "model"
    return r


def model_config_for(arch: str, shape: ShapeSpec) -> ModelConfig:
    return configs.tune_for_shape(configs.get_config(arch), shape)


def default_microbatches(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Grain-size control on the training side (paper Table I, DESIGN §4):
    split the global batch into grains so per-grain activations fit HBM.
    Baseline grains by model size; §Perf hillclimbs the dial per cell."""
    n = api.n_params(cfg)
    if n >= 50e9:
        micro = 16
    elif n >= 3e9:
        micro = 4
    elif n >= 1e9:
        micro = 2
    else:
        micro = 1
    while shape.global_batch % micro:
        micro //= 2
    return max(1, micro)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract train/prefill batch for one cell."""
    B = shape.global_batch
    S = shape.seq_len
    d: dict[str, SDS] = {}
    if cfg.family == "vlm":
        text = S - cfg.n_patches          # patches + text fill the budget
        d["tokens"] = SDS((B, text), jnp.int32)
        d["patches"] = SDS((B, cfg.n_patches, cfg.vision_width), cfg.cdtype)
        if shape.kind == "train":
            d["labels"] = SDS((B, text), jnp.int32)
    elif cfg.family == "encdec":
        src = min(ENCDEC_SRC_LEN, S)
        d["tokens"] = SDS((B, S), jnp.int32)
        d["frames"] = SDS((B, src, cfg.vision_width), cfg.cdtype)
        if shape.kind == "train":
            d["labels"] = SDS((B, S), jnp.int32)
    else:
        d["tokens"] = SDS((B, S), jnp.int32)
        if shape.kind == "train":
            d["labels"] = SDS((B, S), jnp.int32)
    if shape.kind == "train":
        d["mask"] = SDS((B, d["labels"].shape[1]), jnp.float32)
    return d


def cache_specs_abstract(cfg: ModelConfig, shape: ShapeSpec):
    """(SDS tree, logical-axes tree) for the decode cells' KV cache."""
    specs = api.cache_specs(cfg, shape.global_batch, shape.seq_len)
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(
        x[0], jax.ShapeDtypeStruct)
    sds = jax.tree.map(lambda t: t[0], specs, is_leaf=is_leaf)
    axes = jax.tree.map(lambda t: t[1], specs, is_leaf=is_leaf)
    return sds, axes


def input_specs(arch: str, shape_name: str,
                cfg_overrides: dict | None = None) -> dict[str, Any]:
    """Everything the dry-run needs for one cell (abstract, no allocation)."""
    shape = configs.SHAPES[shape_name]
    cfg = model_config_for(arch, shape)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    rules = rules_for_shape(shape)
    out: dict[str, Any] = {"cfg": cfg, "shape": shape, "rules": rules}
    if shape.kind == "train":
        # >=100B: bf16 moments + bf16 grad accumulation (update math fp32)
        # — without this, 236B x (2+4+4+4) B/param cannot fit 256 chips
        big = api.n_params(cfg) >= 100e9
        out["moment_dtype"] = "bfloat16" if big else "float32"
        out["accum_dtype"] = "bfloat16" if big else "float32"
        out["state"] = train_step_mod.abstract_state(cfg, out["moment_dtype"])
        out["state_axes"] = train_step_mod.state_axes(cfg)
        out["batch"] = batch_specs(cfg, shape)
        out["n_microbatches"] = default_microbatches(cfg, shape)
    elif shape.kind == "prefill":
        out["params"] = api.abstract_params(cfg)
        out["param_axes"] = api.param_axes(cfg)
        out["batch"] = batch_specs(cfg, shape)
    else:  # decode
        out["params"] = api.abstract_params(cfg)
        out["param_axes"] = api.param_axes(cfg)
        out["tokens"] = SDS((shape.global_batch, 1), jnp.int32)
        out["pos"] = SDS((shape.global_batch,), jnp.int32)
        cache_sds, cache_axes = cache_specs_abstract(cfg, shape)
        out["cache"] = cache_sds
        out["cache_axes"] = cache_axes
    return out


# ---------------------------------------------------------------- shardings ----
def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def shardings_for(mesh, spec_tree, axes_tree, rules) -> Any:
    """Divisibility-aware NamedShardings for an abstract tree.

    (flatten both trees in parallel: the axes tree's tuple leaves would be
    traversed as pytree containers under a joint tree.map)
    """
    sds_leaves, treedef = jax.tree.flatten(spec_tree)
    ax_leaves = jax.tree.flatten(axes_tree, is_leaf=_is_axes)[0]
    assert len(sds_leaves) == len(ax_leaves), (len(sds_leaves), len(ax_leaves))
    out = [shr.named_sharding_for(mesh, a, tuple(s.shape), rules)
           for s, a in zip(sds_leaves, ax_leaves)]
    return jax.tree.unflatten(treedef, out)


def batch_shardings_for(mesh, batch: dict, rules) -> dict:
    return {
        k: shr.named_sharding_for(
            mesh, ("batch",) + (None,) * (len(v.shape) - 1), tuple(v.shape),
            rules)
        for k, v in batch.items()
    }
