"""Production meshes (as FUNCTIONS — importing this never touches devices).

Single pod: (16, 16) = 256 chips, axes ("data", "model") — FSDP over
``data``, tensor/expert parallel over ``model``.

Multi-pod: (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
``pod`` axis carries ONLY the gradient all-reduce (params replicated across
pods), which is the DCN-friendly layout for 1000+ node scale: everything
chatty stays on ICI inside a pod.

The dry-run materializes these on 512 placeholder CPU devices
(``--xla_force_host_platform_device_count=512`` — set by dryrun.py before
any jax import).
"""

from __future__ import annotations

import math

import jax

from repro.compat import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devs)}. "
            "For the dry-run set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 BEFORE importing jax (dryrun.py does this).")
    return make_auto_mesh(shape, axes, devices=devs[:need])


def make_ensemble_mesh(devices=None):
    """1-D mesh over the given (default: all visible) devices on axis
    ``"ens"`` — the root-parallel forest's ensemble axis.

    The multi-chip analogue of the paper's per-thread trees: members are
    embarrassingly parallel, so the only mesh that matters is a flat
    ensemble axis (``core/root_parallel.py`` shards E trees over it; on
    CPU, force the 8-virtual-device mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before any jax
    import — see README "Scaling out").
    """
    devs = list(jax.devices() if devices is None else devices)
    return make_auto_mesh((len(devs),), ("ens",), devices=devs)


def make_host_mesh(model_axis: int | None = None):
    """Best-effort mesh over whatever devices exist (tests, examples).

    Factors the device count into (data, model); model_axis forces the
    model dimension.
    """
    n = len(jax.devices())
    m = model_axis or max(d for d in (1, 2, 4, 8) if n % d == 0)
    return make_auto_mesh((n // m, m), ("data", "model"))


def mesh_dims(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
