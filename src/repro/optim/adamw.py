"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Pure-function optimizer over plain pytrees (no optax dependency). Moment
tensors inherit the parameter sharding (the dry-run's in_shardings map the
same logical axes), so optimizer memory is FSDP/TP-sharded exactly like the
params — required for the 236B configs to fit 16 GB/chip.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # moment storage dtype. fp32 default; the 200B+ cells store bf16
    # moments (the update math stays fp32) — the optimizer-state
    # compression used by several 100B+ trainings (incl. DeepSeek-V2);
    # without it 236B x (2+4+4) B/param cannot fit 256 x 16 GiB chips.
    moment_dtype: str = "float32"


def lr_at(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(math.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any, moment_dtype="float32") -> dict:
    """m/v moments (sharded like params), plus the step counter."""
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float):
    """Dtype-preserving clip: scaling in-dtype avoids materializing an
    fp32 copy of the full gradient tree (3.7 GB/chip at 236B)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params: Any, grads: Any, state: dict, cfg: OptConfig):
    """One AdamW step -> (new_params, new_state, metrics).

    Non-finite gradients (inf/nan from a bad batch or a flaky host) SKIP the
    update entirely — fault-tolerance-by-construction for loss spikes; the
    step counter still advances so the schedule is unaffected.
    """
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    finite = jnp.isfinite(gnorm)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)          # update math in fp32 (fused)
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) * (p.ndim >= 2)
        p2 = p.astype(jnp.float32) - lr * (delta + decay)
        # skip-on-nonfinite: keep old values when the grad norm blew up
        p2 = jnp.where(finite, p2, p.astype(jnp.float32))
        m2 = jnp.where(finite, m2, m.astype(jnp.float32))
        v2 = jnp.where(finite, v2, v.astype(jnp.float32))
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr,
               "skipped": (~finite).astype(jnp.float32)}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
