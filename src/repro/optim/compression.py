"""Int8 gradient compression for the cross-pod (DCN) all-reduce.

The pod axis of the production mesh carries exactly one collective: the
data-parallel gradient reduction. Over DCN that reduction is the slowest
link, so we compress it: block-wise int8 quantization, all-gather of the
int8 payload (+fp32 scales) over the pod axis, local dequantize-and-sum.

Wire bytes per element: all-gather int8 = 1 B received per peer vs ring
all-reduce bf16 ~= 2 B — a 2x wire saving at 2 pods (and int8 vs bf16 stays
2x at any pod count). Quantization error is bounded by the per-block scale
(max-abs / 127); tests assert the compressed psum matches the exact psum to
~1% of the block scale.

Used inside a ``shard_map`` over the ``pod`` axis only (data/model stay on
the GSPMD auto path) — see ``repro.train.step.train_step_compressed``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 1024


def _pad_to(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    n = x.size
    pad = (-n) % mult
    return jnp.pad(x.reshape(-1), (0, pad))


def quantize_int8(x: jnp.ndarray, block: int = BLOCK):
    """Flatten -> (nblocks, block) int8 + fp32 per-block scales."""
    flat = _pad_to(x.astype(jnp.float32), block).reshape(-1, block)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.round(flat / jnp.maximum(scale, 1e-30)).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype):
    flat = q.astype(jnp.float32) * scale
    n = 1
    for s in shape:
        n *= s
    return flat.reshape(-1)[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str,
                    block: int = BLOCK) -> jnp.ndarray:
    """psum(x) over `axis_name` with int8 on the wire.

    all_gather(int8) + local sum == psum up to quantization error. The fp32
    scales add 4/block bytes per element (0.4% at block=1024).
    """
    q, scale = quantize_int8(x, block)
    qs = jax.lax.all_gather(q, axis_name)          # (P, nb, block) int8 wire
    ss = jax.lax.all_gather(scale, axis_name)      # (P, nb, 1) fp32 wire
    total = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    n = x.size
    return total.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def compressed_psum_tree(tree, axis_name: str, block: int = BLOCK):
    return jax.tree.map(lambda g: compressed_psum(g, axis_name, block), tree)
