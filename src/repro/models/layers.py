"""Shared neural building blocks: norms, RoPE, MLPs, embeddings, losses."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Spec, activation, dense_spec, norm_spec
from repro.sharding.rules import shard as _shard


# -------------------------------------------------------------------- norm ----
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * w.astype(dt) + b.astype(dt)


# -------------------------------------------------------------------- rope ----
def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd) rotated pairwise; pos: (..., S) int positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                            # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(pos: jnp.ndarray, d: int) -> jnp.ndarray:
    """(S,) positions -> (S, d) sinusoidal embeddings (seamless/encdec)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------- mlp ----
def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.act in ("silu", "gelu"):  # gated (SwiGLU/GeGLU)
        return {"wg": dense_spec(d, ff, ("embed", "mlp")),
                "wu": dense_spec(d, ff, ("embed", "mlp")),
                "wd": dense_spec(ff, d, ("mlp", "embed"))}
    return {"wu": dense_spec(d, ff, ("embed", "mlp")),
            "wd": dense_spec(ff, d, ("mlp", "embed"))}


def mlp(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    act = activation(cfg.act)
    if "wg" in params:
        h = act(x @ params["wg"].astype(x.dtype)) * (x @ params["wu"].astype(x.dtype))
    else:
        h = act(x @ params["wu"].astype(x.dtype))
    h = _shard(h, ("batch", None, "mlp"))
    return h @ params["wd"].astype(x.dtype)


# -------------------------------------------------------------- embeddings ----
def embed_specs(cfg: ModelConfig) -> dict:
    s = {"embedding": Spec((cfg.vocab, cfg.d_model), ("vocab", "embed"), 0.02)}
    if not cfg.tie_embeddings:
        s["unembed"] = Spec((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                            1.0 / (cfg.d_model ** 0.5))
    return s


def embed(params: dict, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = jnp.take(params["embedding"], tokens, axis=0).astype(cfg.cdtype)
    return _shard(x, ("batch", None, None))


def unembed(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["embedding"].T.astype(x.dtype)
    else:
        w = params["unembed"].astype(x.dtype)
    logits = x @ w
    return _shard(logits, ("batch", None, "vocab"))


# -------------------------------------------------------------------- loss ----
def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token-mean CE in fp32; logits (B,S,V), targets (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    m = mask.astype(jnp.float32)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def chunked_ce_loss(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                    targets: jnp.ndarray, mask: jnp.ndarray | None) -> jnp.ndarray:
    """CE with the unembed applied per sequence chunk (bounds logits memory).

    At train_4k × 152k vocab the full logits tensor is ~TBs; chunking the
    sequence axis keeps the live logits block at chunk×V. Grad flows through
    the scan; FLOPs are unchanged (roofline-neutral, memory-term win).
    """
    B, S, D = x.shape
    ck = cfg.logits_chunk
    if ck <= 0 or S % ck != 0 or S == ck:
        return cross_entropy(unembed(params, x, cfg), targets, mask)
    n = S // ck
    xs = x.reshape(B, n, ck, D).swapaxes(0, 1)            # (n, B, ck, D)
    ts = targets.reshape(B, n, ck).swapaxes(0, 1)
    ms = (mask.reshape(B, n, ck).swapaxes(0, 1) if mask is not None
          else jnp.ones((n, B, ck), jnp.float32))

    @jax.checkpoint  # recompute chunk logits in bwd: without this the scan
    def step(carry, inp):  # would SAVE every chunk's logits = full logits
        xs_c, ts_c, ms_c = inp
        logits = unembed(params, xs_c, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, ts_c[..., None], axis=-1)[..., 0]
        nll_sum, m_sum = carry
        m = ms_c.astype(jnp.float32)
        return (nll_sum + ((lse - ll) * m).sum(), m_sum + m.sum()), None

    from repro.models.common import maybe_scan
    (nll, msum), _ = maybe_scan(cfg, step,
                                (jnp.float32(0.0), jnp.float32(0.0)),
                                (xs, ts, ms))
    return nll / jnp.maximum(msum, 1.0)
