"""xLSTM blocks: mLSTM (matrix memory, parallel form) + sLSTM (scalar memory).

Follows arXiv:2405.04517. mLSTM trains in the stabilized parallel (quadratic)
form and decodes with the O(1) recurrence; the two are cross-validated in
tests/test_xlstm.py. sLSTM is inherently sequential (recurrent gate mixing)
and runs under `lax.scan` in both modes.

Gating: forget gate via logsigmoid (the numerically robust choice also used
by the reference implementation), input gate exponential with max-stabilizer m.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Spec, dense_spec, norm_spec
from repro.models.layers import rmsnorm
from repro.sharding.rules import shard as _shard


QKV_BLOCK = 4  # official xLSTM qkv_proj_blocksize (near-diagonal projections)


def mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    d_in = int(cfg.mlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    dv = d_in // H
    dk = dv
    return d_in, H, dk, dv


# ------------------------------------------------------------------ mLSTM ----
def mlstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, H, dk, dv = mlstm_dims(cfg)
    K = 4
    return {
        "ln": norm_spec(d),
        "w_up": dense_spec(d, d_in, ("embed", "mlp")),
        "w_gate": dense_spec(d, d_in, ("embed", "mlp")),
        "w_conv": Spec((K, d_in), ("conv", None), 1.0 / math.sqrt(K)),
        "b_conv": Spec((d_in,), (None,), 0.0),
        # blocksize-4 head-wise projections, faithful to the official xLSTM
        # "LinearHeadwiseExpand" with qkv_proj_blocksize=4 — each size-4 slice
        # of the stream projects independently (this is what puts the
        # 48L/2048d model at ~1.4B rather than ~2.9B)
        "w_q": Spec((d_in // QKV_BLOCK, QKV_BLOCK, QKV_BLOCK),
                    ("mlp", None, None), 1.0 / math.sqrt(QKV_BLOCK)),
        "w_k": Spec((d_in // QKV_BLOCK, QKV_BLOCK, QKV_BLOCK),
                    ("mlp", None, None), 1.0 / math.sqrt(QKV_BLOCK)),
        "w_v": Spec((d_in // QKV_BLOCK, QKV_BLOCK, QKV_BLOCK),
                    ("mlp", None, None), 1.0 / math.sqrt(QKV_BLOCK)),
        "w_i": dense_spec(d_in, H, ("mlp", "heads")),
        "w_f": dense_spec(d_in, H, ("mlp", "heads")),
        "b_i": Spec((H,), ("heads",), 0.0),
        "b_f": Spec((H,), ("heads",), 0.0),
        "out_norm": norm_spec(d_in),
        "w_down": dense_spec(d_in, d, ("mlp", "embed")),
    }


def _causal_conv(x, w, b):
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, w[:, None, :].astype(x.dtype), (1,), "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b.astype(x.dtype)


def _mlstm_qkv(params, cfg, x):
    dt = x.dtype
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    u = h @ params["w_up"].astype(dt)
    gate = h @ params["w_gate"].astype(dt)
    c = jax.nn.silu(_causal_conv(u, params["w_conv"], params["b_conv"]))
    B, L, d_in = u.shape
    H = cfg.n_heads
    nb = d_in // QKV_BLOCK
    cb = c.reshape(B, L, nb, QKV_BLOCK)   # per-block slice of the conv stream
    ub = u.reshape(B, L, nb, QKV_BLOCK)

    def headwise(x4, w):
        y = jnp.einsum("blnd,nde->blne", x4, w.astype(dt))
        return y.reshape(B, L, H, d_in // H)

    q = headwise(cb, params["w_q"])
    k = headwise(cb, params["w_k"])
    v = headwise(ub, params["w_v"])
    i_t = (c @ params["w_i"].astype(dt)).astype(jnp.float32) + params["b_i"]
    f_t = (c @ params["w_f"].astype(dt)).astype(jnp.float32) + params["b_f"]
    return u, gate, q, k, v, i_t, f_t


def _use_chunked(cfg: ModelConfig, L: int) -> bool:
    return bool(cfg.mlstm_chunk) and L > cfg.mlstm_chunk \
        and L % cfg.mlstm_chunk == 0


def _mlstm_chunked(params, cfg: ModelConfig, x: jnp.ndarray):
    """Chunkwise-parallel stabilized mLSTM (TFLA-style), fully scan-free.

    The O(L^2) quadratic form blows past HBM at 32k+ (the (L,L,H) decay
    matrix alone is ~TBs); chunking bounds it to (Q,Q,H) per chunk. Unlike
    the usual sequential inter-chunk scan, state passing here is a strictly
    -lower-triangular (nc x nc) matmul with the max-stabilizer carried in
    log space — MXU-shaped, overlap-friendly, and exact under HLO cost
    analysis (no while loop). Returns (y, decode cache {C, n, m, conv}).
    """
    B, L, d = x.shape
    d_in, H, dk, dv = mlstm_dims(cfg)
    Q = cfg.mlstm_chunk
    nc = L // Q
    dt = x.dtype
    u, gate, q, k, v, i_t, f_t = _mlstm_qkv(params, cfg, x)
    k = k / math.sqrt(dk)

    qc = q.reshape(B, nc, Q, H, dk)
    kc = k.reshape(B, nc, Q, H, dk)
    vc = v.reshape(B, nc, Q, H, dv)
    logf = jax.nn.log_sigmoid(f_t).reshape(B, nc, Q, H)    # fp32
    ic = i_t.reshape(B, nc, Q, H)
    Floc = jnp.cumsum(logf, axis=2)                        # (B,nc,Q,H)

    # ---- intra-chunk (quadratic within Q) -------------------------------
    Dlog = (Floc[:, :, :, None, :] - Floc[:, :, None, :, :]
            + ic[:, :, None, :, :])                        # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    Dlog = jnp.where(tri[None, None, :, :, None], Dlog, -jnp.inf)
    m_intra = jnp.max(Dlog, axis=3)                        # (B,nc,Q,H)

    # ---- per-chunk local states -----------------------------------------
    w = Floc[:, :, -1:, :] - Floc + ic                     # (B,nc,Q,H)
    m_loc = jnp.max(w, axis=2)                             # (B,nc,H)
    g = jnp.exp(w - m_loc[:, :, None, :]).astype(dt)
    S_loc = jnp.einsum("bcjh,bcjhk,bcjhv->bchkv", g, kc, vc)
    n_loc = jnp.einsum("bcjh,bcjhk->bchk", g, kc)

    # ---- cross-chunk state passing (triangular matmul, stabilized) ------
    G = jnp.cumsum(Floc[:, :, -1, :], axis=1)              # (B,nc,H)
    Gprev = jnp.pad(G[:, :-1], ((0, 0), (1, 0), (0, 0)))
    A = (Gprev[:, :, None, :] - G[:, None, :, :]
         + m_loc[:, None, :, :])                           # (B,nc,nc,H)
    ctri = jnp.tril(jnp.ones((nc, nc), dtype=bool), k=-1)
    A = jnp.where(ctri[None, :, :, None], A, -jnp.inf)
    Tmax = jnp.max(A, axis=2)                              # (B,nc,H); -inf @ c=0
    Tmax_safe = jnp.where(jnp.isfinite(Tmax), Tmax, 0.0)
    Aw = jnp.where(ctri[None, :, :, None],
                   jnp.exp(jnp.clip(A - Tmax_safe[:, :, None, :], -60.0, 0.0)),
                   0.0).astype(dt)
    S_tilde = jnp.einsum("bcCh,bChkv->bchkv", Aw, S_loc)   # (B,nc,H,dk,dv)
    n_tilde = jnp.einsum("bcCh,bChk->bchk", Aw, n_loc)

    # ---- combine intra + inter with a joint row stabilizer ---------------
    inter_log = jnp.where(jnp.isfinite(Tmax)[:, :, None, :],
                          Floc + Tmax_safe[:, :, None, :], -jnp.inf)
    M = jnp.maximum(inter_log, m_intra)                    # (B,nc,Q,H) finite
    P = jnp.where(tri[None, None, :, :, None],
                  jnp.exp(Dlog - M[:, :, :, None, :]), 0.0).astype(dt)
    scores = jnp.einsum("bcihk,bcjhk->bcijh", qc, kc)
    num_intra = jnp.einsum("bcijh,bcjhv->bcihv", scores * P, vc)
    den_intra = jnp.einsum("bcijh->bcih", scores * P)
    sc = jnp.where(jnp.isfinite(inter_log),
                   jnp.exp(inter_log - M), 0.0).astype(dt)  # (B,nc,Q,H)
    num_inter = jnp.einsum("bcihk,bchkv->bcihv", qc, S_tilde) * sc[..., None]
    den_inter = jnp.einsum("bcihk,bchk->bcih", qc, n_tilde) * sc
    num = num_intra + num_inter
    den = den_intra + den_inter
    hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-M).astype(dt))[..., None]

    hout = hout.reshape(B, L, d_in)
    hout = rmsnorm(hout, params["out_norm"], cfg.norm_eps)
    y = (hout * jax.nn.silu(gate)) @ params["w_down"].astype(dt)

    # ---- end state (decode cache) ----------------------------------------
    wf = G[:, -1:, :] - G + m_loc                          # (B,nc,H)
    m_end = jnp.max(wf, axis=1)                            # (B,H) fp32
    gf = jnp.exp(wf - m_end[:, None, :]).astype(dt)
    C_end = jnp.einsum("bch,bchkv->bhkv", gf, S_loc)
    n_end = jnp.einsum("bch,bchk->bhk", gf, n_loc)
    conv = u[:, -3:, :] if L >= 3 else jnp.zeros((B, 3, d_in), dt)
    cache = {"C": C_end, "n": n_end, "m": m_end, "conv": conv}
    return y, cache


def mlstm_forward(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Parallel (quadratic) stabilized mLSTM. x: (B,L,d)."""
    B, L, d = x.shape
    if _use_chunked(cfg, L):
        return _mlstm_chunked(params, cfg, x)[0]
    d_in, H, dk, dv = mlstm_dims(cfg)
    u, gate, q, k, v, i_t, f_t = _mlstm_qkv(params, cfg, x)

    logf = jax.nn.log_sigmoid(f_t)               # (B,L,H)
    F = jnp.cumsum(logf, axis=1)
    # D_log[i,j] = F_i - F_j + itilde_j  (j <= i)
    D_log = (F[:, :, None, :] - F[:, None, :, :]) + i_t[:, None, :, :]
    tri = jnp.tril(jnp.ones((L, L), dtype=bool))
    D_log = jnp.where(tri[None, :, :, None], D_log, -jnp.inf)
    m = jnp.max(D_log, axis=2)                   # (B,L,H)
    Dm = jnp.exp(D_log - m[:, :, None, :])
    scores = jnp.einsum("blhk,bmhk->blmh", q, k) / math.sqrt(dk)
    S = scores * Dm.astype(x.dtype)
    norm = jnp.maximum(jnp.abs(S.sum(axis=2)),
                       jnp.exp(-m).astype(x.dtype))  # (B,L,H)
    hout = jnp.einsum("blmh,bmhv->blhv", S, v) / norm[..., None]

    hout = hout.reshape(B, L, d_in)
    hout = rmsnorm(hout, params["out_norm"], cfg.norm_eps)
    y = hout * jax.nn.silu(gate)
    return y @ params["w_down"].astype(x.dtype)


def mlstm_prefill(params, cfg: ModelConfig, x: jnp.ndarray):
    """Parallel forward + the recurrent (C, n, m) state after the last token."""
    B, L, d = x.shape
    if _use_chunked(cfg, L):
        return _mlstm_chunked(params, cfg, x)
    d_in, H, dk, dv = mlstm_dims(cfg)
    u, gate, q, k, v, i_t, f_t = _mlstm_qkv(params, cfg, x)
    y = mlstm_forward(params, cfg, x)

    logf = jax.nn.log_sigmoid(f_t)                         # (B,L,H)
    F = jnp.cumsum(logf, axis=1)
    # state weight of token j at the end: F_L - F_j + i_j
    w = F[:, -1:, :] - F + i_t                             # (B,L,H)
    m_end = jnp.max(w, axis=1)                             # (B,H)
    g = jnp.exp(w - m_end[:, None, :]).astype(x.dtype)     # (B,L,H)
    k_s = k / math.sqrt(dk)
    C = jnp.einsum("blh,blhk,blhv->bhkv", g, k_s, v)
    n = jnp.einsum("blh,blhk->bhk", g, k_s)
    cache = {"C": C, "n": n, "m": m_end,
             "conv": jnp.zeros((B, 3, d_in), x.dtype)}
    # conv window: last 3 up-projected inputs
    cache["conv"] = u[:, -3:, :] if L >= 3 else cache["conv"]
    return y, cache


def mlstm_init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, H, dk, dv = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dk, dv), dtype),
        "n": jnp.zeros((batch, H, dk), dtype),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, 3, d_in), dtype),
    }


def mlstm_cache_specs(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, H, dk, dv = mlstm_dims(cfg)
    return {
        "C": (jax.ShapeDtypeStruct((batch, H, dk, dv), dtype),
              ("batch", "heads", None, None)),
        "n": (jax.ShapeDtypeStruct((batch, H, dk), dtype),
              ("batch", "heads", None)),
        "m": (jax.ShapeDtypeStruct((batch, H), jnp.float32),
              ("batch", "heads")),
        "conv": (jax.ShapeDtypeStruct((batch, 3, d_in), dtype),
                 ("batch", None, None)),
    }


def mlstm_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache: dict):
    """O(1) recurrent step. x: (B,1,d)."""
    B = x.shape[0]
    d_in, H, dk, dv = mlstm_dims(cfg)
    dt = x.dtype
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    u = h @ params["w_up"].astype(dt)
    gate = h @ params["w_gate"].astype(dt)
    window = jnp.concatenate([cache["conv"], u], axis=1)   # (B,4,d_in)
    c = jax.nn.silu(jnp.einsum("bkc,kc->bc", window.astype(dt),
                               params["w_conv"].astype(dt))
                    + params["b_conv"].astype(dt))         # (B,d_in)
    nb = d_in // QKV_BLOCK
    cb = c.reshape(B, nb, QKV_BLOCK)
    ub = u[:, 0].reshape(B, nb, QKV_BLOCK)

    def headwise(x4, w):
        y = jnp.einsum("bnd,nde->bne", x4, w.astype(dt))
        return y.reshape(B, H, d_in // H)

    q = headwise(cb, params["w_q"])
    k = headwise(cb, params["w_k"])
    v = headwise(ub, params["w_v"])
    i_t = (c @ params["w_i"].astype(dt)).astype(jnp.float32) + params["b_i"]
    f_t = (c @ params["w_f"].astype(dt)).astype(jnp.float32) + params["b_f"]

    logf = jax.nn.log_sigmoid(f_t)                         # (B,H)
    m_prev = cache["m"]
    m_new = jnp.maximum(logf + m_prev, i_t)
    i_p = jnp.exp(i_t - m_new).astype(dt)
    f_p = jnp.exp(logf + m_prev - m_new).astype(dt)
    k_s = k / math.sqrt(dk)
    C = f_p[..., None, None] * cache["C"].astype(dt) + \
        i_p[..., None, None] * jnp.einsum("bhk,bhv->bhkv", k_s, v)
    n = f_p[..., None] * cache["n"].astype(dt) + i_p[..., None] * k_s
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)),
                      jnp.exp(-m_new).astype(dt))
    hout = (num / den[..., None]).reshape(B, 1, d_in)
    hout = rmsnorm(hout, params["out_norm"], cfg.norm_eps)
    y = (hout * jax.nn.silu(gate)) @ params["w_down"].astype(dt)
    new_cache = {"C": C.astype(cache["C"].dtype), "n": n.astype(cache["n"].dtype),
                 "m": m_new, "conv": window[:, 1:].astype(cache["conv"].dtype)}
    return y, new_cache


# ------------------------------------------------------------------ sLSTM ----
def slstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ffd = int(d * 4 / 3)
    K = 4
    s = {"ln": norm_spec(d),
         "w_conv": Spec((K, d), ("conv", None), 1.0 / math.sqrt(K)),
         "b_conv": Spec((d,), (None,), 0.0),
         "out_norm": norm_spec(d),
         "w_up1": dense_spec(d, ffd, ("embed", "mlp")),
         "w_up2": dense_spec(d, ffd, ("embed", "mlp")),
         "w_down": dense_spec(ffd, d, ("mlp", "embed"))}
    for g in ("i", "f", "z", "o"):
        s[f"w_{g}"] = dense_spec(d, d, ("embed", "heads"))
        s[f"r_{g}"] = Spec((H, dh, dh), ("heads", None, None), 1.0 / math.sqrt(dh))
        s[f"b_{g}"] = Spec((d,), (None,), 0.0)
    return s


def _slstm_cell(params, gates_x: dict, state: tuple, H: int, dh: int):
    """One sLSTM step. gates_x: precomputed W·x (B,d) per gate."""
    h, c, n, m = state  # h,c,n: (B,H,dh); m: (B,H,dh) stabilizer
    def rec(g):
        return gates_x[g].reshape(-1, H, dh) + jnp.einsum(
            "bhd,hde->bhe", h, params[f"r_{g}"].astype(h.dtype))
    i_t = rec("i").astype(jnp.float32)
    f_t = rec("f").astype(jnp.float32)
    z_t = jnp.tanh(rec("z"))
    o_t = jax.nn.sigmoid(rec("o"))
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + m, i_t)
    i_p = jnp.exp(i_t - m_new).astype(h.dtype)
    f_p = jnp.exp(logf + m - m_new).astype(h.dtype)
    c_new = f_p * c + i_p * z_t
    n_new = f_p * n + i_p
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def slstm_forward(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    B, L, d = x.shape
    H = cfg.n_heads
    dh = d // H
    dt = x.dtype
    hin = rmsnorm(x, params["ln"], cfg.norm_eps)
    cpre = jax.nn.silu(_causal_conv(hin, params["w_conv"], params["b_conv"]))
    gx = {g: (jnp.where(g in ("i", "f"), 1, 1) *
              (cpre if g in ("i", "f") else hin) @ params[f"w_{g}"].astype(dt)
              + params[f"b_{g}"].astype(dt)) for g in ("i", "f", "z", "o")}
    state0 = (jnp.zeros((B, H, dh), dt), jnp.zeros((B, H, dh), dt),
              jnp.zeros((B, H, dh), dt),
              jnp.full((B, H, dh), -1e30, jnp.float32))

    def step(st, inp):
        gates = {g: inp[gi] for gi, g in enumerate(("i", "f", "z", "o"))}
        st2 = _slstm_cell(params, gates, st, H, dh)
        return st2, st2[0]

    seq = tuple(gx[g].swapaxes(0, 1) for g in ("i", "f", "z", "o"))
    _, hs = jax.lax.scan(step, state0, seq)
    hout = hs.swapaxes(0, 1).reshape(B, L, d)
    hout = rmsnorm(hout, params["out_norm"], cfg.norm_eps)
    y = (hout @ params["w_up1"].astype(dt)) * jax.nn.gelu(
        hout @ params["w_up2"].astype(dt))
    return y @ params["w_down"].astype(dt)


def slstm_prefill(params, cfg: ModelConfig, x: jnp.ndarray):
    """Parallel-in-math sequential scan that also returns the final state."""
    B, L, d = x.shape
    H = cfg.n_heads
    dh = d // H
    dt = x.dtype
    hin = rmsnorm(x, params["ln"], cfg.norm_eps)
    cpre = jax.nn.silu(_causal_conv(hin, params["w_conv"], params["b_conv"]))
    gx = {g: ((cpre if g in ("i", "f") else hin) @ params[f"w_{g}"].astype(dt)
              + params[f"b_{g}"].astype(dt)) for g in ("i", "f", "z", "o")}
    state0 = (jnp.zeros((B, H, dh), dt), jnp.zeros((B, H, dh), dt),
              jnp.zeros((B, H, dh), dt),
              jnp.full((B, H, dh), -1e30, jnp.float32))

    def step(st, inp):
        gates = {g: inp[gi] for gi, g in enumerate(("i", "f", "z", "o"))}
        st2 = _slstm_cell(params, gates, st, H, dh)
        return st2, st2[0]

    seq = tuple(gx[g].swapaxes(0, 1) for g in ("i", "f", "z", "o"))
    (h_f, c_f, n_f, m_f), hs = jax.lax.scan(step, state0, seq)
    hout = hs.swapaxes(0, 1).reshape(B, L, d)
    hout = rmsnorm(hout, params["out_norm"], cfg.norm_eps)
    y = (hout @ params["w_up1"].astype(dt)) * jax.nn.gelu(
        hout @ params["w_up2"].astype(dt))
    y = y @ params["w_down"].astype(dt)
    cache = {"h": h_f, "c": c_f, "n": n_f, "m": m_f,
             "conv": hin[:, -3:, :] if L >= 3
             else jnp.zeros((B, 3, d), dt)}
    return y, cache


def slstm_init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    H = cfg.n_heads
    dh = cfg.d_model // H
    return {"h": jnp.zeros((batch, H, dh), dtype),
            "c": jnp.zeros((batch, H, dh), dtype),
            "n": jnp.zeros((batch, H, dh), dtype),
            "m": jnp.full((batch, H, dh), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, 3, cfg.d_model), dtype)}


def slstm_cache_specs(cfg: ModelConfig, batch: int, dtype) -> dict:
    H = cfg.n_heads
    dh = cfg.d_model // H
    sds = jax.ShapeDtypeStruct
    return {"h": (sds((batch, H, dh), dtype), ("batch", "heads", None)),
            "c": (sds((batch, H, dh), dtype), ("batch", "heads", None)),
            "n": (sds((batch, H, dh), dtype), ("batch", "heads", None)),
            "m": (sds((batch, H, dh), jnp.float32), ("batch", "heads", None)),
            "conv": (sds((batch, 3, cfg.d_model), dtype), ("batch", None, None))}


def slstm_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache: dict):
    B = x.shape[0]
    H = cfg.n_heads
    d = cfg.d_model
    dh = d // H
    dt = x.dtype
    hin = rmsnorm(x, params["ln"], cfg.norm_eps)          # (B,1,d)
    window = jnp.concatenate([cache["conv"], hin], axis=1)
    cpre = jax.nn.silu(jnp.einsum("bkc,kc->bc", window.astype(dt),
                                  params["w_conv"].astype(dt))
                       + params["b_conv"].astype(dt))
    hflat = hin[:, 0]
    gx = {g: ((cpre if g in ("i", "f") else hflat) @ params[f"w_{g}"].astype(dt)
              + params[f"b_{g}"].astype(dt)) for g in ("i", "f", "z", "o")}
    st = (cache["h"].astype(dt), cache["c"].astype(dt),
          cache["n"].astype(dt), cache["m"])
    h_new, c_new, n_new, m_new = _slstm_cell(params, gx, st, H, dh)
    hout = rmsnorm(h_new.reshape(B, 1, d), params["out_norm"], cfg.norm_eps)
    y = (hout @ params["w_up1"].astype(dt)) * jax.nn.gelu(
        hout @ params["w_up2"].astype(dt))
    y = y @ params["w_down"].astype(dt)
    new_cache = {"h": h_new.astype(cache["h"].dtype),
                 "c": c_new.astype(cache["c"].dtype),
                 "n": n_new.astype(cache["n"].dtype), "m": m_new,
                 "conv": window[:, 1:].astype(cache["conv"].dtype)}
    return y, new_cache
