"""zamba2 hybrid segments: `attn_every` Mamba-2 blocks + one SHARED dense
(attention+MLP) block, alternating between ``n_shared_blocks`` weight sets.

The shared blocks' parameters live outside the segment scan (they are reused
by every segment — the arch's defining trick); inside the scan the segment
index picks which of the stacked shared sets to apply. Each segment still
keeps its *own* attention KV cache (weights are shared, activations are not).

Simplification vs the released zamba2 (noted in DESIGN.md §5): the shared
block consumes the residual stream directly instead of concat(x, embeddings)
+ re-projection, and per-invocation LoRA deltas on the shared weights are
omitted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import maybe_scan


def _tfm():
    from repro.models import transformer as tfm  # lazy: avoid import cycle
    return tfm


def zamba_seg_specs(cfg) -> dict:
    tfm = _tfm()
    return {"mamba": tfm._stack_specs(tfm.mamba_block_specs(cfg),
                                      cfg.attn_every)}


def zamba_seg_cache_specs(cfg, batch: int, max_len: int, dtype) -> dict:
    tfm = _tfm()
    return {
        "mamba": tfm._stack_cache_specs(
            tfm.mamba_block_cache_specs(cfg, batch, max_len, dtype),
            cfg.attn_every),
        "attn": tfm.dense_block_cache_specs(cfg, batch, max_len, dtype),
    }


def _pick_shared(shared, i, n_shared: int):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i % n_shared, 0,
                                               keepdims=False), shared)


def zamba_seg_scan(stage_params, cfg, x, shared, maybe_remat, prefix_len=0):
    tfm = _tfm()
    nseg = jax.tree.leaves(stage_params)[0].shape[0]

    def seg_body(c, inp):
        seg_p, i = inp

        def inner(cc, lp):
            return tfm.mamba_block_fwd(lp, cfg, cc), None

        c, _ = maybe_scan(cfg, inner, c, seg_p["mamba"])
        blk = _pick_shared(shared, i, cfg.n_shared_blocks)
        return tfm.dense_block_fwd(blk, cfg, c, prefix_len), None

    body = maybe_remat(seg_body, cfg)
    x, _ = maybe_scan(cfg, body, x, (stage_params,
                                       jnp.arange(nseg, dtype=jnp.int32)))
    return x


def zamba_seg_prefill_scan(stage_params, cfg, x, shared, max_len: int):
    tfm = _tfm()
    nseg = jax.tree.leaves(stage_params)[0].shape[0]

    def seg_body(c, inp):
        seg_p, i = inp

        def inner(cc, lp):
            return tfm.mamba_block_prefill(lp, cfg, cc, max_len)

        c, m_caches = maybe_scan(cfg, inner, c, seg_p["mamba"])
        blk = _pick_shared(shared, i, cfg.n_shared_blocks)
        c, a_cache = tfm.dense_block_prefill(blk, cfg, c, max_len)
        return c, {"mamba": m_caches, "attn": a_cache}

    x, caches = maybe_scan(cfg, seg_body, x,
                           (stage_params, jnp.arange(nseg, dtype=jnp.int32)))
    return x, caches


def zamba_seg_decode_scan(stage_params, cfg, x, stage_cache, shared, pos):
    tfm = _tfm()
    nseg = jax.tree.leaves(stage_params)[0].shape[0]

    def seg_body(c, inp):
        seg_p, seg_c, i = inp

        def inner(cc, lp_lc):
            lp, lc = lp_lc
            return tfm.mamba_block_decode(lp, cfg, cc, lc, pos)

        c, m_caches = maybe_scan(cfg, inner, c, (seg_p["mamba"], seg_c["mamba"]))
        blk = _pick_shared(shared, i, cfg.n_shared_blocks)
        c, a_cache = tfm.dense_block_decode(blk, cfg, c, seg_c["attn"], pos)
        return c, {"mamba": m_caches, "attn": a_cache}

    x, new_cache = maybe_scan(
        cfg, seg_body, x,
        (stage_params, stage_cache, jnp.arange(nseg, dtype=jnp.int32)))
    return x, new_cache
