"""Family-dispatched model API used by train/serve/launch.

One uniform surface over decoder-only (transformer.py) and encoder-decoder
(encdec.py) models:

    specs(cfg)                         -> param Spec tree
    loss(params, cfg, batch)           -> scalar
    cache_specs(cfg, batch, max_len)   -> tree of (ShapeDtypeStruct, axes)
    init_cache(cfg, batch, max_len)    -> zeroed cache tree
    prefill(params, cfg, batch, max_len) -> (logits, cache)
    decode(params, cfg, token, pos, cache) -> (logits, cache)
"""

from __future__ import annotations

import jax

from repro.models import encdec as ed
from repro.models import transformer as tfm
from repro.models.common import ModelConfig

# encoder memory length used for enc-dec decode shapes (audio is bounded;
# DESIGN.md §5 documents this interpretation of the enc-dec decode cells)
ENCDEC_SRC_LEN = 4096


def specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return ed.encdec_specs(cfg)
    return tfm.lm_specs(cfg)


def loss(params, cfg: ModelConfig, batch: dict):
    if cfg.family == "encdec":
        return ed.encdec_loss(params, cfg, batch)
    return tfm.lm_loss(params, cfg, batch)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                src_len: int | None = None):
    if cfg.family == "encdec":
        return ed.encdec_cache_specs(cfg, batch, max_len,
                                     src_len or min(ENCDEC_SRC_LEN, max_len))
    return tfm.lm_cache_specs(cfg, batch, max_len)


def cache_batch_axes(cfg: ModelConfig, batch: int, max_len: int,
                     src_len: int | None = None):
    """Pytree (matching the cache treedef) of each leaf's batch-axis index.

    Cache leaves are layer-stacked, so the batch axis sits at a different
    position per leaf; serving code that copies or splits per-request rows
    (SlotEngine, mcts_decode_search_batch) derives the indices here.
    """
    spec_tree = cache_specs(cfg, batch, max_len, src_len)
    is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                         and hasattr(x[0], "shape"))
    return jax.tree.map(lambda t: t[1].index("batch"), spec_tree,
                        is_leaf=is_leaf)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: int | None = None):
    if cfg.family == "encdec":
        return ed.encdec_init_cache(cfg, batch, max_len,
                                    src_len or min(ENCDEC_SRC_LEN, max_len))
    return tfm.lm_init_cache(cfg, batch, max_len)


def prefill(params, cfg: ModelConfig, batch: dict, max_len: int):
    if cfg.family == "encdec":
        return ed.encdec_prefill(params, cfg, batch["frames"],
                                 batch["tokens"], max_len)
    return tfm.lm_prefill(params, cfg, batch["tokens"], max_len,
                          patches=batch.get("patches"))


def decode(params, cfg: ModelConfig, token, pos, cache):
    if cfg.family == "encdec":
        return ed.encdec_decode(params, cfg, token, pos, cache)
    return tfm.lm_decode(params, cfg, token, pos, cache)


def n_params(cfg: ModelConfig) -> int:
    from repro.models.common import n_params as np_
    return np_(specs(cfg))


def init_params(cfg: ModelConfig, key: jax.Array):
    from repro.models.common import init_tree, spec_with_dtype
    return init_tree(spec_with_dtype(specs(cfg), cfg.pdtype), key)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct param tree — the dry-run's no-allocation stand-in."""
    from repro.models.common import spec_with_dtype, tree_specs
    return tree_specs(spec_with_dtype(specs(cfg), cfg.pdtype))


def param_axes(cfg: ModelConfig):
    """Parallel tree of logical-axis tuples (for NamedSharding derivation)."""
    from repro.models.common import tree_axes
    return tree_axes(specs(cfg))
