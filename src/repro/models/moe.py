"""Mixture-of-Experts layer: top-k router, shared+routed experts, EP dispatch.

Capacity-based dispatch in the MaxText/GSPMD style: tokens are flattened,
assignments sorted by expert, positions within each expert computed from the
sorted order, entries beyond capacity dropped, tokens gathered into an
(E, C, d) buffer whose expert axis is sharded over the ``model`` mesh axis
(expert parallelism — GSPMD inserts the all-to-all), expert FFNs applied as
one batched einsum, and results scattered back weighted by router probs.

Covers DeepSeek-V2 (2 shared + 160 routed, top-6, softmax gate) and
Qwen3-MoE (128 routed, top-8, normalized top-k probs).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Spec, activation, dense_spec
from repro.models.layers import mlp, mlp_specs
from repro.sharding.rules import shard as _shard


def moe_specs(cfg: ModelConfig) -> dict:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    s = {
        "router": Spec((d, E), ("embed", None), 1.0 / math.sqrt(d)),
        # routed experts: stacked (E, ...) with expert axis sharded (EP)
        "wg": Spec((E, d, ff), ("expert", "embed", "mlp"), 1.0 / math.sqrt(d)),
        "wu": Spec((E, d, ff), ("expert", "embed", "mlp"), 1.0 / math.sqrt(d)),
        "wd": Spec((E, ff, d), ("expert", "mlp", "embed"), 1.0 / math.sqrt(ff)),
    }
    if cfg.n_shared_experts > 0:
        # shared experts fused into one wider MLP (DeepSeek-V2 style)
        s["shared"] = mlp_specs(cfg, d_ff=cfg.n_shared_experts * ff)
    return s


def router_topk(logits: jnp.ndarray, k: int, norm_topk: bool):
    """(T, E) logits -> (T, k) indices + fp32 combine weights."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    if norm_topk:
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    return topi.astype(jnp.int32), topv


def capacity(tokens: int, k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(tokens * k * factor / n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def n_groups(T: int, group_size: int) -> int:
    """Dispatch group count: ~group_size tokens per group, G | T, G <= 256."""
    target = max(1, min(256, T // min(group_size, T)))
    for g in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if g <= target and T % g == 0:
            return g
    return 1


def moe_ffn(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d). Routed top-k experts (+ shared experts).

    GShard-style GROUPED dispatch: tokens split into G groups (sharded over
    ``data``), each group sorts its own assignments and scatters into a
    per-group (E, C, d) buffer — every sort/scatter/gather is group-local,
    so the partitioner keeps dispatch on-shard and inserts exactly one
    all-to-all pair moving the expert axis onto ``model`` (EP) and back.
    (A single global sort would serialize dispatch onto every chip — that
    lowered, but at ~856 GiB/chip and 24x the FLOPs. This version is what
    makes the 128-160 expert cells fit.)
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    T = B * S
    G = n_groups(T, cfg.moe_group_size)
    gs = T // G
    C = capacity(gs, k, E, cfg.capacity_factor)
    dt = x.dtype
    act = activation(cfg.act)

    # groups shard over data; within a group everything is chip-local
    xg = _shard(x.reshape(G, gs, d), ("expert_group", None, None))
    logits = jnp.einsum("gsd,de->gse", xg, params["router"].astype(dt))
    topi, topw = router_topk(logits, k, cfg.norm_topk_prob)    # (G,gs,k)

    # ---- group-local dispatch: sort by expert, rank within expert ----
    flat_e = topi.reshape(G, gs * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)           # FIFO per expert
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    idx = jnp.arange(gs * k, dtype=jnp.int32)[None, :]
    is_start = jnp.concatenate(
        [jnp.ones((G, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    start_idx = jax.lax.cummax(jnp.where(is_start, idx, 0), axis=1)
    pos_in_e = idx - start_idx                                 # rank in expert
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)     # E*C = drop row
    token_sorted = (order // k).astype(jnp.int32)              # (G, gs*k)

    # ---- dispatch as ONE gather: invert slot->token, index the tokens ----
    # (scatter-of-gather materializes a (gs*k, d) intermediate = k x the
    # token bytes; the inverted index keeps peak memory at the (E*C, d)
    # buffer itself)
    def invert_group(slots, toks):
        return jnp.full((E * C + 1,), gs, jnp.int32).at[slots].set(
            toks, mode="drop")[:E * C]

    inv = jax.vmap(invert_group)(slot, token_sorted).reshape(G, E, C)
    # index tensor sharded (data, model): each model shard gathers ONLY its
    # experts' rows from xg (which is replicated across model), so the
    # (G,E,C,d) buffer is born EP-sharded — no transient full-E copy and no
    # explicit all-to-all
    inv = _shard(inv, ("expert_group", "expert", None))
    xg_pad = jnp.concatenate([xg, jnp.zeros((G, 1, d), dt)], axis=1)
    buf = jax.vmap(lambda xi, iv: xi[iv])(xg_pad, inv)         # (G,E,C,d)
    buf = _shard(buf, ("expert_group", "expert", None, None))

    # ---- expert FFN: batched over the (model-sharded) expert axis ----
    h = act(jnp.einsum("gecd,edf->gecf", buf, params["wg"].astype(dt))) * \
        jnp.einsum("gecd,edf->gecf", buf, params["wu"].astype(dt))
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["wd"].astype(dt))
    out_buf = _shard(out_buf, ("expert_group", "expert", None, None))

    # ---- combine: per-k gather from the EP-sharded buffer ----------------
    # slot -> (expert, pos) indices; gathering from an expert-sharded
    # operand with replicated indices partitions as masked-local-gather +
    # all-reduce of the small (G, gs, d) result (never a full-E copy)
    slot_by_token = jax.vmap(
        lambda o, s: jnp.zeros((gs * k,), jnp.int32).at[o].set(s)
    )(order, slot).reshape(G, gs, k)
    dropped = slot_by_token >= E * C
    e_idx = jnp.minimum(slot_by_token, E * C - 1) // C         # (G, gs, k)
    c_idx = jnp.minimum(slot_by_token, E * C - 1) % C
    # zero the WEIGHT of dropped tokens rather than where()-masking the
    # gathered values: a scalar multiply keeps GSPMD's partial-sum state
    # alive across the k accumulation, so the partitioner can emit ONE
    # all-reduce for the whole combine instead of one per expert choice
    w_eff = jnp.where(dropped, 0.0, topw).astype(dt)           # (G, gs, k)
    yg = jnp.zeros((G, gs, d), dt)
    for j in range(k):
        gj = jax.vmap(lambda ob, ei, ci: ob[ei, ci])(
            out_buf, e_idx[:, :, j], c_idx[:, :, j])           # (G, gs, d)
        # accumulate in compute dtype: an f32 accumulation chain here keeps
        # ~20 f32 (G,gs,d) cotangent copies live through the unrolled-k bwd
        yg = yg + w_eff[:, :, j][:, :, None] * gj
    y = _shard(yg, ("expert_group", None, None)).reshape(B, S, d)

    if cfg.n_shared_experts > 0:
        y = y + mlp(params["shared"], cfg, x)
    return y


def aux_load_balance_loss(logits: jnp.ndarray, topi: jnp.ndarray,
                          n_experts: int) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (mean prob × mean dispatch)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=0)                                    # (E,)
    onehot = jax.nn.one_hot(topi[:, 0], n_experts, dtype=jnp.float32)
    ce = onehot.mean(axis=0)
    return n_experts * jnp.sum(me * ce)
