"""Mamba-2 (SSD) block: chunked state-space dual scan + causal conv + gating.

Implements the SSD algorithm (Dao & Gu, arXiv:2405.21060) in its chunked
form: intra-chunk quadratic term + inter-chunk state scan. Scalar-per-head
decay A, d_state=N per single group, headdim=P.

Train path: (B, L, d) with L a multiple of cfg.ssm_chunk.
Decode path: single-token recurrence with carried (state, conv) cache.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Spec, dense_spec, norm_spec
from repro.models.layers import rmsnorm
from repro.sharding.rules import shard as _shard


def ssm_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_headdim
    return d_inner, H, cfg.ssm_headdim, cfg.ssm_state


def mamba2_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, H, P, N = ssm_dims(cfg)
    K = cfg.ssm_conv
    conv_dim = d_inner + 2 * N
    return {
        "w_z": dense_spec(d, d_inner, ("embed", "mlp")),
        "w_x": dense_spec(d, d_inner, ("embed", "mlp")),
        "w_B": dense_spec(d, N, ("embed", None)),
        "w_C": dense_spec(d, N, ("embed", None)),
        "w_dt": dense_spec(d, H, ("embed", "ssm_heads")),
        "dt_bias": Spec((H,), ("ssm_heads",), 0.0),
        "A_log": Spec((H,), ("ssm_heads",), scale=1.0),  # A = -exp(A_log)
        "D": Spec((H,), ("ssm_heads",), scale=1.0),
        "w_conv": Spec((K, conv_dim), ("conv", None), 1.0 / math.sqrt(K)),
        "b_conv": Spec((conv_dim,), (None,), 0.0),
        "norm": norm_spec(d_inner),
        "w_out": dense_spec(d_inner, d, ("mlp", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B,L,C), w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, w[:, None, :].astype(x.dtype),  # (K, 1, C) KIO
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b.astype(x.dtype)


def _projections(params, cfg: ModelConfig, x: jnp.ndarray):
    d_inner, H, P, N = ssm_dims(cfg)
    dt_ = x.dtype
    z = x @ params["w_z"].astype(dt_)
    xs = x @ params["w_x"].astype(dt_)
    Bm = x @ params["w_B"].astype(dt_)
    Cm = x @ params["w_C"].astype(dt_)
    dt = jax.nn.softplus(
        (x @ params["w_dt"].astype(dt_)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))
    return z, xs, Bm, Cm, dt


def mamba2_forward(params, cfg: ModelConfig, x: jnp.ndarray,
                   return_cache: bool = False):
    """Full-sequence SSD. x: (B, L, d) -> (B, L, d) [, decode cache]."""
    Bsz, L, d = x.shape
    d_inner, H, P, N = ssm_dims(cfg)
    Q = min(cfg.ssm_chunk, L)      # short sequences: one chunk
    assert L % Q == 0, (L, Q)
    nc = L // Q

    z, xs, Bm, Cm, dt = _projections(params, cfg, x)
    cat_pre = jnp.concatenate([xs, Bm, Cm], axis=-1)
    cat = jax.nn.silu(_causal_conv(cat_pre, params["w_conv"], params["b_conv"]))
    xs, Bm, Cm = jnp.split(cat, [d_inner, d_inner + N], axis=-1)

    xs = _shard(xs.reshape(Bsz, L, H, P), ("batch", None, "ssm_heads", None))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))     # (H,)
    log_a = dt * A                                         # (B,L,H) fp32, <=0
    xbar = xs * dt.astype(xs.dtype)[..., None]             # (B,L,H,P)

    # chunk
    log_a = log_a.reshape(Bsz, nc, Q, H)
    cs = jnp.cumsum(log_a, axis=2)                         # inclusive
    xbar = xbar.reshape(Bsz, nc, Q, H, P)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    # ---- intra-chunk (quadratic) ----
    tri = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    # decay exp(cs_i - cs_j) for j <= i
    ddecay = jnp.where(tri[None, None, :, :, None],
                       jnp.exp(jnp.clip(cs[:, :, :, None, :]
                                        - cs[:, :, None, :, :], -60.0, 0.0)),
                       0.0).astype(x.dtype)                # (B,nc,Q,Q,H)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)             # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, ddecay, xbar)

    # ---- chunk states ----
    to_end = jnp.exp(jnp.clip(cs[:, :, -1:, :] - cs, -60.0, 0.0)).astype(x.dtype)
    S_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, to_end, xbar)

    # ---- inter-chunk state passing as a triangular MATMUL (scan-free) ----
    # The sequential chunk scan becomes S_prev[c] = sum_{c'<c} exp(G[c-1] -
    # G[c']) * S_chunk[c'] with G the chunk-boundary cumulative log decay.
    # One (nc x nc) masked matmul replaces nc dependent steps: MXU-friendly,
    # overlappable, and exact under HLO cost analysis (no while loop).
    from_start = jnp.exp(jnp.clip(cs, -60.0, 0.0)).astype(x.dtype)  # (B,nc,Q,H)
    G = jnp.cumsum(cs[:, :, -1, :], axis=1)                # (B,nc,H) fp32
    Gprev = jnp.pad(G[:, :-1], ((0, 0), (1, 0), (0, 0)))   # G[c-1]; 0 at c=0
    diff = Gprev[:, :, None, :] - G[:, None, :, :]         # (B,nc,nc,H)
    ctri = jnp.tril(jnp.ones((nc, nc), dtype=bool), k=-1)  # strictly lower
    T = jnp.where(ctri[None, :, :, None],
                  jnp.exp(jnp.clip(diff, -60.0, 0.0)), 0.0).astype(x.dtype)
    S_prev = jnp.einsum("bcCh,bChnp->bchnp", T, S_chunk)   # (B,nc,H,N,P)
    y_inter = jnp.einsum("bcin,bchnp->bcihp", Cc, S_prev) \
        * from_start[..., None]                            # (B,nc,Q,H,P)
    w_final = jnp.exp(jnp.clip(G[:, -1:, :] - G, -60.0, 0.0)).astype(x.dtype)
    S_final = jnp.einsum("bch,bchnp->bhnp", w_final, S_chunk)

    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    y = y + params["D"].astype(x.dtype)[None, None, :, None] * xs
    y = y.reshape(Bsz, L, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["w_out"].astype(x.dtype)
    if return_cache:
        cache = {"state": S_final, "conv": cat_pre[:, L - (cfg.ssm_conv - 1):, :]}
        return out, cache
    return out


# ------------------------------------------------------------------ decode ----
def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_inner, H, P, N = ssm_dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "state": jnp.zeros((batch, H, N, P), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def mamba2_cache_specs(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_inner, H, P, N = ssm_dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "state": (jax.ShapeDtypeStruct((batch, H, N, P), dtype),
                  ("batch", "ssm_heads", None, None)),
        "conv": (jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_dim), dtype),
                 ("batch", None, None)),
    }


def mamba2_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache: dict):
    """Single-token step. x: (B,1,d) -> (B,1,d), updated cache."""
    Bsz = x.shape[0]
    d_inner, H, P, N = ssm_dims(cfg)
    z, xs, Bm, Cm, dt = _projections(params, cfg, x)
    cat = jnp.concatenate([xs, Bm, Cm], axis=-1)[:, 0]      # (B, conv_dim)
    window = jnp.concatenate([cache["conv"], cat[:, None, :]], axis=1)  # (B,K,Cd)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(x.dtype),
                          params["w_conv"].astype(x.dtype)) + params["b_conv"].astype(x.dtype)
    cat = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(cat, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(Bsz, H, P)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0] * A).astype(x.dtype)               # (B,H)
    xbar = xs * dt[:, 0].astype(x.dtype)[..., None]         # (B,H,P)
    S = cache["state"].astype(x.dtype)
    S = a[:, :, None, None] * S + jnp.einsum("bn,bhp->bhnp", Bm, xbar)
    y = jnp.einsum("bn,bhnp->bhp", Cm, S)
    y = y + params["D"].astype(x.dtype)[None, :, None] * xs
    y = y.reshape(Bsz, 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["w_out"].astype(x.dtype)
    new_cache = {"state": S.astype(cache["state"].dtype),
                 "conv": window[:, 1:].astype(cache["conv"].dtype)}
    return out, new_cache
