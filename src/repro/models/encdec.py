"""Encoder-decoder assembly (seamless-m4t backbone).

Speech frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings (B, S_src, frame_dim); a linear projection lifts
them to d_model. Encoder: bidirectional self-attention blocks. Decoder:
causal self-attention + cross-attention + MLP blocks. Both stacks scan over
stacked layer params like the decoder-only path.

Fidelity notes (DESIGN.md §5): RoPE replaces seamless's learned/relative
positions; the conformer conv module of the speech encoder is outside the
assigned backbone spec (12L transformer enc-dec, d=1024, 16H, ff=4096).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ModelConfig, Spec, dense_spec, norm_spec
from repro.models.layers import (chunked_ce_loss, embed, embed_specs, mlp,
                                 mlp_specs, rmsnorm, unembed)
from repro.sharding.rules import shard as _shard

SDS = jax.ShapeDtypeStruct


# ------------------------------------------------------------------ blocks ----
def enc_block_specs(cfg: ModelConfig) -> dict:
    return {"ln1": norm_spec(cfg.d_model), "attn": attn.gqa_specs(cfg),
            "ln2": norm_spec(cfg.d_model), "mlp": mlp_specs(cfg)}


def enc_block_fwd(p, cfg: ModelConfig, x):
    """Bidirectional self-attention + MLP."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    B, S, _ = h.shape
    pos = jnp.arange(S)[None, :].astype(jnp.int32)
    q, k, v = attn._qkv(p["attn"], cfg, h, pos)
    q = _shard(q, ("batch", None, "heads", None))
    a = attn.sdpa(q, k, v, None)  # no mask: bidirectional
    a = jnp.einsum("bshk,hkd->bsd", a, p["attn"]["wo"].astype(h.dtype))
    x = _shard(x + a, ("batch", "act_seq", None))
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return _shard(x + mlp(p["mlp"], cfg, h), ("batch", "act_seq", None))


def dec_block_specs(cfg: ModelConfig) -> dict:
    return {"ln1": norm_spec(cfg.d_model), "attn": attn.gqa_specs(cfg),
            "lnx": norm_spec(cfg.d_model), "xattn": attn.cross_specs(cfg),
            "ln2": norm_spec(cfg.d_model), "mlp": mlp_specs(cfg)}


def _cross_kv(p, memory):
    dt = memory.dtype
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(dt))
    return k, v


def _cross_apply(p, x, k, v, scale=None):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    out = attn.sdpa(q, k.astype(dt), v.astype(dt), None, scale)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def dec_block_fwd(p, cfg: ModelConfig, x, memory):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a = attn.gqa_attention(p["attn"], cfg, h)            # causal (chunked OK)
    x = _shard(x + a, ("batch", "act_seq", None))
    h = rmsnorm(x, p["lnx"], cfg.norm_eps)
    k, v = _cross_kv(p["xattn"], memory)
    x = _shard(x + _cross_apply(p["xattn"], h, k, v), ("batch", "act_seq", None))
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return _shard(x + mlp(p["mlp"], cfg, h), ("batch", "act_seq", None))


def dec_block_prefill(p, cfg, x, memory, max_len: int):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, (sk, sv) = attn.gqa_prefill(p["attn"], cfg, h)
    from repro.models.transformer import _pad_len
    cache = {"k": _pad_len(sk, max_len), "v": _pad_len(sv, max_len)}
    x = x + a
    h = rmsnorm(x, p["lnx"], cfg.norm_eps)
    ck, cv = _cross_kv(p["xattn"], memory)
    cache["xk"], cache["xv"] = ck, cv
    x = x + _cross_apply(p["xattn"], h, ck, cv)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + mlp(p["mlp"], cfg, h), cache


def dec_block_decode(p, cfg, x, cache, pos):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, (sk, sv) = attn.gqa_decode(p["attn"], cfg, h,
                                  (cache["k"], cache["v"]), pos)
    x = x + a
    h = rmsnorm(x, p["lnx"], cfg.norm_eps)
    x = x + _cross_apply(p["xattn"], h, cache["xk"], cache["xv"])
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + mlp(p["mlp"], cfg, h), {"k": sk, "v": sv,
                                       "xk": cache["xk"], "xv": cache["xv"]}


# ------------------------------------------------------------------- model ----
def encdec_specs(cfg: ModelConfig) -> dict:
    from repro.models.transformer import _stack_specs
    return {
        "embed": embed_specs(cfg),
        "frontend": dense_spec(cfg.vision_width, cfg.d_model, ("embed", None)),
        "enc": _stack_specs(enc_block_specs(cfg), cfg.n_enc_layers),
        "enc_norm": norm_spec(cfg.d_model),
        "dec": _stack_specs(dec_block_specs(cfg), cfg.n_layers),
        "final_norm": norm_spec(cfg.d_model),
    }


def encode(params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_src, frame_dim) stub embeddings -> (B, S_src, d)."""
    x = frames.astype(cfg.cdtype) @ params["frontend"].astype(cfg.cdtype)
    x = _shard(x, ("batch", "act_seq", None))

    def body(c, lp):
        return enc_block_fwd(lp, cfg, c), None

    from repro.models.transformer import _maybe_remat
    from repro.models.common import maybe_scan
    x, _ = maybe_scan(cfg, _maybe_remat(body, cfg), x, params["enc"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def encdec_hidden(params, cfg: ModelConfig, frames, tokens):
    memory = encode(params, cfg, frames)
    x = embed(params["embed"], tokens, cfg)
    x = _shard(x, ("batch", "act_seq", None))

    def body(c, lp):
        return dec_block_fwd(lp, cfg, c, memory), None

    from repro.models.transformer import _maybe_remat
    from repro.models.common import maybe_scan
    x, _ = maybe_scan(cfg, _maybe_remat(body, cfg), x, params["dec"])
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def encdec_loss(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    hidden = encdec_hidden(params, cfg, batch["frames"], batch["tokens"])
    return chunked_ce_loss(params["embed"], cfg, hidden, batch["labels"],
                           batch.get("mask"))


# ------------------------------------------------------------------- serve ----
def encdec_cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                       src_len: int, dtype=None) -> dict:
    dtype = dtype or cfg.cdtype
    H, hd = cfg.n_heads, cfg.hd
    Hkv = cfg.n_kv_heads
    per_layer = {
        "k": (SDS((batch, max_len, Hkv, hd), dtype),
              ("batch", "kv_len", "kv_heads", None)),
        "v": (SDS((batch, max_len, Hkv, hd), dtype),
              ("batch", "kv_len", "kv_heads", None)),
        "xk": (SDS((batch, src_len, H, hd), dtype),
               ("batch", None, "heads", None)),
        "xv": (SDS((batch, src_len, H, hd), dtype),
               ("batch", None, "heads", None)),
    }
    from repro.models.transformer import _stack_cache_specs
    return {"dec": _stack_cache_specs(per_layer, cfg.n_layers)}


def encdec_init_cache(cfg, batch, max_len, src_len, dtype=None):
    from repro.models.transformer import _is_cache_leaf
    specs = encdec_cache_specs(cfg, batch, max_len, src_len, dtype)
    return jax.tree.map(lambda t: jnp.zeros(t[0].shape, t[0].dtype),
                        specs, is_leaf=_is_cache_leaf)


def encdec_prefill(params, cfg: ModelConfig, frames, tokens, max_len: int):
    memory = encode(params, cfg, frames)
    x = embed(params["embed"], tokens, cfg)

    def body(c, lp):
        return dec_block_prefill(lp, cfg, c, memory, max_len)

    from repro.models.common import maybe_scan
    x, caches = maybe_scan(cfg, body, x, params["dec"])
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params["embed"], h[:, -1:, :], cfg), {"dec": caches}


def encdec_decode(params, cfg: ModelConfig, token, pos, cache: dict):
    x = embed(params["embed"], token, cfg)

    def body(c, inp):
        lp, lc = inp
        return dec_block_decode(lp, cfg, c, lc, pos)

    from repro.models.common import maybe_scan
    x, new = maybe_scan(cfg, body, x, (params["dec"], cache["dec"]))
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params["embed"], h, cfg), {"dec": new}
