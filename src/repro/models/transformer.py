"""Decoder-only LM assembly: staged scan-over-layers, remat, cache plumbing.

A model is a list of *stages*; each stage is `count` copies of one block
`kind` with parameters stacked along a leading ``layers`` axis and applied
under ``lax.scan`` (keeps HLO compact for 30-94 layer models — critical for
the 512-device dry-run compile). Heterogeneous families (hybrid zamba2,
xlstm) are expressed as composite segment kinds so each stage stays
scan-homogeneous.

Three entry points per model: ``lm_loss`` (training), ``lm_prefill`` and
``lm_decode`` (serving, explicit cache trees). The cache tree mirrors the
stage structure so the same scan drives all three.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (ModelConfig, Spec, dense_spec, maybe_scan,
                                 norm_spec)
from repro.models.layers import (chunked_ce_loss, embed, embed_specs, mlp,
                                 mlp_specs, rmsnorm, unembed)
from repro.sharding.rules import shard as _shard

SDS = jax.ShapeDtypeStruct


# ------------------------------------------------------------- layer plan ----
def layer_plan(cfg: ModelConfig) -> list[tuple[str, int]]:
    """[(kind, count)] stages; each stage is one homogeneous scan."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return [("dense", cfg.n_layers)]
    if fam == "moe":
        plan = []
        if cfg.n_dense_layers:
            plan.append(("dense", cfg.n_dense_layers))
        plan.append(("moe", cfg.n_layers - cfg.n_dense_layers))
        return plan
    if fam == "ssm":
        return [("mamba", cfg.n_layers)]
    if fam == "xlstm":
        k = cfg.slstm_every
        segs, rem = divmod(cfg.n_layers, k)
        plan = []
        if segs:
            plan.append(("xlstm_seg", segs))
        if rem:
            plan.append(("mlstm", rem))
        return plan
    if fam == "hybrid":
        k = cfg.attn_every
        segs, rem = divmod(cfg.n_layers, k)
        plan = []
        if segs:
            plan.append(("zamba_seg", segs))
        if rem:
            plan.append(("mamba", rem))
        return plan
    raise ValueError(f"unknown family {fam}")


def _stack_specs(tree, n: int):
    return jax.tree.map(
        lambda s: dataclasses.replace(s, shape=(n, *s.shape),
                                      axes=("layers", *s.axes)),
        tree, is_leaf=lambda x: isinstance(x, Spec))


def _is_cache_leaf(x) -> bool:
    return (isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], jax.ShapeDtypeStruct))


def _stack_cache_specs(tree, n: int):
    return jax.tree.map(
        lambda t: (SDS((n, *t[0].shape), t[0].dtype), ("layers", *t[1])),
        tree, is_leaf=_is_cache_leaf)


def _maybe_remat(f, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(f)
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return f


def _edge(x):
    return _shard(x, ("batch", "act_seq", None))


# ------------------------------------------------------------ block: dense ----
def dense_block_specs(cfg: ModelConfig, use_moe: bool = False) -> dict:
    d = cfg.d_model
    s = {"ln1": norm_spec(d), "ln2": norm_spec(d)}
    s["attn"] = attn.mla_specs(cfg) if cfg.use_mla else attn.gqa_specs(cfg)
    if use_moe:
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg)
    return s


def dense_block_fwd(p, cfg: ModelConfig, x, prefix_len=0):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        a = attn.mla_attention(p["attn"], cfg, h)
    elif isinstance(prefix_len, int) and prefix_len == 0:
        a = attn.gqa_attention(p["attn"], cfg, h)
    else:
        a = attn.gqa_prefix_attention(p["attn"], cfg, h, prefix_len)
    x = _edge(x + a)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    f = (moe_mod.moe_ffn(p["moe"], cfg, h) if "moe" in p
         else mlp(p["mlp"], cfg, h))
    return _edge(x + f)


def dense_block_cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype):
    if cfg.use_mla:
        return {
            "c_kv": (SDS((batch, max_len, cfg.kv_lora_rank), dtype),
                     ("batch", "kv_len", None)),
            "k_pe": (SDS((batch, max_len, cfg.qk_rope_head_dim), dtype),
                     ("batch", "kv_len", None)),
        }
    return {
        "k": (SDS((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
              ("batch", "kv_len", "kv_heads", None)),
        "v": (SDS((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
              ("batch", "kv_len", "kv_heads", None)),
    }


def dense_block_prefill(p, cfg: ModelConfig, x, max_len: int, prefix_len=0):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        a, (c_kv, k_pe) = attn.mla_prefill(p["attn"], cfg, h)
        cache = {"c_kv": _pad_len(c_kv, max_len), "k_pe": _pad_len(k_pe, max_len)}
    else:
        # prefix_len rides through run_attention so the q-chunked path can
        # build the prefix-LM mask per chunk (never an (S,S) materialization)
        a, (k, v) = attn.gqa_prefill(p["attn"], cfg, h, prefix_len=prefix_len)
        cache = {"k": _pad_len(k, max_len), "v": _pad_len(v, max_len)}
    x = _edge(x + a)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    f = (moe_mod.moe_ffn(p["moe"], cfg, h) if "moe" in p
         else mlp(p["mlp"], cfg, h))
    return _edge(x + f), cache


def dense_block_decode(p, cfg: ModelConfig, x, cache, pos):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        a, (c_kv, k_pe) = attn.mla_decode(p["attn"], cfg, h,
                                          (cache["c_kv"], cache["k_pe"]), pos)
        cache = {"c_kv": c_kv, "k_pe": k_pe}
    else:
        a, (k, v) = attn.gqa_decode(p["attn"], cfg, h,
                                    (cache["k"], cache["v"]), pos)
        cache = {"k": k, "v": v}
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    f = (moe_mod.moe_ffn(p["moe"], cfg, h) if "moe" in p
         else mlp(p["mlp"], cfg, h))
    return x + f, cache


def _pad_len(x, max_len: int):
    """Pad a (B, S, ...) prefill cache out to the max_len buffer."""
    S = x.shape[1]
    if S == max_len:
        return x
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, max_len - S)
    return jnp.pad(x, pad)


# ------------------------------------------------------------ block: mamba ----
def mamba_block_specs(cfg: ModelConfig) -> dict:
    return {"ln": norm_spec(cfg.d_model), "ssm": ssm_mod.mamba2_specs(cfg)}


def mamba_block_fwd(p, cfg, x, prefix_len=0):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    return _edge(x + ssm_mod.mamba2_forward(p["ssm"], cfg, h))


def mamba_block_cache_specs(cfg, batch, max_len, dtype):
    return ssm_mod.mamba2_cache_specs(cfg, batch, dtype)


def mamba_block_prefill(p, cfg, x, max_len, prefix_len=0):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    y, cache = ssm_mod.mamba2_forward(p["ssm"], cfg, h, return_cache=True)
    return _edge(x + y), cache


def mamba_block_decode(p, cfg, x, cache, pos):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    y, cache = ssm_mod.mamba2_decode(p["ssm"], cfg, h, cache)
    return x + y, cache


# ------------------------------------------------------------ block: mlstm ----
def mlstm_block_fwd(p, cfg, x, prefix_len=0):
    return _edge(x + xlstm_mod.mlstm_forward(p, cfg, x))


def mlstm_block_prefill(p, cfg, x, max_len, prefix_len=0):
    y, cache = xlstm_mod.mlstm_prefill(p, cfg, x)
    return _edge(x + y), cache


def mlstm_block_decode(p, cfg, x, cache, pos):
    y, cache = xlstm_mod.mlstm_decode(p, cfg, x, cache)
    return x + y, cache


# -------------------------------------------------------- block: xlstm_seg ----
def xlstm_seg_specs(cfg: ModelConfig) -> dict:
    k = cfg.slstm_every
    return {"mlstm": _stack_specs(xlstm_mod.mlstm_specs(cfg), k - 1),
            "slstm": xlstm_mod.slstm_specs(cfg)}


def xlstm_seg_fwd(p, cfg, x, prefix_len=0):
    def body(c, lp):
        return mlstm_block_fwd(lp, cfg, c), None
    x, _ = maybe_scan(cfg, body, x, p["mlstm"])
    return _edge(x + xlstm_mod.slstm_forward(p["slstm"], cfg, x))


def xlstm_seg_cache_specs(cfg, batch, max_len, dtype):
    k = cfg.slstm_every
    return {"mlstm": _stack_cache_specs(
        xlstm_mod.mlstm_cache_specs(cfg, batch, dtype), k - 1),
        "slstm": xlstm_mod.slstm_cache_specs(cfg, batch, dtype)}


def xlstm_seg_prefill(p, cfg, x, max_len, prefix_len=0):
    def body(c, lp):
        return mlstm_block_prefill(lp, cfg, c, max_len)
    x, m_caches = maybe_scan(cfg, body, x, p["mlstm"])
    y, s_cache = xlstm_mod.slstm_prefill(p["slstm"], cfg, x)
    return _edge(x + y), {"mlstm": m_caches, "slstm": s_cache}


def xlstm_seg_decode(p, cfg, x, cache, pos):
    def body(c, inp):
        lp, lc = inp
        return mlstm_block_decode(lp, cfg, c, lc, pos)
    x, m_caches = maybe_scan(cfg, body, x, (p["mlstm"], cache["mlstm"]))
    y, s_cache = xlstm_mod.slstm_decode(p["slstm"], cfg, x, cache["slstm"])
    return x + y, {"mlstm": m_caches, "slstm": s_cache}


# -------------------------------------------------------- block: zamba_seg ----
# zamba2: `attn_every` mamba blocks then one of the n_shared_blocks shared
# dense (attn+MLP) blocks, alternating — the shared params live OUTSIDE the
# scanned stage (repro.models.hybrid wires them through).
from repro.models import hybrid as hybrid_mod  # noqa: E402  (cycle-free: hybrid imports nothing from here at module scope)


# ----------------------------------------------------------------- registry ----
_BLOCKS: dict[str, dict[str, Any]] = {
    "dense": dict(specs=lambda cfg: dense_block_specs(cfg, use_moe=False),
                  fwd=dense_block_fwd, cache=dense_block_cache_specs,
                  prefill=dense_block_prefill, decode=dense_block_decode),
    "moe": dict(specs=lambda cfg: dense_block_specs(cfg, use_moe=True),
                fwd=dense_block_fwd, cache=dense_block_cache_specs,
                prefill=dense_block_prefill, decode=dense_block_decode),
    "mamba": dict(specs=mamba_block_specs, fwd=mamba_block_fwd,
                  cache=mamba_block_cache_specs, prefill=mamba_block_prefill,
                  decode=mamba_block_decode),
    "mlstm": dict(specs=xlstm_mod.mlstm_specs, fwd=mlstm_block_fwd,
                  cache=lambda cfg, b, m, dt: xlstm_mod.mlstm_cache_specs(cfg, b, dt),
                  prefill=mlstm_block_prefill, decode=mlstm_block_decode),
    "xlstm_seg": dict(specs=xlstm_seg_specs, fwd=xlstm_seg_fwd,
                      cache=xlstm_seg_cache_specs, prefill=xlstm_seg_prefill,
                      decode=xlstm_seg_decode),
    "zamba_seg": dict(specs=hybrid_mod.zamba_seg_specs,
                      fwd=None,  # needs shared params; handled in _apply_stage
                      cache=hybrid_mod.zamba_seg_cache_specs,
                      prefill=None, decode=None),
}


# ------------------------------------------------------------------- specs ----
def lm_specs(cfg: ModelConfig) -> dict:
    p: dict[str, Any] = {"embed": embed_specs(cfg),
                         "final_norm": norm_spec(cfg.d_model)}
    for i, (kind, count) in enumerate(layer_plan(cfg)):
        p[f"stage_{i}"] = _stack_specs(_BLOCKS[kind]["specs"](cfg), count)
    if cfg.family == "hybrid":
        p["shared"] = _stack_specs(dense_block_specs(cfg), cfg.n_shared_blocks)
    if cfg.family == "vlm":
        p["vision_proj"] = dense_spec(cfg.vision_width, cfg.d_model,
                                      ("embed", None))
    return p


def lm_cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=None) -> dict:
    dtype = dtype or cfg.cdtype
    c: dict[str, Any] = {}
    for i, (kind, count) in enumerate(layer_plan(cfg)):
        c[f"stage_{i}"] = _stack_cache_specs(
            _BLOCKS[kind]["cache"](cfg, batch, max_len, dtype), count)
    return c


def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    specs = lm_cache_specs(cfg, batch, max_len, dtype)

    # zero caches are valid starts everywhere: the mlstm/slstm stabilizer m
    # only weights the (zero) C/n contributions, so m=0 is equivalent to -inf.
    return jax.tree.map(lambda t: jnp.zeros(t[0].shape, t[0].dtype),
                        specs, is_leaf=_is_cache_leaf)


# ----------------------------------------------------------------- forward ----
def _apply_stage(stage_params, cfg: ModelConfig, kind: str, x, prefix_len,
                 shared=None):
    if kind == "zamba_seg":
        return hybrid_mod.zamba_seg_scan(stage_params, cfg, x, shared,
                                         _maybe_remat, prefix_len)
    fwd = _BLOCKS[kind]["fwd"]

    def body(c, lp):
        return fwd(lp, cfg, c, prefix_len), None

    body = _maybe_remat(body, cfg)
    x, _ = maybe_scan(cfg, body, x, stage_params)
    return x


def lm_hidden(params, cfg: ModelConfig, tokens: jnp.ndarray,
              patches: jnp.ndarray | None = None) -> tuple[jnp.ndarray, Any]:
    """Token (+ optional stub-modality prefix) -> final hidden states."""
    x = embed(params["embed"], tokens, cfg)
    prefix_len = 0
    if cfg.family == "vlm":
        assert patches is not None, "vlm needs stub patch embeddings"
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)   # gemma embed scaling
        vis = (patches.astype(cfg.cdtype) @ params["vision_proj"].astype(cfg.cdtype))
        x = jnp.concatenate([vis, x], axis=1)
        prefix_len = patches.shape[1]
    x = _edge(x)
    for i, (kind, _) in enumerate(layer_plan(cfg)):
        x = _apply_stage(params[f"stage_{i}"], cfg, kind, x, prefix_len,
                         shared=params.get("shared"))
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), prefix_len


def lm_loss(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    """batch: tokens (B,S), labels (B,S) [, patches (B,P,W), mask (B,S)]."""
    hidden, prefix_len = lm_hidden(params, cfg, batch["tokens"],
                                   batch.get("patches"))
    if cfg.family == "vlm":           # loss over the text tail only
        hidden = hidden[:, prefix_len:, :]
    return chunked_ce_loss(params["embed"], cfg, hidden, batch["labels"],
                           batch.get("mask"))


# ------------------------------------------------------------------- serve ----
def lm_prefill(params, cfg: ModelConfig, tokens: jnp.ndarray, max_len: int,
               patches: jnp.ndarray | None = None):
    """Process a prompt; return (last-position logits, cache tree)."""
    x = embed(params["embed"], tokens, cfg)
    prefix_len = 0
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        vis = (patches.astype(cfg.cdtype) @ params["vision_proj"].astype(cfg.cdtype))
        x = jnp.concatenate([vis, x], axis=1)
        prefix_len = patches.shape[1]
    x = _edge(x)
    caches = {}
    for i, (kind, _) in enumerate(layer_plan(cfg)):
        if kind == "zamba_seg":
            x, caches[f"stage_{i}"] = hybrid_mod.zamba_seg_prefill_scan(
                params[f"stage_{i}"], cfg, x, params["shared"], max_len)
            continue
        pf = _BLOCKS[kind]["prefill"]

        def body(c, lp, pf=pf):
            return pf(lp, cfg, c, max_len, prefix_len)

        x, caches[f"stage_{i}"] = maybe_scan(cfg, body, x, params[f"stage_{i}"])
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h[:, -1:, :], cfg)
    return logits, caches


def lm_decode(params, cfg: ModelConfig, token: jnp.ndarray, pos, cache: dict):
    """One decode step. token: (B,1) ids; pos: scalar position; cache in/out."""
    x = embed(params["embed"], token, cfg)
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    new_cache = {}
    for i, (kind, _) in enumerate(layer_plan(cfg)):
        if kind == "zamba_seg":
            x, new_cache[f"stage_{i}"] = hybrid_mod.zamba_seg_decode_scan(
                params[f"stage_{i}"], cfg, x, cache[f"stage_{i}"],
                params["shared"], pos)
            continue
        dec = _BLOCKS[kind]["decode"]

        def body(c, inp, dec=dec):
            lp, lc = inp
            return dec(lp, cfg, c, lc, pos)

        x, new_cache[f"stage_{i}"] = maybe_scan(
            cfg, body, x, (params[f"stage_{i}"], cache[f"stage_{i}"]))
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg)
    return logits, new_cache
