"""Attention variants: MHA/GQA (+bias), MLA (DeepSeek-V2), cache decode.

All functions are pure; the Pallas flash-attention kernel is an optional hot
path behind ``cfg.use_flash`` (never used in the dry-run, where the XLA
einsum path keeps cost_analysis meaningful).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Spec, dense_spec, norm_spec
from repro.models.layers import apply_rope, rmsnorm
from repro.sharding.rules import shard as _shard

NEG = -1e30


# ------------------------------------------------------------------- masks ----
def causal_mask(S: int) -> jnp.ndarray:
    return jnp.tril(jnp.ones((S, S), dtype=bool))


def prefix_lm_mask(S: int, prefix_len: int) -> jnp.ndarray:
    """Bidirectional over the first `prefix_len` positions, causal after
    (PaliGemma-style image+prompt prefix)."""
    m = causal_mask(S)
    pre = jnp.arange(S) < prefix_len
    return m | (pre[None, :] & pre[:, None])


# --------------------------------------------------------------- GQA attn ----
def gqa_specs(cfg: ModelConfig) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        "wq": Spec((d, H, hd), ("embed", "heads", None), 1.0 / math.sqrt(d)),
        "wk": Spec((d, Hkv, hd), ("embed", "kv_heads", None), 1.0 / math.sqrt(d)),
        "wv": Spec((d, Hkv, hd), ("embed", "kv_heads", None), 1.0 / math.sqrt(d)),
        "wo": Spec((H, hd, d), ("heads", None, "embed"), 1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        s["bq"] = Spec((H, hd), ("heads", None), 0.0)
        s["bk"] = Spec((Hkv, hd), ("kv_heads", None), 0.0)
        s["bv"] = Spec((Hkv, hd), ("kv_heads", None), 0.0)
    return s


def _qkv(params, cfg: ModelConfig, x: jnp.ndarray, pos: jnp.ndarray):
    """x: (B,S,d) -> q (B,S,H,hd), k/v (B,S,Hkv,hd), RoPE applied."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         mask: jnp.ndarray | None, scale: float | None = None) -> jnp.ndarray:
    """q (B,Sq,H,hd), k/v (B,Skv,Hkv,hd) with GQA head-group broadcast."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale or (1.0 / math.sqrt(hd))
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scores = jnp.einsum("bqhgc,bkhc->bhgqk", qg * scale,
                        k, preferred_element_type=jnp.float32)
    del hd
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :] if mask.ndim == 3
                           else mask[None, None, None, :, :], scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])  # v dim may differ from q (MLA)


def sdpa_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 chunk: int, prefix_len: jnp.ndarray | int = 0,
                 scale: float | None = None,
                 unroll: bool = False) -> jnp.ndarray:
    """Causal/prefix-LM attention scanned over query chunks.

    Bounds the live score tensor to (B, H, chunk, S) — the memory-term fix for
    the 32k cells where a full (S, S) mask/score tensor is O(4 GB)/chip. The
    mask is computed on the fly from positions (never materialized at S×S).
    """
    B, S, H, hd = q.shape
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    qc = q.reshape(B, n, chunk, H, hd).swapaxes(0, 1)      # (n, B, c, H, hd)
    kpos = jnp.arange(S, dtype=jnp.int32)

    @jax.checkpoint  # per-chunk remat: otherwise the scan saves every
    def step(_, inp):  # chunk's (B,H,chunk,S) probs for bwd = full S^2 again
        qi, i = inp
        qpos = i * chunk + jnp.arange(chunk, dtype=jnp.int32)
        mask = kpos[None, :] <= qpos[:, None]
        mask = mask | ((qpos[:, None] < prefix_len) & (kpos[None, :] < prefix_len))
        return None, sdpa(qi, k, v, mask, scale)

    if unroll:  # dry-run: every chunk visible to cost analysis
        outs = jnp.stack([step(None, (qc[i], jnp.int32(i)))[1]
                          for i in range(n)])
    else:
        _, outs = jax.lax.scan(step, None,
                               (qc, jnp.arange(n, dtype=jnp.int32)))
    # out dim follows v (MLA: v_head_dim != qk head dim)
    return outs.swapaxes(0, 1).reshape(B, S, H, outs.shape[-1])


def run_attention(cfg: ModelConfig, q, k, v, prefix_len=0,
                  scale: float | None = None) -> jnp.ndarray:
    """Dispatch: Pallas flash > q-chunked scan > naive, per cfg."""
    S = q.shape[1]
    if cfg.use_flash and prefix_len == 0:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True, scale=scale)
    if cfg.attn_chunk and S % cfg.attn_chunk == 0 and S > cfg.attn_chunk:
        return sdpa_chunked(q, k, v, cfg.attn_chunk, prefix_len, scale,
                            unroll=cfg.unroll_loops)
    if isinstance(prefix_len, int) and prefix_len == 0:
        mask = causal_mask(S)
    else:
        mask = prefix_lm_mask(S, prefix_len)
    return sdpa(q, k, v, mask, scale)


def gqa_attention(params, cfg: ModelConfig, x: jnp.ndarray,
                  mask: jnp.ndarray | None = None,
                  pos: jnp.ndarray | None = None) -> jnp.ndarray:
    B, S, _ = x.shape
    if pos is None:
        pos = jnp.arange(S)[None, :].astype(jnp.int32)
    q, k, v = _qkv(params, cfg, x, pos)
    q = _shard(q, ("batch", None, "heads", None))
    k = _shard(k, ("batch", None, "kv_heads", None))
    if mask is None:
        out = run_attention(cfg, q, k, v)
    else:
        out = sdpa(q, k, v, mask)
    out = _shard(out, ("batch", None, "heads", None))
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def gqa_prefix_attention(params, cfg: ModelConfig, x: jnp.ndarray,
                         prefix_len) -> jnp.ndarray:
    """Prefix-LM attention (PaliGemma): bidirectional prefix, causal tail."""
    B, S, _ = x.shape
    pos = jnp.arange(S)[None, :].astype(jnp.int32)
    q, k, v = _qkv(params, cfg, x, pos)
    q = _shard(q, ("batch", None, "heads", None))
    out = run_attention(cfg, q, k, v, prefix_len=prefix_len)
    out = _shard(out, ("batch", None, "heads", None))
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


# ------------------------------------------------------------ GQA + cache ----
def gqa_prefill(params, cfg: ModelConfig, x: jnp.ndarray,
                mask: jnp.ndarray | None = None, prefix_len=0):
    """Returns (attn_out, (k_cache, v_cache)) for the processed prefix."""
    B, S, _ = x.shape
    pos = jnp.arange(S)[None, :].astype(jnp.int32)
    q, k, v = _qkv(params, cfg, x, pos)
    if mask is None:
        out = run_attention(cfg, q, k, v, prefix_len=prefix_len)
    else:
        out = sdpa(q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, (k, v)


def update_cache_at(cache: jnp.ndarray, new: jnp.ndarray,
                    pos_b: jnp.ndarray) -> jnp.ndarray:
    """Write `new` (B,1,...) into `cache` (B,Smax,...) at per-batch positions.

    vmap over the batch keeps per-slot positions independent (continuous
    batching: every slot may sit at a different depth) while staying a
    single batched dynamic-update-slice for the partitioner.
    """
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), p, axis=0))(cache, new, pos_b[:, 0])


def gqa_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache: tuple,
               pos: jnp.ndarray):
    """One-token decode. x: (B,1,d); cache k/v: (B,Smax,Hkv,hd); pos: (B,) or ()."""
    k_cache, v_cache = cache
    B = x.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1, 1), (B, 1))
    q, k_new, v_new = _qkv(params, cfg, x, pos_b)
    # write the new K/V at each slot's own `pos`
    k_cache = update_cache_at(k_cache, k_new, pos_b)
    v_cache = update_cache_at(v_cache, v_new, pos_b)
    k_cache = _shard(k_cache, ("batch", "kv_len", "kv_heads", None))
    v_cache = _shard(v_cache, ("batch", "kv_len", "kv_heads", None))
    Smax = k_cache.shape[1]
    valid = (jnp.arange(Smax)[None, :] <= pos_b)        # (B, Smax)
    out = sdpa(q, k_cache.astype(x.dtype), v_cache.astype(x.dtype),
               valid[:, None, :])                        # mask (B,1,Smax)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, (k_cache, v_cache)


# ----------------------------------------------------------- MLA (DSv2) ----
def mla_specs(cfg: ModelConfig) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": dense_spec(d, qr, ("embed", "lora")),
        "q_norm": norm_spec(qr),
        "wq_b": Spec((qr, H, dn + dr), ("lora", "heads", None), 1.0 / math.sqrt(qr)),
        "wkv_a": dense_spec(d, kvr, ("embed", "lora")),
        "kv_norm": norm_spec(kvr),
        "wk_rope": dense_spec(d, dr, ("embed", None)),
        "wkv_b": Spec((kvr, H, dn + dv), ("lora", "heads", None),
                      1.0 / math.sqrt(kvr)),
        "wo": Spec((H, dv, d), ("heads", None, "embed"), 1.0 / math.sqrt(H * dv)),
    }


def _mla_qkv(params, cfg: ModelConfig, x, pos, c_kv, k_pe):
    """Shared MLA math given latent c_kv (B,S,kvr) and rope key k_pe (B,S,dr)."""
    dt = x.dtype
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    cq = rmsnorm(x @ params["wq_a"].astype(dt), params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"].astype(dt))
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, pos, cfg.rope_theta)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, params["wkv_b"].astype(dt))
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k_pe_h = jnp.broadcast_to(k_pe[:, :, None, :],
                              (*k_pe.shape[:2], H, dr))
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_full = jnp.concatenate([k_nope, k_pe_h], axis=-1)
    scale = 1.0 / math.sqrt(dn + dr)
    return q_full, k_full, v, scale


def mla_attention(params, cfg: ModelConfig, x: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    B, S, _ = x.shape
    dt = x.dtype
    pos = jnp.arange(S)[None, :].astype(jnp.int32)
    c_kv = rmsnorm(x @ params["wkv_a"].astype(dt), params["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope((x @ params["wk_rope"].astype(dt))[:, :, None, :],
                      pos, cfg.rope_theta)[:, :, 0, :]
    q, k, v, scale = _mla_qkv(params, cfg, x, pos, c_kv, k_pe)
    if mask is None:
        out = run_attention(cfg, q, k, v, scale=scale)
    else:
        out = sdpa(q, k, v, mask, scale)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


def mla_prefill(params, cfg: ModelConfig, x: jnp.ndarray):
    """Cache is the LATENT (c_kv, k_pe) — the MLA memory win: per token only
    kv_lora_rank + rope_dim values instead of 2·H·hd."""
    B, S, _ = x.shape
    dt = x.dtype
    pos = jnp.arange(S)[None, :].astype(jnp.int32)
    c_kv = rmsnorm(x @ params["wkv_a"].astype(dt), params["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope((x @ params["wk_rope"].astype(dt))[:, :, None, :],
                      pos, cfg.rope_theta)[:, :, 0, :]
    q, k, v, scale = _mla_qkv(params, cfg, x, pos, c_kv, k_pe)
    out = run_attention(cfg, q, k, v, scale=scale)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return out, (c_kv, k_pe)


def mla_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache: tuple,
               pos: jnp.ndarray):
    c_cache, pe_cache = cache  # (B,Smax,kvr), (B,Smax,dr)
    B = x.shape[0]
    dt = x.dtype
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1, 1), (B, 1))
    c_new = rmsnorm(x @ params["wkv_a"].astype(dt), params["kv_norm"], cfg.norm_eps)
    pe_new = apply_rope((x @ params["wk_rope"].astype(dt))[:, :, None, :],
                        pos_b, cfg.rope_theta)[:, :, 0, :]
    c_cache = update_cache_at(c_cache, c_new, pos_b)
    pe_cache = update_cache_at(pe_cache, pe_new, pos_b)
    c_cache = _shard(c_cache, ("batch", "kv_len", None))
    q, k, v, scale = _mla_qkv(params, cfg, x, pos_b,
                              c_cache.astype(dt), pe_cache.astype(dt))
    Smax = c_cache.shape[1]
    valid = jnp.arange(Smax)[None, :] <= pos_b
    out = sdpa(q, k, v, valid[:, None, :], scale)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return out, (c_cache, pe_cache)


# ------------------------------------------------------------- cross attn ----
def cross_specs(cfg: ModelConfig) -> dict:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "wq": Spec((d, H, hd), ("embed", "heads", None), 1.0 / math.sqrt(d)),
        "wk": Spec((d, H, hd), ("embed", "heads", None), 1.0 / math.sqrt(d)),
        "wv": Spec((d, H, hd), ("embed", "heads", None), 1.0 / math.sqrt(d)),
        "wo": Spec((H, hd, d), ("heads", None, "embed"), 1.0 / math.sqrt(H * hd)),
    }


def cross_attention(params, cfg: ModelConfig, x: jnp.ndarray,
                    memory: jnp.ndarray,
                    mem_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """x: (B,Sq,d) queries over encoder memory (B,Skv,d). No RoPE."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"].astype(dt))
    mask = None
    if mem_mask is not None:
        mask = jnp.broadcast_to(mem_mask[:, None, :],
                                (x.shape[0], x.shape[1], memory.shape[1]))
    out = sdpa(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
