"""PaliGemma-style VLM support (stub SigLIP frontend).

Per the assignment, the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, n_patches, vision_width) standing
in for SigLIP-so400m output (n_patches=256 at 224px/patch-14,
vision_width=1152). The backbone work — linear projection to d_model,
prefix-LM masking over [patches | prompt], gemma decoder — lives in
``repro.models.transformer`` (family == "vlm"); this module holds the stub's
dimension bookkeeping so configs and input_specs agree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

SDS = jax.ShapeDtypeStruct


def patch_spec(cfg: ModelConfig, batch: int):
    """ShapeDtypeStruct for the stub patch-embedding input."""
    return SDS((batch, cfg.n_patches, cfg.vision_width), cfg.cdtype)


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text tokens occupy the sequence budget left after the patch prefix."""
    assert seq_len > cfg.n_patches, (seq_len, cfg.n_patches)
    return seq_len - cfg.n_patches


def fake_patches(cfg: ModelConfig, batch: int, key: jax.Array) -> jnp.ndarray:
    """Deterministic stand-in frontend output for smoke tests/examples."""
    return 0.02 * jax.random.normal(
        key, (batch, cfg.n_patches, cfg.vision_width), cfg.cdtype)
