"""Model configuration + parameter-tree machinery shared by all families.

Parameters are plain nested dicts of arrays. Every leaf has a parallel
*logical axis* annotation (tuple of axis names like ("embed", "mlp")) used by
``repro.sharding.rules`` to derive PartitionSpecs. ``param_specs`` builds the
tree abstractly (ShapeDtypeStruct — used by the dry-run, no allocation);
``init_params`` materializes it (used by smoke tests and real training).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | vlm | encdec | xlstm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 256
    vocab: int = 512
    qkv_bias: bool = False
    tie_embeddings: bool = False
    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU) | gelu_mlp
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    n_dense_layers: int = 0        # leading dense (non-MoE) layers
    norm_topk_prob: bool = False
    moe_group_size: int = 4096     # tokens per dispatch group (GShard-style)
    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (Mamba-2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- hybrid (zamba2) ---
    attn_every: int = 6            # shared attn block period
    n_shared_blocks: int = 2       # alternating shared attention blocks
    # --- xLSTM ---
    slstm_every: int = 8           # every k-th block is sLSTM
    mlstm_proj_factor: float = 2.0
    # --- vlm ---
    n_patches: int = 0
    vision_width: int = 0          # stub frontend embedding width
    prefix_lm: bool = False
    # --- encdec ---
    n_enc_layers: int = 0
    # --- numerics / structure ---
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "none"            # none | full | dots
    scan_layers: bool = True
    logits_chunk: int = 0          # 0 -> unchunked CE; else seq-chunked CE
    use_flash: bool = False        # Pallas flash-attention hot path (TPU)
    attn_chunk: int = 0            # 0 -> naive sdpa; else q-chunked (memory-bounded)
    mlstm_chunk: int = 1024        # chunked mLSTM when L > chunk (0 = never)
    # dry-run knob: unroll structural loops (layers, q-chunks, microbatches)
    # so compiled.cost_analysis() counts every iteration — XLA's cost model
    # visits a while body ONCE, which would undercount scanned FLOPs by ~L.
    unroll_loops: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ------------------------------------------------------------ param trees ----
@dataclasses.dataclass(frozen=True)
class Spec:
    """Declarative leaf: shape + logical sharding axes (+ init scale)."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    scale: float = 0.02
    dtype: Any = None  # defaults to cfg param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def tree_specs(spec_tree) -> Any:
    """Strip to a ShapeDtypeStruct tree (abstract params for the dry-run)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, Spec))


def tree_axes(spec_tree) -> Any:
    """Parallel tree of logical-axis tuples."""
    return jax.tree.map(lambda s: s.axes, spec_tree,
                        is_leaf=lambda x: isinstance(x, Spec))


def init_tree(spec_tree, key: jax.Array) -> Any:
    """Materialize parameters (truncated-normal fan-in style)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        if s.scale == 0.0:
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.scale == 1.0 and len(s.shape) == 1:
            out.append(jnp.ones(s.shape, s.dtype))
        else:
            out.append(s.scale * jax.random.truncated_normal(
                k, -2.0, 2.0, s.shape, jnp.float32).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def n_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, Spec))
    return sum(int(np.prod(s.shape)) for s in leaves)


def spec_with_dtype(spec_tree, dtype) -> Any:
    return jax.tree.map(
        lambda s: dataclasses.replace(s, dtype=dtype) if s.dtype is None else s,
        spec_tree, is_leaf=lambda x: isinstance(x, Spec))


# ---------------------------------------------------------------- helpers ----
def maybe_scan(cfg: ModelConfig, body, init, xs, length: int | None = None):
    """``lax.scan``, or an unrolled python loop when ``cfg.unroll_loops``.

    Only for STRUCTURAL loops with static trip counts (layer stacks,
    attention q-chunks, CE chunks, microbatches) — never for token-level
    recurrences. Unrolling exists so the dry-run's cost analysis is exact.
    """
    if not cfg.unroll_loops:
        return jax.lax.scan(body, init, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, stacked


def dense_spec(d_in: int, d_out: int, axes=("embed", "mlp"), scale=None) -> Spec:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return Spec((d_in, d_out), axes, scale)


def norm_spec(d: int, axis: str | None = None) -> Spec:
    return Spec((d,), (axis,), scale=1.0)


def activation(name: str) -> Callable[[jnp.ndarray], jnp.ndarray]:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_mlp": jax.nn.gelu, "relu": jax.nn.relu}[name]
