"""JAX version-compatibility shims.

The codebase targets current jax (top-level ``jax.shard_map``,
``jax.sharding.AxisType``, dict-valued ``Compiled.cost_analysis``), but must
also run on the 0.4.x line some containers pin (no AxisType, shard_map still
under ``jax.experimental``, list-valued cost_analysis). Every
version-sensitive call site goes through this module so the difference lives
in exactly one place.
"""

from __future__ import annotations

import jax


def make_auto_mesh(shape, axis_names, devices=None):
    """``jax.make_mesh`` with Auto axis types where supported.

    On older jax the ``axis_types`` kwarg (and ``AxisType``) don't exist;
    meshes are implicitly auto there, so omitting it is equivalent.
    """
    kwargs = {} if devices is None else {"devices": devices}
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axis_names,
                             axis_types=(AxisType.Auto,) * len(axis_names),
                             **kwargs)
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axis_names, **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check: bool = False):
    """Top-level ``jax.shard_map`` or the ``jax.experimental`` fallback.

    ``axis_names`` = the mesh axes the body is manual over (None: all).
    ``check`` maps onto check_vma (new) / check_rep (old).
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        try:
            return jax.shard_map(f, check_vma=check, **kwargs)
        except TypeError:                       # older check_rep spelling
            return jax.shard_map(f, check_rep=check, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=auto)


def cost_analysis_dict(compiled_or_lowered) -> dict:
    """``.cost_analysis()`` as a flat dict (older jax returns a 1-list)."""
    cost = compiled_or_lowered.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
