"""qwen3-moe-235b-a22b — 128-expert top-8 MoE, GQA kv=4, head_dim 128.

[hf:Qwen/Qwen3-30B-A3B (family); hf] 94L d_model=4096 64H (GQA kv=4)
expert d_ff=1536 vocab=151936, MoE 128e top-8 with normalized top-k probs,
explicit head_dim=128 (64 x 128 != d_model).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151_936,
    n_experts=128,
    n_experts_per_tok=8,
    moe_d_ff=1536,
    norm_topk_prob=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
