"""xlstm-1.3b — mLSTM (matrix memory) + sLSTM blocks, 7:1.

[arXiv:2405.04517; unverified] 48L d_model=2048 4H vocab=50304, d_ff=0
(pre-up-projection blocks carry the FFN capacity; proj factor 2).
Every ``slstm_every``-th block is an sLSTM block.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    slstm_every=8,           # 42 mLSTM : 6 sLSTM =~ 7:1
    mlstm_proj_factor=2.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
