"""Architecture registry: ``--arch <id>`` -> ModelConfig (+ reduced twins).

``get_config(id)`` returns the full published configuration;
``reduced_config(id)`` returns a family-faithful shrunken twin for CPU smoke
tests (few layers, narrow width, tiny vocab, few experts — same block
structure and code paths). The full configs are exercised only through the
dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

from repro.models.common import ModelConfig

from repro.configs import (  # noqa: F401
    deepseek_v2_236b,
    llama3_8b,
    paligemma_3b,
    qwen15_05b,
    qwen15_4b,
    qwen3_moe_235b,
    seamless_m4t_medium,
    smollm_135m,
    xlstm_1p3b,
    zamba2_7b,
)
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, tune_for_shape  # noqa: F401

_REGISTRY: dict[str, ModelConfig] = {
    "zamba2-7b": zamba2_7b.CONFIG,
    "llama3-8b": llama3_8b.CONFIG,
    "smollm-135m": smollm_135m.CONFIG,
    "qwen1.5-0.5b": qwen15_05b.CONFIG,
    "qwen1.5-4b": qwen15_4b.CONFIG,
    "deepseek-v2-236b": deepseek_v2_236b.CONFIG,
    "qwen3-moe-235b-a22b": qwen3_moe_235b.CONFIG,
    "xlstm-1.3b": xlstm_1p3b.CONFIG,
    "paligemma-3b": paligemma_3b.CONFIG,
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
}

ARCHS: tuple[str, ...] = tuple(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def reduced_config(name: str) -> ModelConfig:
    """Family-faithful small twin: same block structure, CPU-sized dims."""
    full = get_config(name)
    kw: dict = dict(
        name=full.name + "-reduced",
        d_model=128,
        n_heads=4,
        n_kv_heads=(1 if full.n_kv_heads == 1
                    else (2 if full.n_kv_heads < full.n_heads else 4)),
        head_dim=32 if full.head_dim else 0,
        d_ff=256 if full.d_ff else 0,
        vocab=512,
        param_dtype="float32",
        compute_dtype="float32",
        logits_chunk=0,
        attn_chunk=0,
        remat="none",
    )
    fam = full.family
    if fam == "moe":
        # capacity 8.0: reduced twins are drop-free so decode == forward in
        # tests (capacity dropping is a train-time approximation whose drop
        # set depends on T — prefill/forward would legitimately diverge)
        kw.update(n_layers=3 if full.n_dense_layers else 2, n_experts=8,
                  n_experts_per_tok=min(2, full.n_experts_per_tok),
                  moe_d_ff=64, capacity_factor=8.0,
                  n_dense_layers=min(1, full.n_dense_layers))
        if full.use_mla:
            kw.update(q_lora_rank=48, kv_lora_rank=32, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32, d_ff=256)
    elif fam == "hybrid":
        kw.update(n_layers=2 * full.attn_every + 1, attn_every=full.attn_every,
                  ssm_state=16, ssm_headdim=16, ssm_chunk=16, d_ff=256)
    elif fam == "ssm":
        kw.update(n_layers=3, ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    elif fam == "xlstm":
        kw.update(n_layers=2 * full.slstm_every, d_ff=0)
    elif fam == "vlm":
        kw.update(n_layers=2, n_patches=8, vision_width=48)
    elif fam == "encdec":
        kw.update(n_layers=2, n_enc_layers=2, vision_width=48)
    else:
        kw.update(n_layers=2)
    return full.replace(**kw)
