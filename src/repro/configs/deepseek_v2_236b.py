"""deepseek-v2-236b — MLA + fine-grained MoE (2 shared + 160 routed, top-6).

[arXiv:2405.04434; hf] 60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536,
qk_nope=128 / qk_rope=64 / v=128), expert d_ff=1536, vocab=102400,
first layer dense (d_ff=12288), 2 shared + 160 routed experts top-6.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: per-head KV is derived from the latent
    d_ff=12288,              # the single leading dense layer's FFN
    vocab=102_400,
    n_experts=160,
    n_experts_per_tok=6,
    n_shared_experts=2,
    moe_d_ff=1536,
    n_dense_layers=1,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
