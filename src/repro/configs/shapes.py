"""Assigned input shapes and per-(arch × shape) applicability.

Four LM shapes per architecture (40 cells total):

    train_4k      seq_len=4,096   global_batch=256   -> lowers train_step
    prefill_32k   seq_len=32,768  global_batch=32    -> lowers prefill_step
    decode_32k    seq_len=32,768  global_batch=128   -> lowers serve_step
    long_500k     seq_len=524,288 global_batch=1     -> lowers serve_step

``decode_*`` / ``long_*`` lower one-new-token serve steps against a KV cache
of ``seq_len``. ``long_500k`` needs sub-quadratic attention and is skipped
for pure full-attention architectures (DESIGN.md §5); it runs for the
SSM/hybrid archs (zamba2, xlstm) whose decode state is O(1)/O(L).
"""

from __future__ import annotations

import dataclasses

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# families whose decode-time attention state is sub-quadratic in seq_len
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid", "xlstm")


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for one (arch × shape) cell."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "skip(full-attn): 500k KV decode needs sub-quadratic attention"
    return True, ""


def tune_for_shape(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Shape-driven memory knobs so the BASELINE cell fits 16 GB/chip.

    These are fit-the-machine settings (remat, chunked CE, q-chunked
    attention), not hillclimb optimizations; §Perf iterates beyond them.
    """
    kw: dict = {}
    if shape.kind == "train":
        # chunked CE keeps live logits at chunk x vocab (152k-vocab archs
        # would otherwise materialize ~0.6 TB of logits per step)
        kw["logits_chunk"] = 512
        # full remat + q-chunked attention: unremat'd (B,H,S,S) fp32 scores
        # are ~10 GB/chip/layer at 4k even for the small archs
        kw["remat"] = "full"
        if shape.seq_len >= 2048:
            kw["attn_chunk"] = 512
    if shape.kind == "prefill" and shape.seq_len >= 8_192:
        kw["attn_chunk"] = 1024  # bound live scores to (B,H,chunk,S);
        # applies to every family with any attention (incl. hybrid's shared
        # blocks, the VLM prefix path and the enc-dec decoder)
        kw["remat"] = "full" if cfg.d_model >= 2048 else cfg.remat
    return cfg.replace(**kw) if kw else cfg
