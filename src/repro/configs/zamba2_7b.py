"""zamba2-7b — Mamba2 backbone + alternating shared attention blocks.

[arXiv:2411.15242; unverified] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64. Every ``attn_every`` Mamba-2 blocks, one of the
``n_shared_blocks`` *shared* (weight-reused) attention+MLP blocks is applied,
alternating between the two shared blocks (DESIGN.md §5).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32_000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    attn_every=6,
    n_shared_blocks=2,
    tie_embeddings=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
