"""smollm-135m — llama-architecture small model (the e2e train example).

[hf:HuggingFaceTB/SmolLM-135M; hf] 30L d_model=576 9H (GQA kv=3) d_ff=1536
vocab=49152, tied embeddings.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49_152,
    tie_embeddings=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
