"""seamless-m4t-medium — encoder-decoder, multimodal (speech frontend stub).

[arXiv:2308.11596; hf] 12L enc + 12L dec, d_model=1024 16H d_ff=4096
vocab=256206. The speech frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (width 1024,
w2v-BERT-style) consumed by the bidirectional encoder; the causal decoder
cross-attends to the encoded memory.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,             # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256_206,
    vision_width=1024,       # stub frame-embedding width (frontend output)
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
