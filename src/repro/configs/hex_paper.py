"""The paper's own experiment configuration (section IV).

11x11 Hex, 1,048,576 playouts per move, Cp = 1.0. ``PAPER`` is the
full-scale setting; ``PAPER_CPU`` is the laptop-scaled harness default used
by tests/benchmarks on this CPU container (same shape of the experiment
grid, fewer playouts — wall-clock numbers are reported per-playout so the
scaling curves remain comparable).
"""

from repro.core.gscpm import GSCPMConfig

PAPER = GSCPMConfig(
    board_size=11,
    n_playouts=1_048_576,
    n_tasks=4096,           # paper's TPFIFO sweet spot (Fig 7)
    n_workers=244,          # 61 cores x 4-way SMT
    cp=1.0,
    tree_cap=1 << 20,
)

PAPER_CPU = GSCPMConfig(
    board_size=11,
    n_playouts=4096,
    n_tasks=64,
    n_workers=16,
    cp=1.0,
    tree_cap=1 << 14,
)

# the paper's task-count sweep (Fig 7/8 x-axis)
TASK_SWEEP = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096,
              8192, 16384]
