"""paligemma-3b — SigLIP(stub) + gemma-2b decoder, prefix-LM masking.

[arXiv:2407.07726; hf] 18L d_model=2048 8H (MQA kv=1, head_dim=256)
d_ff=16384 vocab=257216. The SigLIP frontend is a STUB per the assignment:
``input_specs()`` supplies 256 precomputed patch embeddings of width 1152
(so400m/14 @ 224px); the framework projects them to d_model and applies a
bidirectional prefix mask over [patches | prompt].
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257_216,
    act="gelu",
    tie_embeddings=True,
    n_patches=256,
    vision_width=1152,
    prefix_lm=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
