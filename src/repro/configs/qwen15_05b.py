"""qwen1.5-0.5b — dense transformer with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf] 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936, QKV bias, tied embeddings.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
