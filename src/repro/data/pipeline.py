"""Deterministic synthetic LM data pipeline, host-sharded, prefetching.

Every batch is a pure function of (seed, step, host shard), so the loader's
full state is ONE integer — the step counter — which rides inside the
checkpoint and makes restarts exactly resumable (no data repeated, none
skipped). Each host generates only its slice of the global batch
(process_index/process_count), which is how the pipeline scales to
multi-pod: data loading is embarrassingly parallel over hosts.

The token stream is a mixture of Zipf-distributed unigrams and short copy
motifs, giving a learnable (loss goes well below uniform) yet unbounded
synthetic corpus — enough signal for the e2e train example to show a
decreasing loss curve without any external dataset.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 17
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.3
    process_index: int = 0
    process_count: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.process_count == 0
        return self.global_batch // self.process_count


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    # Philox keyed on (seed, host) x counter=step — counter-based, O(1) seek
    # = the paper's per-task independent RNG streams, applied to the data path.
    key = (np.uint64(cfg.seed) << np.uint64(20)) | np.uint64(cfg.process_index)
    return np.random.Generator(np.random.Philox(key=[key, np.uint64(step)]))


def synth_batch(cfg: DataConfig, vocab: int, step: int,
                vlm_patches: tuple[int, int] | None = None,
                frames: tuple[int, int] | None = None) -> dict:
    """One deterministic {tokens, labels, mask} batch (+ stub modalities)."""
    rng = _rng_for(cfg, step)
    B, S = cfg.local_batch, cfg.seq_len
    # Zipf unigrams over the vocab (clipped), reserving 0 as pad/bos
    base = rng.zipf(cfg.zipf_a, size=(B, S + 1)).astype(np.int64)
    tokens = (base % (vocab - 1)) + 1
    # splice copy motifs: short windows repeated later in the sequence
    n_motifs = int(cfg.motif_prob * S / cfg.motif_len)
    L = cfg.motif_len
    if n_motifs and S + 1 > 2 * L:
        src = rng.integers(0, S + 1 - 2 * L, size=(B, n_motifs))
        dst = rng.integers(src + L, S + 2 - L)  # copy window stays in bounds
        for b in range(B):
            for m in range(n_motifs):
                s, d = src[b, m], dst[b, m]
                tokens[b, d:d + L] = tokens[b, s:s + L]
    tokens = tokens.astype(np.int32)
    out = {
        "tokens": tokens[:, :S],
        "labels": tokens[:, 1:S + 1],
        "mask": np.ones((B, S), np.float32),
    }
    if vlm_patches is not None:
        n_patches, width = vlm_patches
        out["patches"] = rng.standard_normal(
            (B, n_patches, width), dtype=np.float32) * 0.02
    if frames is not None:
        src_len, width = frames
        out["frames"] = rng.standard_normal(
            (B, src_len, width), dtype=np.float32) * 0.02
    return out


def make_batch_fn(cfg: DataConfig, model: ModelConfig,
                  src_len: int | None = None):
    """step -> batch closure, wiring the stub-modality shapes per family."""
    vlm = (model.n_patches, model.vision_width) if model.family == "vlm" else None
    frm = ((src_len or 4096, model.vision_width)
           if model.family == "encdec" else None)

    def fn(step: int) -> dict:
        b = synth_batch(cfg, model.vocab, step, vlm_patches=vlm, frames=frm)
        if model.family == "vlm":
            # text occupies seq_len; patches ride alongside (prefix concat
            # happens inside the model)
            pass
        return b

    return fn


class Prefetcher:
    """Background-thread prefetch of the next `depth` batches.

    Overlaps host-side data generation with device compute — the data-path
    half of compute/comm overlap. ``state()``/``restore()`` expose the step
    counter for checkpointing.
    """

    def __init__(self, batch_fn, start_step: int = 0, depth: int = 2):
        self._fn = batch_fn
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                item = (step, self._fn(step))
            except BaseException as e:  # surface in the consumer, don't hang
                self._q.put(e)
                return
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    step += 1
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        item = self._q.get()
        if isinstance(item, BaseException):
            raise item
        step, batch = item
        self._step = step + 1
        return step, batch

    def state(self) -> int:
        return self._step

    def close(self):
        self._stop.set()
        while not self._q.empty():
            self._q.get_nowait()
