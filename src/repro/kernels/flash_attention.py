"""Blockwise (flash) attention Pallas kernel — causal + GQA, TPU-targeted.

The 32k-prefill cells are quadratic-attention dominated; this kernel keeps
the working set in VMEM with an online-softmax accumulator so the (S x S)
score matrix never touches HBM.

Tiling (BlockSpec): grid = (B*H, S/bq, S/bk); for one (head, q-block) the
kv grid axis streams K/V blocks of shape (bk, d) through VMEM while fp32
scratch accumulators (m, l, acc) persist across the kv axis — the canonical
revisiting-output pattern. Block shapes default to (128, 128): MXU-aligned
(128x128 systolic array) and small enough that q/k/v/acc tiles fit VMEM
(~(2*bq + 2*bk) * d * 4 B ~= 0.5 MB at d=128 vs ~16 MB VMEM/core).

Causality is exploited structurally: kv blocks strictly above the diagonal
are skipped via ``@pl.when`` (halves the FLOPs — this is why the kernel,
not XLA's full-masked sdpa, is the TPU hot path for long prefill).

GQA is handled in the index maps: query head h reads kv head h // G, so no
K/V replication ever materializes.

Validated on CPU with ``interpret=True`` against ``ref.flash_attention``
over shape/dtype sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip kv blocks entirely above the causal diagonal
    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(                        # (bq, bk)
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                             # (bq,)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])                 # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, S, d); k/v: (B, Hkv, S, d), H % Hkv == 0. Returns q-shaped.

    d is padded to a multiple of 128 lanes inside the wrapper (zero columns
    are exact no-ops for both the dots and the softmax).
    """
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    scale_v = scale if scale is not None else 1.0 / math.sqrt(d)

    dp = -(-d // 128) * 128
    if dp != d:
        pad = [(0, 0)] * 3 + [(0, dp - d)]
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))

    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_q, n_kv = S // bq, S // bk

    qf = q.reshape(B * H, S, dp)
    kf = k.reshape(B * Hkv, S, dp)
    vf = v.reshape(B * Hkv, S, dp)

    kernel = functools.partial(
        _flash_kernel, scale=scale_v, block_q=bq, block_k=bk, causal=causal,
        n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, dp), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dp), lambda b, i, j, G=G, Hkv=Hkv:
                         ((b // (G * Hkv)) * Hkv + (b % (G * Hkv)) // G, j, 0)),
            pl.BlockSpec((1, bk, dp), lambda b, i, j, G=G, Hkv=Hkv:
                         ((b // (G * Hkv)) * Hkv + (b % (G * Hkv)) // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running denom l
            pltpu.VMEM((bq, dp), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(B, H, S, dp)
    return out[..., :d] if dp != d else out
