"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors its kernel's public signature exactly; tests sweep
shapes/dtypes and assert_allclose(kernel(interpret=True), ref).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    scale: float | None = None) -> jnp.ndarray:
    """q: (B, H, S, d); k/v: (B, Hkv, S, d) with GQA broadcast. fp32 math."""
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, S, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf * scale, kf)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, H, S, d).astype(q.dtype)


def uct_select(wins: jnp.ndarray, visits: jnp.ndarray, vloss: jnp.ndarray,
               parent_total: jnp.ndarray, valid: jnp.ndarray,
               cp, noise: jnp.ndarray | None = None,
               lane_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """(W, C) child stats -> (W,) best child slot (paper eq. 1 + tie-break).

    ``cp`` may be a traced scalar; ``lane_mask`` (W,) bool marks live lanes
    (a masked row is all-invalid and deterministically yields slot 0).
    """
    from repro.core.uct import select_child, uct_scores
    if lane_mask is not None:
        valid = valid & lane_mask[..., None]
    scores = uct_scores(wins, visits, vloss, parent_total, cp, valid)
    return select_child(scores, noise).astype(jnp.int32)


def hex_winner(boards: jnp.ndarray, size: int) -> jnp.ndarray:
    """(W, size*size) FILLED boards -> (W,) int8 winners in {1, 2}.

    Same filled-board contract as the kernel (`repro.core.hex.winner`).
    The batched pointer-doubling solve in `repro.core.hex` IS the jnp
    reference semantics: one connectivity check for BLACK decides every
    lane (the Hex theorem).
    """
    from repro.core import hex as hx
    black = hx.connected_batch(boards, hx.BLACK, hx.HexSpec(size))
    return jnp.where(black, jnp.int8(1), jnp.int8(2))


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """x: (..., D); w: (D,). fp32 statistics, input-dtype output."""
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * w.astype(jnp.float32)).astype(x.dtype)
