"""Public jit'd wrappers over the Pallas kernels.

``interpret`` defaults to auto: compiled on TPU, interpret-mode (pure
Python execution of the kernel body) everywhere else — which is how this
CPU container validates the kernels. Call sites (models/attention.py,
core/gscpm.py) go through these wrappers only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import uct_select as _us


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    interpret: bool | None = None,
                    layout: str = "bshd") -> jnp.ndarray:
    """Flash attention. layout 'bshd' (models) or 'bhsd' (kernel-native)."""
    it = _auto_interpret(interpret)
    if layout == "bshd":
        q, k, v = (t.swapaxes(1, 2) for t in (q, k, v))
    out = _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                              interpret=it)
    return out.swapaxes(1, 2) if layout == "bshd" else out


def uct_select(wins, visits, vloss, parent_total, valid, cp: float,
               noise=None, interpret: bool | None = None):
    return _us.uct_select(wins, visits, vloss, parent_total, valid, cp,
                          noise=noise, interpret=_auto_interpret(interpret))


def rmsnorm(x, w, eps: float = 1e-5, interpret: bool | None = None):
    return _rn.rmsnorm(x, w, eps=eps, interpret=_auto_interpret(interpret))
