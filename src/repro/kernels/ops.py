"""Public jit'd wrappers over the Pallas kernels.

``interpret`` defaults to auto. For ``flash_attention``/``rmsnorm`` that
means compiled on TPU, interpret-mode (pure Python execution of the kernel
body) everywhere else — which is how this CPU container validates them.
``uct_select`` and ``hex_winner`` sit on the search hot path, so their auto
mode never runs interpret-mode Pallas: compiled Pallas on TPU, the jitted
jnp reference on every other backend (interpret mode remains available for
validation via ``interpret=True``). This module is also the per-game eval
dispatch point of the Game seam (DESIGN.md §13): ``hex_winner`` for Hex,
``gomoku_winner`` / ``gomoku_first_winner`` for Gomoku (single jnp body on
all backends until a Pallas twin lands — ROADMAP). Call sites
(models/attention.py, core/gscpm.py, core/hex.py, core/gomoku.py,
serve/mcts_decode.py) go through these wrappers only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import hex_winner as _hw
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rn
from repro.kernels import uct_select as _us


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    interpret: bool | None = None,
                    layout: str = "bshd") -> jnp.ndarray:
    """Flash attention. layout 'bshd' (models) or 'bhsd' (kernel-native)."""
    it = _auto_interpret(interpret)
    if layout == "bshd":
        q, k, v = (t.swapaxes(1, 2) for t in (q, k, v))
    out = _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                              interpret=it)
    return out.swapaxes(1, 2) if layout == "bshd" else out


def uct_select(wins, visits, vloss, parent_total, valid, cp,
               noise=None, lane_mask=None, interpret: bool | None = None):
    """Batched UCT child selection — the search hot path's dispatch point.

    interpret=None (the default) picks the fast path per backend: the
    compiled Pallas kernel on TPU, the jitted jnp reference elsewhere
    (interpret-mode Pallas executes the kernel body in pure Python — it is
    a validation tool, never a serving/benchmark path). Pass interpret=True
    to force the interpret-mode kernel for validation. ``cp`` is traced on
    every path: sweeping it never recompiles.
    """
    if interpret is None and jax.default_backend() != "tpu":
        return _jitted_ref_uct_select(wins, visits, vloss, parent_total,
                                      valid, cp, noise, lane_mask)
    return _us.uct_select(wins, visits, vloss, parent_total, valid, cp,
                          noise=noise, lane_mask=lane_mask,
                          interpret=_auto_interpret(interpret))


@jax.jit
def _jitted_ref_uct_select(wins, visits, vloss, parent_total, valid, cp,
                           noise, lane_mask):
    return _ref.uct_select(wins, visits, vloss, parent_total, valid, cp,
                           noise=noise, lane_mask=lane_mask)


def hex_winner(boards, size: int, interpret: bool | None = None):
    """Batched Hex winner evaluation — the playout phase's dispatch point.

    boards: (W, size*size) FILLED boards; returns (W,) int8 winners.
    interpret=None (the default) picks the fast path per backend exactly
    like ``uct_select``: the compiled pointer-doubling Pallas kernel on
    TPU; elsewhere the jitted batched flood fill — on scalar-ish hardware
    a handful of extra boolean dilation steps are cheaper than the
    pointer-doubling round's gathers, so the O(log n) formulation is the
    *vector-hardware* fast path, not a universal one (DESIGN.md §12,
    measured in benchmarks/kernels_micro.py). Pass interpret=True to force
    the interpret-mode kernel for validation (never a timing path); the
    pointer-doubling jnp reference stays in ``kernels.ref`` as the
    kernel-semantics oracle.
    """
    if interpret is None and jax.default_backend() != "tpu":
        return _jitted_flood_hex_winner(boards, size)
    return _hw.hex_winner(boards, size,
                          interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("size",))
def _jitted_flood_hex_winner(boards, size: int):
    from repro.core import hex as hx
    return hx.winner_flood_batch(boards, hx.HexSpec(size))


def gomoku_winner(boards, size: int, interpret: bool | None = None):
    """Batched Gomoku terminal winner — the per-game eval dispatch twin of
    ``hex_winner`` (DESIGN.md §13).

    boards: (W, size*size) TERMINAL boards; returns (W,) int8 in
    {0 draw, 1, 2}. Unlike Hex — whose connectivity solve has two
    formulations with backend-dependent winners (pointer doubling vs flood
    fill) — the five-in-a-row test is four static-roll window scans that
    lower to plain vector shifts/ANDs on every backend, so a single jitted
    jnp body serves TPU and CPU alike. A dedicated Pallas kernel slot stays
    open in ROADMAP.md; ``interpret`` is accepted for signature symmetry.
    """
    del interpret  # no Pallas body yet — one jnp path on all backends
    return _jitted_gomoku_winner(boards, size)


@functools.partial(jax.jit, static_argnames=("size",))
def _jitted_gomoku_winner(boards, size: int):
    from repro.core import gomoku as gm
    return gm.winner_scan_batch(boards, gm.GomokuSpec(size))


def gomoku_first_winner(filled, times, size: int,
                        interpret: bool | None = None):
    """Fused Gomoku playout outcome: completion-time resolution over a
    random fill (the playout phase's dispatch point for the ``gomoku``
    game, as ``hex_winner`` is for ``hex``).

    filled: (W, size*size) int8 fully-filled boards; times: (W, size*size)
    int32 fill rank per cell (-1 for pre-playout stones). Returns (W,) int8
    outcomes {0 draw, 1, 2}: the color of the monochrome 5-window whose
    last cell has the minimal fill rank (see ``core/gomoku.py``).
    """
    del interpret
    return _jitted_gomoku_first_winner(filled, times, size)


@functools.partial(jax.jit, static_argnames=("size",))
def _jitted_gomoku_first_winner(filled, times, size: int):
    from repro.core import gomoku as gm
    return gm.first_completion_winner(filled, times, gm.GomokuSpec(size))


def rmsnorm(x, w, eps: float = 1e-5, interpret: bool | None = None):
    return _rn.rmsnorm(x, w, eps=eps, interpret=_auto_interpret(interpret))
