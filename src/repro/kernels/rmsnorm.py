"""Fused RMSNorm Pallas kernel (bandwidth-bound: one read, one write).

XLA emits mul + mean + rsqrt + mul + mul as separate HBM-visiting ops when
fusion heuristics miss; the kernel guarantees the fused form: each (bn, D)
tile is read once into VMEM, fp32 statistics computed in-register, scaled
output written once. Rows tile over the grid; D stays whole per tile
(d_model <= 8k -> tile <= 4 MB fp32, well inside VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, d_real: int):
    x = x_ref[...].astype(jnp.float32)
    # padded columns are zero and contribute nothing; divide by the REAL D
    ms = jnp.sum(x * x, axis=1, keepdims=True) / d_real
    y = x * jax.lax.rsqrt(ms + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_n", "interpret"))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5,
            block_n: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (..., D), w: (D,) -> same shape/dtype as x."""
    shape = x.shape
    D = shape[-1]
    x2 = x.reshape(-1, D)
    N = x2.shape[0]
    bn = min(block_n, N)
    Np = -(-N // bn) * bn
    Dp = -(-D // 128) * 128
    x2 = jnp.pad(x2, ((0, Np - N), (0, Dp - D)))
    wp = jnp.pad(w, (0, Dp - D)).reshape(1, Dp)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d_real=D),
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, Dp), lambda i: (i, 0)),
            pl.BlockSpec((1, Dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, Dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Dp), x.dtype),
        interpret=interpret,
    )(x2, wp)
    return out[:N, :D].reshape(shape)
