"""Batched pointer-doubling Hex winner Pallas kernel (the playout endgame).

The paper evaluates a playout by one union-find connectivity pass; on the
Phi that pass is the serial tail of every playout. The TPU-native
equivalent solves ALL W lanes' connectivity at once over a (W, n_cells)
tile with the PRAM pointer-jumping scheme (Shiloach–Vishkin / FastSV):
each round hooks every cell to the minimum label in its closed same-color
neighborhood, lets roots adopt the best label their subtree saw, and then
pointer-jumps — O(log n_cells) fixed rounds, no per-lane convergence
loop (DESIGN.md §12).

TPU mapping choices:

- the hook's six Hex-neighbor reads are *static* shifts of the flat board
  (``pltpu.roll`` by ``dr*size + dc``) with in-bounds masks computed from a
  2D iota — no gather;
- the pointer jump ``P[i] = P[P[i]]`` and the scatter-min hook are dynamic
  by nature; both are expressed as one-hot compare-and-reduce over a
  (bw, C, C) tile — the classic gather/scatter-as-matmul trick that keeps
  the kernel in pure VPU/MXU-friendly ops (C = padded cell count, 128 for
  boards up to 11x11, so the tile is small);
- the round count is FIXED at ``ceil(log2(n_cells)) + 2`` (converges
  <= 7 rounds empirically on random and adversarial snake/comb boards up
  to 25x25, against caps of 9-12; the jnp reference's fixpoint loop and
  the fixed-round kernel agree bit-for-bit — tests/test_hex_batch.py,
  tests/test_kernels.py).

Like ``uct_select``, the auto dispatch in ``kernels.ops`` compiles this on
TPU and uses the jitted jnp reference elsewhere; interpret mode is a
validation tool only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# (r, c) offsets of the six Hex neighbors on the rhombus board
DELTAS = ((-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0))


def _winner_kernel(board_ref, out_ref, *, size: int, n: int, rounds: int):
    brd = board_ref[...]                                   # (bw, C) int32
    bw, C = brd.shape
    cell = jax.lax.broadcasted_iota(jnp.int32, (bw, C), 1)
    r = cell // size
    c = cell % size
    black = (brd == 1) & (cell < n)

    # per-direction "neighbor exists and both endpoints are black" masks;
    # static across rounds, so hoisted out of the loop (rolls carry int32:
    # TPU vector shifts are lane ops, booleans are cast around them)
    blacki = black.astype(jnp.int32)
    edge_ok = []
    for dr, dc in DELTAS:
        rr, cc = r + dr, c + dc
        inb = (rr >= 0) & (rr < size) & (cc >= 0) & (cc < size)
        off = dr * size + dc
        nbr_black = pltpu.roll(blacki, (-off) % C, 1) == 1
        edge_ok.append(black & inb & nbr_black)

    col = jax.lax.broadcasted_iota(jnp.int32, (bw, C, C), 2)

    def one_round(_, P):
        # gather hook: min label over the closed same-color neighborhood
        m = P
        for (dr, dc), ok in zip(DELTAS, edge_ok):
            off = dr * size + dc
            m = jnp.minimum(m, jnp.where(ok, pltpu.roll(P, (-off) % C, 1), C))
        # scatter hook (roots adopt their subtree's best label) as a
        # one-hot segmented min: scat[j] = min{m[i] : P[i] == j}
        oh = P[:, :, None] == col
        scat = jnp.min(jnp.where(oh, m[:, :, None], C), axis=1)
        Q = jnp.minimum(jnp.minimum(P, scat), m)
        # pointer jump Q[i] = Q[Q[i]] as a one-hot gather
        ohq = Q[:, :, None] == col
        return jnp.min(jnp.where(ohq, Q[:, None, :], C), axis=2)

    P0 = cell  # non-black cells stay inert self-loops (no hookable edges)
    P = jax.lax.fori_loop(0, rounds, one_round, P0)

    # black connects top<->bottom iff a bottom black cell's component root
    # is also some top black cell's root
    top = black & (r == 0)
    bottom = black & (r == size - 1)
    oh = P[:, :, None] == col
    mark = jnp.any(oh & top[:, :, None], axis=1)           # (bw, C) roots@top
    reach = jnp.any(oh & mark[:, None, :], axis=2)         # mark[P[i]]
    conn = jnp.any(reach & bottom, axis=1, keepdims=True)  # (bw, 1)
    out_ref[...] = jnp.where(conn, 1, 2).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("size", "block_w", "interpret"))
def hex_winner(boards: jnp.ndarray, size: int, block_w: int = 8,
               interpret: bool = False) -> jnp.ndarray:
    """boards: (W, size*size) int8 FILLED boards. Returns (W,) int8 winners.

    Same contract as ``repro.core.hex.winner``: boards must be completely
    filled (the Hex-theorem single connectivity check is only a winner
    check on terminal boards).
    """
    # the round budget is owned by repro.core.hex (function-level import:
    # kernels must not depend on core at module scope) so kernel and jnp
    # paths can never drift apart
    from repro.core.hex import doubling_rounds

    W, n = boards.shape
    if n != size * size:
        raise ValueError(f"boards last dim {n} != size*size {size * size}")
    C = max(128, -(-n // 128) * 128)
    bw = min(block_w, W)
    Wp = -(-W // bw) * bw
    brd = jnp.pad(boards.astype(jnp.int32), ((0, Wp - W), (0, C - n)))

    out = pl.pallas_call(
        functools.partial(_winner_kernel, size=size, n=n,
                          rounds=doubling_rounds(n)),
        grid=(Wp // bw,),
        in_specs=[pl.BlockSpec((bw, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bw, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Wp, 1), jnp.int32),
        interpret=interpret,
    )(brd)
    return out[:W, 0].astype(jnp.int8)
