"""Batched UCT child-selection Pallas kernel (the paper's hot inner loop).

On the Xeon Phi the paper leans on 512-bit VPUs to vectorize UCT scoring;
the TPU-native equivalent is a VPU (8x128 vector unit) tile kernel: W worker
lanes x C child slots per tile, fused score computation + masked argmax,
one pass over VMEM-resident stats.

    UCT(j) = w_j/n_j + Cp * sqrt(ln(n_parent)/n_j)        (paper eq. 1)

with virtual loss folded into n_j, unvisited-first semantics (score 1e30),
invalid-slot masking (-1e30), done-lane masking (a finished lane's row is
all-invalid, so its pick is slot 0 and the caller holds it in place), and
bounded tie-break noise — bit-for-bit the same selection as
``repro.core.uct`` (tests sweep W/C/dtype and compare the chosen indices
against the oracle).

``cp`` is a *traced* scalar operand (a (1, 1) tile broadcast to every grid
step), not a static argument: sweeping Cp across an ablation grid reuses one
compiled kernel (the repo's "knobs traced ⇒ zero recompiles" rule).

Tiling: grid over W blocks; child axis padded to the 128-lane boundary and
kept whole per tile (C <= a few hundred for Hex/LM decode — one tile row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _uct_kernel(cp_ref, wins_ref, visits_ref, vloss_ref, ptot_ref, valid_ref,
                noise_ref, out_ref):
    wins = wins_ref[...].astype(jnp.float32)
    n_j = visits_ref[...].astype(jnp.float32) + vloss_ref[...].astype(jnp.float32)
    valid = valid_ref[...] > 0.5
    noise = noise_ref[...].astype(jnp.float32)
    cp = cp_ref[0, 0]

    x_j = wins / jnp.maximum(n_j, 1.0)
    n_p = jnp.maximum(ptot_ref[...].astype(jnp.float32), 1.0)   # (bw, 1)
    explore = cp * jnp.sqrt(jnp.log(n_p) / jnp.maximum(n_j, 1.0))
    score = x_j + explore + noise
    score = jnp.where(n_j <= 0.0, BIG + noise, score)   # unvisited first
    score = jnp.where(valid, score, -BIG)               # masked slots last
    out_ref[...] = jnp.argmax(score, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def uct_select(wins: jnp.ndarray, visits: jnp.ndarray, vloss: jnp.ndarray,
               parent_total: jnp.ndarray, valid: jnp.ndarray, cp,
               noise: jnp.ndarray | None = None,
               lane_mask: jnp.ndarray | None = None, block_w: int = 128,
               interpret: bool = False) -> jnp.ndarray:
    """wins/visits/vloss/valid: (W, C); parent_total: (W,). Returns (W,) i32.

    ``cp`` is traced (python float or 0-d array both hit one compile).
    ``lane_mask`` (W,) bool marks live lanes; a False row is fully invalid
    and deterministically selects slot 0 (its caller holds the lane anyway).
    """
    W, C = wins.shape
    if noise is None:
        noise = jnp.zeros((W, C), jnp.float32)
    if lane_mask is not None:
        valid = valid & lane_mask[:, None]

    bw = min(block_w, W)
    Wp = -(-W // bw) * bw
    Cp_ = -(-C // 128) * 128
    padWC = lambda x, fill=0.0: jnp.pad(
        x.astype(jnp.float32), ((0, Wp - W), (0, Cp_ - C)),
        constant_values=fill)
    wins_p = padWC(wins)
    visits_p = padWC(visits, 1.0)   # pad slots "visited" so no BIG scores
    vloss_p = padWC(vloss)
    valid_p = padWC(valid.astype(jnp.float32))          # pads invalid
    noise_p = padWC(noise)
    ptot_p = jnp.pad(parent_total.astype(jnp.float32), (0, Wp - W),
                     constant_values=1.0).reshape(Wp, 1)
    cp_arr = jnp.asarray(cp, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _uct_kernel,
        grid=(Wp // bw,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((bw, Cp_), lambda i: (i, 0)),
            pl.BlockSpec((bw, Cp_), lambda i: (i, 0)),
            pl.BlockSpec((bw, Cp_), lambda i: (i, 0)),
            pl.BlockSpec((bw, 1), lambda i: (i, 0)),
            pl.BlockSpec((bw, Cp_), lambda i: (i, 0)),
            pl.BlockSpec((bw, Cp_), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bw, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Wp, 1), jnp.int32),
        interpret=interpret,
    )(cp_arr, wins_p, visits_p, vloss_p, ptot_p, valid_p, noise_p)
    return out[:W, 0]
